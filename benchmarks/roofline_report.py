"""Aggregate the dry-run JSONs into the §Dry-run / §Roofline tables.

  PYTHONPATH=src python -m benchmarks.roofline_report [--dir ...] [--md]

Re-derives the three roofline terms from the stored raw values (so older
records produced before a roofline-formula fix are recomputed consistently)
and prints a per-(arch x shape x mesh) table plus the bottleneck summary.
"""
import argparse
import glob
import json
import os

from repro.configs import get_config
from repro.launch import roofline

DEF = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_all(d):
    recs = []
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_bytes(b):
    if b >= 1e9:
        return f"{b / 1e9:.1f}GB"
    if b >= 1e6:
        return f"{b / 1e6:.1f}MB"
    return f"{b / 1e3:.1f}KB"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=DEF)
    ap.add_argument("--md", action="store_true", help="markdown table")
    ap.add_argument("--mesh", default="all", choices=["all", "single", "multi"])
    args = ap.parse_args()

    recs = load_all(args.dir)
    rows, skips, fails = [], [], []
    for r in recs:
        if "skipped" in r:
            skips.append(r)
            continue
        if "error" in r:
            fails.append(r)
            continue
        cfg = get_config(r["arch"])
        r["roofline"] = roofline.roofline_terms(r, cfg, r["shape"])
        rows.append(r)

    rows.sort(key=lambda r: (r["arch"], r["shape"], r["multi_pod"]))
    hdr = ["arch", "shape", "mesh", "mode", "compute_s", "memory_s",
           "collective_s", "dominant", "hbm/dev", "ucr", "compile_s"]
    if args.md:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
    else:
        print(",".join(hdr))
    for r in rows:
        if args.mesh == "single" and r["multi_pod"]:
            continue
        if args.mesh == "multi" and not r["multi_pod"]:
            continue
        rl = r["roofline"]
        mem = r.get("memory", {})
        hbm = mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0) + \
            mem.get("output_bytes", 0) - mem.get("alias_bytes", 0)
        cells = [r["arch"], r["shape"],
                 "2x16x16" if r["multi_pod"] else "16x16",
                 r.get("mode", "default"),
                 f"{rl['compute_s']:.3e}", f"{rl['memory_s']:.3e}",
                 f"{rl['collective_s']:.3e}", rl["dominant"],
                 fmt_bytes(hbm), f"{rl['useful_compute_ratio']:.3f}",
                 str(r.get("compile_s", ""))]
        if args.md:
            print("| " + " | ".join(cells) + " |")
        else:
            print(",".join(cells))

    print()
    print(f"# combos: {len(rows)} ok, {len(skips)} skipped, "
          f"{len(fails)} failed")
    for s in skips:
        print(f"# skip {s['arch']} x {s['shape']}: {s['skipped'][:80]}")
    for s in fails:
        print(f"# FAIL {s['arch']} x {s['shape']} "
              f"(multi={s['multi_pod']}): {s['error'][:120]}")

    # bottleneck census
    from collections import Counter
    doms = Counter((r["shape"], r["roofline"]["dominant"]) for r in rows
                   if not r["multi_pod"])
    print("# dominant-term census (single-pod):")
    for (shape, dom), cnt in sorted(doms.items()):
        print(f"#   {shape:12s} {dom:10s} x{cnt}")


if __name__ == "__main__":
    main()
