"""Benchmark harness — one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only table1,table5] [--list]
  PYTHONPATH=src python -m benchmarks.run --tree [--smoke-floor 1.8]
  PYTHONPATH=src python -m benchmarks.run --tree --temperature 0.8 \
      [--smoke-floor 1.3]
  PYTHONPATH=src python -m benchmarks.run --scenario sched \
      [--prefix-share 8] [--smoke-floor 0.5]

Prints ``name,us_per_call,derived`` CSV. Requires the trained artifacts
(``python examples/pard_adaptation_train.py``); without them it falls back
to random weights and WARNS (timings still valid, acceptance meaningless —
except the serve_tree table, which self-drafts and stays meaningful).

``--tree`` runs the tree-drafting serve benchmark (serve_tree);
``--temperature`` > 0 switches it to sampled (multi-round rejection
sampling) acceptance, recorded under BENCH_serve.json's "tree_sampled"
section. ``--scenario sched`` runs the layered-scheduler benchmark
(serve_sched: shared-prefix workload, ``--prefix-share`` requests per
system prompt, TTFT/per-token latency + prefix hit rate recorded under
"serve_sched"). ``--pipelined`` runs the overlap-pipeline benchmark
(serve_pipelined: sync vs depth-2 loops, byte-identity asserted, tok/s +
steps/sec + host-overhead recorded under "serve_pipelined").
``--smoke-floor`` turns the run into the CI regression gate: it exits
non-zero with a one-line diagnostic naming the failing mode/metric unless
every PARD mean accepted length recorded in the section that this run
wrote ("tree"/"tree_sampled"/...) stays at or above the floor — for
``--scenario sched`` the floor applies to the cached prefix hit rate
instead (TTFT must have been recorded), and for ``--pipelined`` it
applies to the tree-pipelined / flat-synchronous tokens/sec ratio
(normally 1.0: the ROADMAP gate that tree WINS throughput once host
overhead is hidden), and for ``--kv-quant`` it applies to the int8
pool-byte reduction vs fp32 (normally 2.0) with a fixed secondary
0.95x fp32 tokens/sec floor ("kv_quant" section, quant-gate job), and
for ``--scenario sharded`` it applies to the tp=4 per-chip scaling
efficiency (loose on CPU-emulated collectives; token identity across
mesh shapes is always required — "serve_sharded" section, shard-gate
job; run under XLA_FLAGS=--xla_force_host_platform_device_count=4),
and for ``--scenario dp`` it applies to the dp=2 / dp=1 aggregate
tokens/sec ratio (loose on single-core hosts where the replicas'
device work serializes — the >= 1.5x production expectation presumes
parallel-capable runners; token-set identity between dp=2 and dp=1 is
always required — "serve_dp" section, dp-gate job; run under
XLA_FLAGS=--xla_force_host_platform_device_count=4).

The roofline/dry-run numbers (deliverable e/g) are produced separately by
``python -m repro.launch.dryrun --all --both-meshes`` and summarised with
``python -m benchmarks.roofline_report``.
"""
import argparse
import json
import sys
import time


# the secondary kv_quant gate ratio: int8 tokens/sec must stay within 5%
# of fp32 (the primary --smoke-floor applies to the byte-reduction ratio)
KV_QUANT_TPS_FLOOR = 0.95

# fixed secondary gates for --scenario sharded (the comm-audit gates,
# DESIGN.md §13; the primary --smoke-floor stays on scaling efficiency):
# on tp4 the throughput ruleset must cut per-step collective bytes >= 2x
# vs exact, bound all-reduces at <= 2 per layer, match tp1 greedy tokens
# at >= 0.99 exact-match rate, and hold mean_accepted within 2% of exact
COMM_BYTES_RATIO_FLOOR = 2.0
COMM_ALL_REDUCES_PER_LAYER_MAX = 2.0
THROUGHPUT_EXACT_MATCH_FLOOR = 0.99
THROUGHPUT_MEAN_ACCEPTED_TOL = 0.02

# fixed secondary gate for --adaptive-tree: the vectorized controller host
# path must keep adaptive tok/s >= 0.95x the static baseline at >= its
# acceptance (the primary --smoke-floor stays on mean accepted length)
ADAPTIVE_TPS_FLOOR = 0.95


def check_floor(floor: float, section: str = "tree") -> int:
    """CI gate: every recorded PARD mean accepted length in ``section``
    must be >= floor — except ``serve_sched``, where the floor applies to
    the cached prefix hit rate and TTFT must have been recorded. Prints one
    diagnostic line per entry naming the mode and metric; returns a
    process exit code."""
    from . import common

    with open(common.BENCH_SERVE) as f:
        record = json.load(f)
    tree = record.get(section)
    if not tree:
        flag = {"tree": "--tree", "tree_sampled": "--tree --temperature 0.8",
                "tree_adaptive": "--adaptive-tree",
                "serve_sched": "--scenario sched",
                "serve_pipelined": "--pipelined",
                "kv_quant": "--kv-quant",
                "serve_sharded": "--scenario sharded",
                "serve_dp": "--scenario dp"}.get(section, "--tree")
        print(f"smoke-floor: no '{section}' section in {common.BENCH_SERVE}"
              f" — run with {flag}", file=sys.stderr)
        return 2
    failed = False
    if section == "serve_pipelined":
        # the ROADMAP gate: tree-mode pipelined tokens/sec must clear the
        # flat-K synchronous baseline (ratio >= floor, normally 1.0), and
        # byte-identity must have been asserted by the benchmark run
        gate = tree.get("gate", {})
        ratio = gate.get("tree_pipelined_vs_flat_sync")
        ok = ratio is not None and ratio >= floor
        failed |= not ok
        print(f"smoke-floor: serve_pipelined tree-pipelined/flat-sync tok/s"
              f"={ratio if ratio is None else f'{ratio:.3f}'} "
              f"{'>=' if ok else '< FAIL'} {floor} "
              f"(tree_pipelined={gate.get('tree_pipelined_tps')} "
              f"flat_sync={gate.get('flat_sync_tps')})", file=sys.stderr)
        for name, entry in sorted(tree.items()):
            if not name.endswith(".pipelined"):
                continue
            ok = entry.get("token_identical_to_sync") is True
            failed |= not ok
            print(f"smoke-floor: serve_pipelined.{name} "
                  f"token_identical_to_sync="
                  f"{entry.get('token_identical_to_sync')} "
                  f"{'ok' if ok else 'MISSING/FAIL'}", file=sys.stderr)
        return 1 if failed else 0
    if section == "kv_quant":
        # the quantized-KV acceptance gate: int8 paged serving must record
        # >= floor x byte reduction vs fp32 (scales included) AND hold
        # >= KV_QUANT_TPS_FLOOR x the fp32 tokens/sec (dequant-in-kernel
        # must not eat the win); every dtype must have recorded a tok/s
        gate = tree.get("gate", {})
        ratio = gate.get("int8_byte_reduction_vs_fp32")
        ok = ratio is not None and ratio >= floor
        failed |= not ok
        print(f"smoke-floor: kv_quant int8 byte reduction vs fp32="
              f"{ratio if ratio is None else f'{ratio:.3f}'}x "
              f"{'>=' if ok else '< FAIL'} {floor}", file=sys.stderr)
        tps = gate.get("int8_vs_fp32_tps")
        ok = tps is not None and tps >= KV_QUANT_TPS_FLOOR
        failed |= not ok
        print(f"smoke-floor: kv_quant int8/fp32 tok/s="
              f"{tps if tps is None else f'{tps:.3f}'} "
              f"{'>=' if ok else '< FAIL'} {KV_QUANT_TPS_FLOOR}",
              file=sys.stderr)
        for name in ("fp32", "int8", "fp8"):
            ok = tree.get(name, {}).get("tokens_per_sec") is not None
            failed |= not ok
            print(f"smoke-floor: kv_quant.{name} tokens_per_sec="
                  f"{tree.get(name, {}).get('tokens_per_sec')} "
                  f"{'recorded' if ok else 'MISSING'}", file=sys.stderr)
        return 1 if failed else 0
    if section == "serve_sharded":
        # the sharded-serving gate: the benchmark must have asserted
        # bitwise token identity across mesh shapes 1/2/4, and the
        # 4-device per-chip throughput must clear the (loose, CPU-emulated
        # collectives) scaling-efficiency floor; every mesh size must have
        # recorded a tok/s
        gate = tree.get("gate", {})
        ok = gate.get("token_identical_across_meshes") is True
        failed |= not ok
        print(f"smoke-floor: serve_sharded token_identical_across_meshes="
              f"{gate.get('token_identical_across_meshes')} "
              f"{'ok' if ok else 'MISSING/FAIL'}", file=sys.stderr)
        eff = gate.get("scaling_efficiency_tp4")
        ok = eff is not None and eff >= floor
        failed |= not ok
        print(f"smoke-floor: serve_sharded tp4 scaling efficiency="
              f"{eff if eff is None else f'{eff:.3f}'} "
              f"{'>=' if ok else '< FAIL'} {floor} "
              f"(tp1={gate.get('tp1_tps')} tp4={gate.get('tp4_tps')} "
              f"tok/s)", file=sys.stderr)
        for name in ("tp1", "tp2", "tp4"):
            ok = tree.get(name, {}).get("tokens_per_sec") is not None
            failed |= not ok
            print(f"smoke-floor: serve_sharded.{name} tokens_per_sec="
                  f"{tree.get(name, {}).get('tokens_per_sec')} "
                  f"{'recorded' if ok else 'MISSING'}", file=sys.stderr)
        # comm-audit gates (DESIGN.md §13): collective-byte accounting of
        # the compiled step is the trustworthy proxy for real-interconnect
        # cost that CPU-emulated wall-clock is not
        ratio = gate.get("comm_bytes_ratio_exact_vs_throughput_tp4")
        ok = ratio is not None and ratio >= COMM_BYTES_RATIO_FLOOR
        failed |= not ok
        print(f"smoke-floor: serve_sharded comm bytes exact/throughput tp4="
              f"{ratio if ratio is None else f'{ratio:.2f}'}x "
              f"{'>=' if ok else '< FAIL'} {COMM_BYTES_RATIO_FLOOR} "
              f"(exact={gate.get('comm_bytes_exact_tp4')} "
              f"throughput={gate.get('comm_bytes_throughput_tp4')} B/step)",
              file=sys.stderr)
        arpl = gate.get("all_reduces_per_layer_throughput_tp4")
        ok = arpl is not None and arpl <= COMM_ALL_REDUCES_PER_LAYER_MAX
        failed |= not ok
        print(f"smoke-floor: serve_sharded throughput all-reduces/layer="
              f"{arpl} {'<=' if ok else '> FAIL'} "
              f"{COMM_ALL_REDUCES_PER_LAYER_MAX}", file=sys.stderr)
        match = gate.get("throughput_tp4_greedy_exact_match_rate")
        ok = match is not None and match >= THROUGHPUT_EXACT_MATCH_FLOOR
        failed |= not ok
        print(f"smoke-floor: serve_sharded throughput tp4 greedy "
              f"exact-match rate vs tp1="
              f"{match if match is None else f'{match:.4f}'} "
              f"{'>=' if ok else '< FAIL'} {THROUGHPUT_EXACT_MATCH_FLOOR}",
              file=sys.stderr)
        drift = gate.get("throughput_mean_accepted_rel_delta")
        ok = drift is not None and abs(drift) <= THROUGHPUT_MEAN_ACCEPTED_TOL
        failed |= not ok
        print(f"smoke-floor: serve_sharded throughput mean_accepted drift="
              f"{drift if drift is None else f'{drift:+.4f}'} "
              f"{'within' if ok else 'OUTSIDE FAIL'} "
              f"+/-{THROUGHPUT_MEAN_ACCEPTED_TOL}", file=sys.stderr)
        return 1 if failed else 0
    if section == "serve_dp":
        # the data-parallel serving gate: the benchmark must have asserted
        # token-SET identity between dp=2 and dp=1 for the same request
        # set, the dp=2/dp=1 aggregate tok/s ratio must clear the (loose,
        # single-core hosts serialize the replicas) floor, the warm
        # cross-replica prefix hit rate must have been recorded, and both
        # dp sizes must have recorded a tok/s
        gate = tree.get("gate", {})
        ok = gate.get("token_set_identical") is True
        failed |= not ok
        print(f"smoke-floor: serve_dp token_set_identical="
              f"{gate.get('token_set_identical')} "
              f"{'ok' if ok else 'MISSING/FAIL'}", file=sys.stderr)
        ratio = gate.get("aggregate_tps_ratio_dp2_vs_dp1")
        ok = ratio is not None and ratio >= floor
        failed |= not ok
        print(f"smoke-floor: serve_dp dp2/dp1 aggregate tok/s="
              f"{ratio if ratio is None else f'{ratio:.3f}'} "
              f"{'>=' if ok else '< FAIL'} {floor} "
              f"(dp1={gate.get('dp1_tps')} dp2={gate.get('dp2_tps')} "
              f"tok/s)", file=sys.stderr)
        hit = gate.get("warm_cross_replica_prefix_hit_rate")
        ok = hit is not None
        failed |= not ok
        print(f"smoke-floor: serve_dp warm_cross_replica_prefix_hit_rate="
              f"{hit} {'recorded' if ok else 'MISSING'}", file=sys.stderr)
        for name in ("dp1", "dp2"):
            ok = tree.get(name, {}).get("tokens_per_sec") is not None
            failed |= not ok
            print(f"smoke-floor: serve_dp.{name} tokens_per_sec="
                  f"{tree.get(name, {}).get('tokens_per_sec')} "
                  f"{'recorded' if ok else 'MISSING'}", file=sys.stderr)
        return 1 if failed else 0
    if section == "serve_sched":
        hit = tree.get("cached", {}).get("prefix_hit_rate")
        ok = hit is not None and hit >= floor
        failed |= not ok
        print(f"smoke-floor: serve_sched.cached prefix_hit_rate="
              f"{hit if hit is None else f'{hit:.3f}'} "
              f"{'>=' if ok else '< FAIL'} {floor}", file=sys.stderr)
        for name, entry in sorted(tree.items()):
            ok = entry.get("ttft_p50_ms") is not None
            failed |= not ok
            print(f"smoke-floor: serve_sched.{name} ttft_p50_ms="
                  f"{entry.get('ttft_p50_ms')} "
                  f"{'recorded' if ok else 'MISSING'}", file=sys.stderr)
        return 1 if failed else 0
    for name, entry in sorted(tree.items()):
        acc = entry.get("mean_accepted")
        if acc is None:
            continue
        ok = acc >= floor
        failed |= not ok
        print(f"smoke-floor: {section}.{name} mean_accepted={acc:.3f} "
              f"{'>=' if ok else '< FAIL'} {floor}", file=sys.stderr)
    if section == "tree_adaptive":
        # secondary gate: the controller's host path must not tax the step
        # loop — adaptive tok/s >= ADAPTIVE_TPS_FLOOR x static at >= its
        # acceptance (the benchmark run asserts acceptance itself)
        gate = tree.get("gate", {})
        ratio = gate.get("adaptive_vs_static_tps")
        ok = ratio is not None and ratio >= ADAPTIVE_TPS_FLOOR
        failed |= not ok
        print(f"smoke-floor: tree_adaptive adaptive/static tok/s="
              f"{ratio if ratio is None else f'{ratio:.3f}'} "
              f"{'>=' if ok else '< FAIL'} {ADAPTIVE_TPS_FLOOR} "
              f"(adaptive={gate.get('adaptive_tps')} "
              f"static={gate.get('static_tps')})", file=sys.stderr)
    return 1 if failed else 0


def bench_env() -> dict:
    """Provenance metadata for the recording environment — written as the
    top-level "env" block of BENCH_serve.json so cross-run trajectory
    comparisons (serve_delta, the CI summaries) are interpretable."""
    import os
    import re
    import subprocess

    import jax
    import jaxlib

    forced = os.environ.get("REPRO_HOST_DEVICES")
    if not forced:
        m = re.search(r"--xla_force_host_platform_device_count=(\d+)",
                      os.environ.get("XLA_FLAGS", ""))
        forced = m.group(1) if m else None
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        ).stdout.strip() or None
    except OSError:
        sha = None
    return {
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "backend": jax.default_backend(),
        "device_kind": jax.devices()[0].device_kind,
        "device_count": jax.device_count(),
        "forced_host_devices": int(forced) if forced else None,
        "git_sha": sha,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. table1,fig6b")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--tree", action="store_true",
                    help="run the tree-drafting serve benchmark (serve_tree)")
    ap.add_argument("--adaptive-tree", action="store_true",
                    help="run the adaptive-template serve benchmark "
                         "(serve_adaptive; records the 'tree_adaptive' "
                         "BENCH_serve section and asserts the controller "
                         "matches the static (2,2,2,1) baseline)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="run the quantized-KV serve benchmark "
                         "(serve_kv_quant: fp32 vs int8 vs fp8 paged "
                         "caches, records the 'kv_quant' BENCH_serve "
                         "section; with --smoke-floor F the CI gate "
                         "requires the int8 byte reduction >= F and "
                         "int8 tok/s >= 0.95x fp32)")
    ap.add_argument("--scenario", default=None,
                    choices=["sched", "serve", "tree", "adaptive",
                             "pipelined", "kv-quant", "sharded", "dp"],
                    help="named serving scenario: 'sched' runs the "
                         "scheduler/prefix-cache benchmark (serve_sched, "
                         "records the 'serve_sched' BENCH_serve section); "
                         "'sharded' runs the tensor-parallel mesh benchmark "
                         "(serve_sharded: submeshes of 1/2/4 forced host "
                         "devices, token identity asserted, per-chip "
                         "scaling recorded under 'serve_sharded'); 'dp' "
                         "runs the data-parallel replica benchmark "
                         "(serve_dp: dp=1 vs dp=2 on 4 forced host "
                         "devices, token-set identity asserted, aggregate "
                         "tok/s ratio + warm cross-replica prefix hit "
                         "rate recorded under 'serve_dp'); "
                         "'serve'/'tree'/'adaptive'/'pipelined' alias the "
                         "other serve tables so CI and local runs share one "
                         "entrypoint")
    ap.add_argument("--pipelined", action="store_true",
                    help="run the overlap-pipelined serve benchmark "
                         "(serve_pipelined: sync vs depth-2 dispatch/"
                         "harvest loops, flat and tree; asserts byte-"
                         "identical output and records the 'serve_"
                         "pipelined' BENCH_serve section; with "
                         "--smoke-floor F the CI gate requires tree-"
                         "pipelined tok/s >= F * flat-sync tok/s)")
    ap.add_argument("--prefix-share", type=int, default=8, metavar="N",
                    help="serve_sched workload mix: requests per distinct "
                         "system prompt (1 = all-unique cold workload)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="serve_tree sampling temperature (0 = greedy; > 0 "
                         "records the 'tree_sampled' BENCH_serve section)")
    ap.add_argument("--smoke-floor", type=float, default=None, metavar="ACC",
                    help="after running, fail unless every PARD mean "
                         "accepted length in the BENCH_serve.json section "
                         "this run wrote is >= ACC (the CI perf gate)")
    args = ap.parse_args()

    from . import common, tables

    if args.list:
        print("\n".join(tables.ALL))
        return
    if not common.has_artifacts():
        print("WARNING: benchmarks/artifacts missing — run "
              "examples/pard_adaptation_train.py first; using random weights",
              file=sys.stderr)

    scenario_table = {"sched": "serve_sched", "serve": "serve",
                      "tree": "serve_tree", "adaptive": "serve_adaptive",
                      "pipelined": "serve_pipelined",
                      "kv-quant": "serve_kv_quant",
                      "sharded": "serve_sharded", "dp": "serve_dp"}
    scoped = args.tree or args.adaptive_tree or args.pipelined \
        or args.kv_quant or args.scenario
    names = args.only.split(",") if args.only else \
        ([] if scoped else list(tables.ALL))
    if args.tree and "serve_tree" not in names:
        names.append("serve_tree")
    if args.adaptive_tree and "serve_adaptive" not in names:
        names.append("serve_adaptive")
    if args.pipelined and "serve_pipelined" not in names:
        names.append("serve_pipelined")
    if args.kv_quant and "serve_kv_quant" not in names:
        names.append("serve_kv_quant")
    if args.scenario and scenario_table[args.scenario] not in names:
        names.append(scenario_table[args.scenario])
    t0 = time.time()
    print("name,us_per_call,derived")
    for name in names:
        try:
            if name == "serve_tree":
                tables.serve_tree(temperature=args.temperature)
            elif name == "serve_sched":
                tables.serve_sched(prefix_share=args.prefix_share)
            else:
                tables.ALL[name]()
        except AssertionError as e:
            if args.smoke_floor is not None:
                # the CI gate wants a one-line diagnostic naming the failing
                # mode/metric, not a bare assert traceback
                print(f"smoke-floor: {name} FAILED: {e}", file=sys.stderr)
                sys.exit(1)
            raise
    print(f"# total wall: {time.time() - t0:.1f}s", file=sys.stderr)
    if names:
        # provenance: stamp the recording environment alongside whatever
        # sections this run (re)wrote
        common.update_bench_serve("env", bench_env())

    if args.smoke_floor is not None:
        if args.scenario == "sched":
            section = "serve_sched"
        elif args.scenario == "sharded":
            section = "serve_sharded"
        elif args.scenario == "dp":
            section = "serve_dp"
        elif args.pipelined or args.scenario == "pipelined":
            section = "serve_pipelined"
        elif args.kv_quant or args.scenario == "kv-quant":
            section = "kv_quant"
        elif args.adaptive_tree:
            section = "tree_adaptive"
        else:
            section = "tree_sampled" if args.temperature > 0 else "tree"
        sys.exit(check_floor(args.smoke_floor, section))


if __name__ == "__main__":
    main()
