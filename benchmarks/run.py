"""Benchmark harness — one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only table1,table5] [--list]
  PYTHONPATH=src python -m benchmarks.run --tree [--smoke-floor 1.8]
  PYTHONPATH=src python -m benchmarks.run --tree --temperature 0.8 \
      [--smoke-floor 1.3]

Prints ``name,us_per_call,derived`` CSV. Requires the trained artifacts
(``python examples/pard_adaptation_train.py``); without them it falls back
to random weights and WARNS (timings still valid, acceptance meaningless —
except the serve_tree table, which self-drafts and stays meaningful).

``--tree`` runs the tree-drafting serve benchmark (serve_tree);
``--temperature`` > 0 switches it to sampled (multi-round rejection
sampling) acceptance, recorded under BENCH_serve.json's "tree_sampled"
section. ``--smoke-floor`` turns the run into the CI regression gate: it
exits non-zero with a one-line diagnostic naming the failing mode/metric
unless every PARD mean accepted length recorded in the section that this
run wrote ("tree" or "tree_sampled") stays at or above the floor.

The roofline/dry-run numbers (deliverable e/g) are produced separately by
``python -m repro.launch.dryrun --all --both-meshes`` and summarised with
``python -m benchmarks.roofline_report``.
"""
import argparse
import json
import sys
import time


def check_floor(floor: float, section: str = "tree") -> int:
    """CI gate: every recorded PARD mean accepted length in ``section``
    must be >= floor. Prints one diagnostic line per entry naming the
    mode and metric; returns a process exit code."""
    from . import common

    with open(common.BENCH_SERVE) as f:
        record = json.load(f)
    tree = record.get(section)
    if not tree:
        flag = {"tree": "--tree", "tree_sampled": "--tree --temperature 0.8",
                "tree_adaptive": "--adaptive-tree"}.get(section, "--tree")
        print(f"smoke-floor: no '{section}' section in {common.BENCH_SERVE}"
              f" — run with {flag}", file=sys.stderr)
        return 2
    failed = False
    for name, entry in sorted(tree.items()):
        acc = entry.get("mean_accepted")
        if acc is None:
            continue
        ok = acc >= floor
        failed |= not ok
        print(f"smoke-floor: {section}.{name} mean_accepted={acc:.3f} "
              f"{'>=' if ok else '< FAIL'} {floor}", file=sys.stderr)
    return 1 if failed else 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. table1,fig6b")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--tree", action="store_true",
                    help="run the tree-drafting serve benchmark (serve_tree)")
    ap.add_argument("--adaptive-tree", action="store_true",
                    help="run the adaptive-template serve benchmark "
                         "(serve_adaptive; records the 'tree_adaptive' "
                         "BENCH_serve section and asserts the controller "
                         "matches the static (2,2,2,1) baseline)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="serve_tree sampling temperature (0 = greedy; > 0 "
                         "records the 'tree_sampled' BENCH_serve section)")
    ap.add_argument("--smoke-floor", type=float, default=None, metavar="ACC",
                    help="after running, fail unless every PARD mean "
                         "accepted length in the BENCH_serve.json section "
                         "this run wrote is >= ACC (the CI perf gate)")
    args = ap.parse_args()

    from . import common, tables

    if args.list:
        print("\n".join(tables.ALL))
        return
    if not common.has_artifacts():
        print("WARNING: benchmarks/artifacts missing — run "
              "examples/pard_adaptation_train.py first; using random weights",
              file=sys.stderr)

    names = args.only.split(",") if args.only else \
        ([] if args.tree or args.adaptive_tree else list(tables.ALL))
    if args.tree and "serve_tree" not in names:
        names.append("serve_tree")
    if args.adaptive_tree and "serve_adaptive" not in names:
        names.append("serve_adaptive")
    t0 = time.time()
    print("name,us_per_call,derived")
    for name in names:
        try:
            if name == "serve_tree":
                tables.serve_tree(temperature=args.temperature)
            else:
                tables.ALL[name]()
        except AssertionError as e:
            if args.smoke_floor is not None:
                # the CI gate wants a one-line diagnostic naming the failing
                # mode/metric, not a bare assert traceback
                print(f"smoke-floor: {name} FAILED: {e}", file=sys.stderr)
                sys.exit(1)
            raise
    print(f"# total wall: {time.time() - t0:.1f}s", file=sys.stderr)

    if args.smoke_floor is not None:
        if args.adaptive_tree:
            section = "tree_adaptive"
        else:
            section = "tree_sampled" if args.temperature > 0 else "tree"
        sys.exit(check_floor(args.smoke_floor, section))


if __name__ == "__main__":
    main()
