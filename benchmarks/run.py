"""Benchmark harness — one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only table1,table5] [--list]

Prints ``name,us_per_call,derived`` CSV. Requires the trained artifacts
(``python examples/pard_adaptation_train.py``); without them it falls back
to random weights and WARNS (timings still valid, acceptance meaningless).

The roofline/dry-run numbers (deliverable e/g) are produced separately by
``python -m repro.launch.dryrun --all --both-meshes`` and summarised with
``python -m benchmarks.roofline_report``.
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. table1,fig6b")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    from . import common, tables

    if args.list:
        print("\n".join(tables.ALL))
        return
    if not common.has_artifacts():
        print("WARNING: benchmarks/artifacts missing — run "
              "examples/pard_adaptation_train.py first; using random weights",
              file=sys.stderr)

    names = args.only.split(",") if args.only else list(tables.ALL)
    t0 = time.time()
    print("name,us_per_call,derived")
    for name in names:
        tables.ALL[name]()
    print(f"# total wall: {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
