"""§Perf hillclimb helper: compare dry-run variant records for one
(arch, shape) pair and print before/after roofline terms.

  PYTHONPATH=src python -m benchmarks.perf_compare \
      --pair command-r-35b:decode_32k --dir benchmarks/results/perf

Reads every JSON whose name starts with the pair tag and tabulates the
three terms + per-committed-token costs (verify steps process K+1 = 9
tokens and commit mean_accepted ≈ (paper) 3.5-5 per iteration; we report
per-PROCESSED-token so the comparison is conservative).
"""
import argparse
import glob
import json
import os

from repro.configs import get_config
from repro.launch import roofline


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", required=True, help="arch:shape")
    ap.add_argument("--dir", default="benchmarks/results/perf")
    args = ap.parse_args()
    arch, shape = args.pair.split(":")

    recs = []
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))) + \
            sorted(glob.glob(os.path.join("benchmarks/results/dryrun",
                                          f"{arch}__{shape}__single*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("arch") == arch and r.get("shape") == shape \
                and not r.get("multi_pod") and "error" not in r \
                and "skipped" not in r:
            recs.append(r)

    print(f"== {arch} x {shape} (single pod) ==")
    hdr = ("variant", "mode", "compute_s", "memory_s", "collective_s",
           "dominant", "tokens", "mem_s/token")
    print(("{:>22s}" * len(hdr)).format(*hdr))
    for r in recs:
        cfg = get_config(r["arch"])
        rl = roofline.roofline_terms(r, cfg, r["shape"])
        toks = rl["tokens"]
        print("{:>22s}{:>22s}{:>22.3e}{:>22.3e}{:>22.3e}{:>22s}{:>22.0f}"
              "{:>22.3e}".format(
                  r.get("variant", "baseline"), r.get("mode", "default"),
                  rl["compute_s"], rl["memory_s"], rl["collective_s"],
                  rl["dominant"], toks, rl["memory_s"] / max(toks, 1)))


if __name__ == "__main__":
    main()
