"""One benchmark per paper table/figure (deliverable d).

Each function measures the real engine/decoder machinery on the trained tiny
model family and prints ``name,us_per_call,derived`` CSV rows. The paper's
corresponding numbers are attached as ``paper=`` fields in the derived
column for side-by-side validation of the ORDERINGS and RATIOS (absolute
TPS is CPU-bound here; see common.py).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.eagle import EagleDecoder
from repro.core.spec_decode import SpecDecoder
from repro.models import forward, init_caches
from repro.serving.engine import Engine

from . import common
from .common import emit, load_eagle, load_model, prompts, timed

MAX_NEW = 48
K = 8


def _tps(dec_fn, prompt, max_new=MAX_NEW):
    out, secs = timed(dec_fn, warmup=1, reps=1)
    toks = max_new * prompt.shape[0]
    return toks / secs, secs


def _ar_eager_tps(params, cfg, prompt, max_new=12):
    """The 'AR' baseline: op-by-op eager execution with a KV cache — the
    analogue of unoptimized HF transformers (the paper's AR row)."""
    with jax.disable_jit():
        b, p = prompt.shape
        caches = init_caches(cfg, b, 256)
        t0 = time.perf_counter()
        logits, caches, _ = forward(params, cfg, prompt, caches=caches,
                                    cache_pos=jnp.zeros((b,), jnp.int32))
        cur = jnp.argmax(logits[:, -1], -1)[:, None]
        for i in range(max_new - 1):
            logits, caches, _ = forward(
                params, cfg, cur.astype(jnp.int32), caches=caches,
                cache_pos=jnp.full((b,), p + i, jnp.int32))
            cur = jnp.argmax(logits[:, -1], -1)[:, None]
        jax.block_until_ready(cur)
        secs = time.perf_counter() - t0
    return max_new * b / secs


def table1() -> List:
    """Table 1: AR vs AR+ vs VSD vs PARD on the target/draft pair."""
    tp, tc = load_model("bench-target")
    dp, dc = load_model("bench-draft")
    pp, _ = load_model("pard_k8_r07", "bench-draft")
    prompt = prompts(4)
    rows = []

    ar_tps = _ar_eager_tps(tp, tc, prompt)
    dec = SpecDecoder(tp, tc, dp, dc, k=K, max_len=512)
    (_, s_arp) = timed(lambda: dec.generate_ar(prompt, MAX_NEW))
    arp_tps = MAX_NEW * 4 / s_arp

    (_, s_vsd) = timed(lambda: dec.generate_spec(prompt, MAX_NEW, mode="vsd"))
    (_, st_vsd) = dec.generate_spec(prompt, MAX_NEW, mode="vsd")
    vsd_tps = MAX_NEW * 4 / s_vsd

    decp = SpecDecoder(tp, tc, pp, dc, k=K, max_len=512)
    (_, s_pard) = timed(lambda: decp.generate_spec(prompt, MAX_NEW,
                                                   mode="pard"))
    (_, st_pard) = decp.generate_spec(prompt, MAX_NEW, mode="pard")
    pard_tps = MAX_NEW * 4 / s_pard

    rows.append(("table1.AR", 1e6 / ar_tps,
                 f"tps={ar_tps:.1f};speedup={ar_tps / arp_tps:.2f}x;paper=0.46x"))
    rows.append(("table1.AR+", 1e6 / arp_tps,
                 f"tps={arp_tps:.1f};speedup=1.00x;paper=1.00x"))
    rows.append(("table1.VSD", 1e6 / vsd_tps,
                 f"tps={vsd_tps:.1f};speedup={vsd_tps / arp_tps:.2f}x;"
                 f"acc={st_vsd.acceptance_rate:.2f};paper=2.31x"))
    rows.append(("table1.PARD", 1e6 / pard_tps,
                 f"tps={pard_tps:.1f};speedup={pard_tps / arp_tps:.2f}x;"
                 f"acc={st_pard.acceptance_rate:.2f};"
                 f"mean_acc={st_pard.mean_accepted:.2f};paper=3.57x"))
    emit(rows, "table1")
    return rows


def table2() -> List:
    """Table 2: target independence — ONE PARD draft accelerates the whole
    family (three target sizes, including draft==target size)."""
    dp, dc = load_model("bench-draft")
    pp, _ = load_model("pard_k8_r07", "bench-draft")
    prompt = prompts(4)
    rows = []
    for tname, paper in [("bench-target", "3.57x"), ("bench-mid", "2.81x"),
                         ("bench-draft", "2.17x")]:
        tp, tc = load_model(tname)
        dec = SpecDecoder(tp, tc, dp, dc, k=K, max_len=512)
        (_, s_arp) = timed(lambda: dec.generate_ar(prompt, MAX_NEW))
        arp = MAX_NEW * 4 / s_arp
        (_, s_vsd) = timed(lambda: dec.generate_spec(prompt, MAX_NEW,
                                                     mode="vsd"))
        decp = SpecDecoder(tp, tc, pp, dc, k=K, max_len=512)
        (_, s_pard) = timed(lambda: decp.generate_spec(prompt, MAX_NEW,
                                                       mode="pard"))
        vsd, pard = MAX_NEW * 4 / s_vsd, MAX_NEW * 4 / s_pard
        rows.append((f"table2.{tname}.VSD", 1e6 / vsd,
                     f"speedup={vsd / arp:.2f}x"))
        rows.append((f"table2.{tname}.PARD", 1e6 / pard,
                     f"speedup={pard / arp:.2f}x;paper={paper}"))
    emit(rows, "table2")
    return rows


def table3() -> List:
    """Table 3: method comparison in the serving framework (vLLM analogue):
    AR vs EAGLE vs VSD vs PARD at batch 1."""
    tp, tc = load_model("bench-target")
    dp, dc = load_model("bench-draft")
    pp, _ = load_model("pard_k8_r07", "bench-draft")
    ep = load_eagle(tc)
    prompt = prompts(1)
    rows = []

    dec = SpecDecoder(tp, tc, dp, dc, k=4, max_len=512)
    (_, s_ar) = timed(lambda: dec.generate_ar(prompt, MAX_NEW))
    ar = MAX_NEW / s_ar

    ed = EagleDecoder(tp, tc, ep, k=4, max_len=512)
    (_, s_eag) = timed(lambda: ed.generate(prompt, MAX_NEW))
    _, st_e = ed.generate(prompt, MAX_NEW)
    (_, s_vsd) = timed(lambda: dec.generate_spec(prompt, MAX_NEW, mode="vsd"))
    decp = SpecDecoder(tp, tc, pp, dc, k=4, max_len=512)
    (_, s_pard) = timed(lambda: decp.generate_spec(prompt, MAX_NEW,
                                                   mode="pard"))
    eag, vsd, pard = (MAX_NEW / s for s in (s_eag, s_vsd, s_pard))
    rows.append(("table3.AR", 1e6 / ar, "speedup=1.00x;paper=1.00x"))
    rows.append(("table3.EAGLE", 1e6 / eag,
                 f"speedup={eag / ar:.2f}x;acc={st_e.acceptance_rate:.2f};"
                 f"paper=1.64x"))
    rows.append(("table3.VSD", 1e6 / vsd,
                 f"speedup={vsd / ar:.2f}x;paper=2.02x"))
    rows.append(("table3.PARD", 1e6 / pard,
                 f"speedup={pard / ar:.2f}x;paper=3.06x"))
    emit(rows, "table3")
    return rows


def table4() -> List:
    """Table 4: batch scaling 1..16 through the batched engine."""
    tp, tc = load_model("bench-target")
    dp, dc = load_model("bench-draft")
    pp, _ = load_model("pard_k8_r07", "bench-draft")
    rows = []
    paper = {1: "3.06x", 2: "2.59x", 4: "2.19x", 8: "1.55x", 16: "1.17x"}
    for bs in (1, 2, 4, 8, 16):
        prompt_np = np.asarray(prompts(bs))
        def run(mode, params, dcfg):
            eng = Engine(tp, tc, params, dcfg, mode=mode, k=4,
                         max_batch=bs, max_len=512)
            for r in range(bs):
                eng.submit(prompt_np[r], MAX_NEW)
            t0 = time.perf_counter()
            comps = eng.run()
            return sum(c.generated for c in comps) / (time.perf_counter() - t0)
        run("ar", dp, dc)                       # warm
        ar = run("ar", dp, dc)
        run("pard", pp, dc)
        pard = run("pard", pp, dc)
        rows.append((f"table4.bs{bs}.PARD", 1e6 / pard,
                     f"speedup={pard / ar:.2f}x;paper={paper[bs]}"))
    emit(rows, "table4")
    return rows


def table5() -> List:
    """Table 5: acceptance rates (1-alpha and 4-alpha) PARD vs EAGLE vs VSD."""
    tp, tc = load_model("bench-target")
    dp, dc = load_model("bench-draft")
    pp, _ = load_model("pard_k8_r07", "bench-draft")
    ep = load_eagle(tc)
    prompt = prompts(4)
    rows = []

    def k_alpha(hist, iters):
        h = np.asarray(hist, np.float64) / max(iters, 1)
        return h[0], float(np.mean(h[:4]))

    ed = EagleDecoder(tp, tc, ep, k=4, max_len=512)
    _, st = ed.generate(prompt, MAX_NEW)
    a1, a4 = k_alpha(st.accept_hist, st.iterations * 4)
    rows.append(("table5.EAGLE", 0.0,
                 f"1-alpha={a1:.2f};4-alpha={a4:.2f};paper=0.82/0.72"))

    decp = SpecDecoder(tp, tc, pp, dc, k=4, max_len=512)
    _, st = decp.generate_spec(prompt, MAX_NEW, mode="pard")
    a1, a4 = k_alpha(st.accept_hist, st.iterations * 4)
    rows.append(("table5.PARD", 0.0,
                 f"1-alpha={a1:.2f};4-alpha={a4:.2f};paper=0.90/0.88"))

    dec = SpecDecoder(tp, tc, dp, dc, k=4, max_len=512)
    _, st = dec.generate_spec(prompt, MAX_NEW, mode="vsd")
    a1, a4 = k_alpha(st.accept_hist, st.iterations * 4)
    rows.append(("table5.VSD", 0.0, f"1-alpha={a1:.2f};4-alpha={a4:.2f}"))
    emit(rows, "table5")
    return rows


def table6() -> List:
    """Table 6: draft-phase memory-bandwidth (analytic, bf16): bytes of
    draft weights streamed per speculative iteration. PARD is constant in k;
    AR drafts scale linearly. Computed for BOTH the tiny pair and the
    paper's actual LLaMA3.2-1B draft (param count from the config)."""
    from repro.configs import get_config
    from repro.launch.steps import param_shapes

    def param_bytes(cfg):
        sds = param_shapes(cfg)
        return sum(np.prod(s.shape) for s in jax.tree.leaves(sds)) * 2  # bf16

    rows = []
    for label, arch in [("bench-draft", "bench-draft"),
                        ("L3.2-1B", "llama3.2-1b")]:
        b = param_bytes(get_config(arch))
        for k in (4, 6, 8):
            vsd_gb = b * k / 1e9
            pard_gb = b / 1e9
            paper = {4: "2.48", 6: "2.48", 8: "2.48"}[k] \
                if label == "L3.2-1B" else "-"
            rows.append((f"table6.{label}.k{k}", 0.0,
                         f"vsd_draft_gb={vsd_gb:.2f};pard_draft_gb={pard_gb:.2f};"
                         f"paper_pard_gb={paper}"))
    emit(rows, "table6")
    return rows


def fig6a() -> List:
    """Fig 6a: COD ablation — training token cost vs final speed/acceptance
    for (r=0.7,rmin=0.2), (r=0.5,rmin=0.1), no-drop."""
    tp, tc = load_model("bench-target")
    _, dc = load_model("bench-draft")
    prompt = prompts(4)
    import json
    import os
    man = json.load(open(os.path.join(common.ART, "manifest.json")))
    rows = []
    for tag in ("pard_k8_r07", "pard_k8_r05", "pard_k8_nodrop"):
        pp, _ = load_model(tag, "bench-draft")
        dec = SpecDecoder(tp, tc, pp, dc, k=K, max_len=512)
        (_, secs) = timed(lambda: dec.generate_spec(prompt, MAX_NEW,
                                                    mode="pard"))
        _, st = dec.generate_spec(prompt, MAX_NEW, mode="pard")
        tokens = man["runs"].get(tag, {}).get("train_tokens", 0)
        rows.append((f"fig6a.{tag}", 1e6 * secs / (MAX_NEW * 4),
                     f"train_tokens={tokens};acc={st.acceptance_rate:.3f};"
                     f"mean_acc={st.mean_accepted:.2f}"))
    emit(rows, "fig6a")
    return rows


def fig6b() -> List:
    """Fig 6b: K_train x K_infer grid — extrapolation via the shared mask
    token (K_infer > K_train must still work)."""
    tp, tc = load_model("bench-target")
    _, dc = load_model("bench-draft")
    prompt = prompts(4)
    rows = []
    for ktr, tag in [(2, "pard_k2_r07"), (4, "pard_k4_r07"),
                     (8, "pard_k8_r07")]:
        pp, _ = load_model(tag, "bench-draft")
        for kinf in (2, 4, 8, 12):
            dec = SpecDecoder(tp, tc, pp, dc, k=kinf, max_len=512)
            (_, secs) = timed(lambda: dec.generate_spec(prompt, MAX_NEW,
                                                        mode="pard"))
            _, st = dec.generate_spec(prompt, MAX_NEW, mode="pard")
            tps = MAX_NEW * 4 / secs
            rows.append((f"fig6b.ktrain{ktr}.kinfer{kinf}", 1e6 / tps,
                         f"tps={tps:.1f};mean_acc={st.mean_accepted:.2f}"))
    emit(rows, "fig6b")
    return rows


def _recording_config(**overrides) -> dict:
    """Provenance stamp for a (re)recorded BENCH_serve section: the live
    EngineConfig defaults the recording ran under (plus any explicit
    overrides). serve_delta.py warns when a section's stamp no longer
    matches the current defaults — a stale recording predating an engine
    behavior change (exactly how the seed 'serve' numbers went stale
    against the PR 6 pipelined/greedy_only step variants)."""
    import dataclasses as _dc

    from repro.serving.config import EngineConfig
    cfg = {f.name: f.default for f in _dc.fields(EngineConfig)
           if f.name in ("kv_dtype", "pipelined", "tp_ruleset")}
    cfg.update(overrides)
    return cfg


def serve() -> List:
    """Serving-engine KV layouts: tokens/sec and cache HBM bytes for
    ar/vsd/pard in both the contiguous and the block-paged layout. Uses the
    tiny family (the point is the LAYOUT ratio — paged bytes track actual
    fill — not absolute CPU throughput) and persists the trajectory to the
    canonical BENCH_serve.json at the repo root (common.update_bench_serve;
    the per-table results/ mirror is intentionally not written)."""
    from repro.serving.config import EngineConfig
    tp, tc = load_model("tiny-target")
    dp, dc = load_model("tiny-draft")
    rng = np.random.default_rng(0)
    reqs = [np.asarray(common.corpus().prompts(rng, 1, int(n_tok))[0])
            for n_tok in rng.integers(8, 24, size=8)]
    max_len, max_new = 1024, 24

    rows, record = [], {"config": _recording_config()}
    for mode in ("ar", "vsd", "pard"):
        for layout in ("contiguous", "paged"):
            eng = Engine(tp, tc, dp, dc, config=EngineConfig(
                mode=mode, k=4, max_batch=2, max_len=max_len,
                kv_layout=layout, kv_block_size=64))
            for r in reqs:                      # warm pass: compile steps
                eng.submit(r, max_new)
            eng.run()
            eng.peak_kv_bytes_in_use = eng.kv_bytes_in_use()
            for r in reqs:
                eng.submit(r, max_new)
            t0 = time.perf_counter()
            comps = eng.run()
            wall = time.perf_counter() - t0
            tps = sum(c.generated for c in comps[len(reqs):]) / wall
            cap = eng.kv_capacity_bytes()
            peak = eng.peak_kv_bytes_in_use
            rows.append((f"serve.{mode}.{layout}", 1e6 / tps,
                         f"tps={tps:.1f};kv_capacity_mb={cap / 1e6:.2f};"
                         f"kv_peak_mb={peak / 1e6:.2f}"))
            record[f"{mode}.{layout}"] = dict(
                tokens_per_sec=round(tps, 2), kv_capacity_bytes=cap,
                kv_peak_bytes_in_use=peak)
    common.update_bench_serve("serve", record)
    emit(rows, "serve", persist=False)
    return rows


# tree templates benchmarked by serve_tree: the degenerate chain (asserted
# token-identical to the flat-K path) and the branching template that the
# CI smoke gate tracks.  PARD self-drafts here (draft == target weights):
# depth 1 always matches and the mask-chain conditioning error grows with
# depth — exactly the regime where top-k branches pay off — so accepted
# lengths are meaningful even without trained artifacts.
TREE_K = 4
TREE_TEMPLATES = {"chain-1x1x1x1": (1, 1, 1, 1), "tree-2x2x2x1": (2, 2, 2, 1)}


def serve_tree(temperature: float = 0.0) -> List:
    """Tree-structured PARD drafting through the serving engine: accepted
    length and tokens/sec per tree template vs the flat-K baseline, paged
    KV. Greedy (temperature 0): the degenerate single-branch template must
    be token-identical to flat-K, and the branching template must achieve
    strictly higher mean accepted length per verify step (both enforced
    here; CI gates the recorded floor via ``benchmarks.run --smoke-floor``).
    Sampled (temperature > 0, recorded under "tree_sampled"): acceptance is
    stochastic multi-round rejection sampling, so the token-identity and
    strict-ordering asserts do not apply — CI gates the recorded sampled
    mean accepted length floor instead (``--temperature 0.8
    --smoke-floor 1.3``; self-drafting keeps depth-1 q == p, so every step
    accepts at least one draft token and healthy runs sit well above)."""
    from repro.core.spec_decode import TreeTemplate
    tp, tc = load_model("tiny-target")
    rng = np.random.default_rng(0)
    reqs = [np.asarray(common.corpus().prompts(rng, 1, int(n_tok))[0])
            for n_tok in rng.integers(8, 24, size=6)]
    max_len, max_new = 512, 32
    sampled = temperature > 0.0
    section = "tree_sampled" if sampled else "tree"
    tag = f"serve_tree[T={temperature}]" if sampled else "serve_tree"

    def run_engine(tree):
        eng = Engine(tp, tc, tp, tc, mode="pard", k=TREE_K, max_batch=2,
                     max_len=max_len, temperature=temperature,
                     kv_layout="paged", kv_block_size=64, tree=tree)
        for r in reqs:                          # warm pass: compile steps
            eng.submit(r, max_new)
        eng.run()
        eng.stats.update(accepted=0, live_steps=0)
        for r in reqs:
            eng.submit(r, max_new)
        t0 = time.perf_counter()
        comps = eng.run()
        wall = time.perf_counter() - t0
        toks = {c.rid: c.tokens for c in comps[len(reqs):]}
        tps = sum(c.generated for c in comps[len(reqs):]) / wall
        return toks, tps, eng.mean_accepted()

    rows, record = [], {}
    flat_toks, flat_tps, flat_acc = run_engine(None)
    rows.append((f"{tag}.flat-k{TREE_K}", 1e6 / flat_tps,
                 f"tps={flat_tps:.1f};mean_accepted={flat_acc:.3f}"))
    record[f"flat-k{TREE_K}"] = dict(tokens_per_sec=round(flat_tps, 2),
                                     mean_accepted=round(flat_acc, 4))
    if sampled:
        record[f"flat-k{TREE_K}"]["temperature"] = temperature
    for name, branching in TREE_TEMPLATES.items():
        toks, tps, acc = run_engine(TreeTemplate.from_branching(branching))
        rows.append((f"{tag}.{name}", 1e6 / tps,
                     f"tps={tps:.1f};mean_accepted={acc:.3f}"))
        record[name] = dict(tokens_per_sec=round(tps, 2),
                            mean_accepted=round(acc, 4),
                            branching=list(branching))
        if sampled:
            record[name]["temperature"] = temperature
        elif all(b == 1 for b in branching):
            # degenerate tree == flat-K, token for token
            same = (set(toks) == set(flat_toks) and
                    all(np.array_equal(toks[r], flat_toks[r]) for r in toks))
            assert same, "degenerate chain diverged from the flat-K path"
            record[name]["token_identical_to_flat"] = True
        else:
            assert acc > flat_acc, (
                f"branching template {branching} did not beat flat-K mean "
                f"accepted length ({acc:.3f} <= {flat_acc:.3f})")
    common.update_bench_serve(section, record)
    emit(rows, "serve_tree", persist=False)
    return rows


def serve_adaptive() -> List:
    """Adaptive per-request tree templates (DESIGN.md §7) vs the static
    (2,2,2,1) template that the CI smoke gate tracks: the same ragged
    self-draft workload through the paged engine, once pinned to the static
    tree and once with the acceptance-statistics controller selecting and
    reshaping per request from the default chain/balanced/wide bank. The
    run is fully deterministic (greedy, fixed seeds), and the controller
    must END UP no worse than the static shape — asserted here, with both
    mean accepted lengths recorded under BENCH_serve.json's
    "tree_adaptive" section so ``benchmarks.run --adaptive-tree
    --smoke-floor`` can gate the absolute level and serve_delta.py reports
    the tokens/sec trend."""
    from repro.core.spec_decode import TemplateBank, TreeTemplate
    tp, tc = load_model("tiny-target")
    rng = np.random.default_rng(0)
    reqs = [np.asarray(common.corpus().prompts(rng, 1, int(n_tok))[0])
            for n_tok in rng.integers(8, 24, size=8)]
    max_len, max_new = 512, 32

    def run_engine(tree, adaptive):
        eng = Engine(tp, tc, tp, tc, mode="pard", k=TREE_K, max_batch=2,
                     max_len=max_len, kv_layout="paged", kv_block_size=64,
                     tree=tree, adaptive_tree=adaptive)
        for r in reqs:                          # warm pass: compile steps
            eng.submit(r, max_new)
        eng.run()
        # every recorded stat must cover the TIMED pass only (the warm
        # pass still seeds the controller's global EWMA, as serving would)
        eng.stats.update(accepted=0, live_steps=0, tree_switches=0,
                         tree_hist=np.zeros_like(eng.stats["tree_hist"]))
        for r in reqs:
            eng.submit(r, max_new)
        t0 = time.perf_counter()
        comps = eng.run()
        wall = time.perf_counter() - t0
        tps = sum(c.generated for c in comps[len(reqs):]) / wall
        return tps, eng.mean_accepted(), eng

    rows, record = [], {"config": _recording_config()}
    s_tps, s_acc, _ = run_engine(
        TreeTemplate.from_branching((2, 2, 2, 1)), False)
    rows.append(("serve_adaptive.static-2x2x2x1", 1e6 / s_tps,
                 f"tps={s_tps:.1f};mean_accepted={s_acc:.3f}"))
    record["static-2x2x2x1"] = dict(tokens_per_sec=round(s_tps, 2),
                                    mean_accepted=round(s_acc, 4))

    bank = TemplateBank.default(TREE_K)
    a_tps, a_acc, eng = run_engine(bank, True)
    hist = [int(h) for h in eng.stats["tree_hist"]]
    rows.append(("serve_adaptive.adaptive", 1e6 / a_tps,
                 f"tps={a_tps:.1f};mean_accepted={a_acc:.3f};"
                 f"switches={eng.stats['tree_switches']};"
                 f"hist={'/'.join(map(str, hist))}"))
    record["adaptive"] = dict(
        tokens_per_sec=round(a_tps, 2), mean_accepted=round(a_acc, 4),
        bank=[list(t.branching) for t in bank.templates],
        live_steps_per_template=hist,
        switches=int(eng.stats["tree_switches"]))
    assert a_acc >= s_acc, (
        f"adaptive tree mean accepted fell below the static (2,2,2,1) "
        f"baseline ({a_acc:.3f} < {s_acc:.3f})")
    # the controller's host path (vectorized EWMA update + cached template
    # scoring) must not tax the step loop: adaptive tok/s stays within 5%
    # of the static baseline at >= its acceptance
    assert a_tps >= 0.95 * s_tps, (
        f"adaptive tree tok/s fell below 0.95x the static baseline "
        f"({a_tps:.1f} < 0.95 * {s_tps:.1f}) — controller host overhead "
        f"is back in the step loop")
    record["gate"] = dict(
        adaptive_vs_static_tps=round(a_tps / s_tps, 4),
        adaptive_tps=round(a_tps, 2), static_tps=round(s_tps, 2))
    common.update_bench_serve("tree_adaptive", record)
    emit(rows, "serve_adaptive", persist=False)
    return rows


def serve_sched(prefix_share: int = 8) -> List:
    """Layered scheduler stack on a shared-prefix workload (DESIGN.md §8):
    ``prefix_share`` requests per distinct 32-token system prompt, each with
    a unique tail, through the paged engine with chunked prefill — once
    cold (prefix_cache=False) and once with the refcounted prefix cache.

    Records tokens/sec, prefix hit rate, TTFT p50/p95 and per-token p50/p95
    latency into BENCH_serve.json's "serve_sched" section (the CI gate
    checks the cached hit rate and that TTFT is reported). Asserts the
    acceptance criteria that are deterministic: cached completions are
    token-identical to cold ones, the steady-state hit rate clears 50%,
    and cached throughput does not regress the no-cache path beyond timer
    noise."""
    tp, tc = load_model("tiny-target")
    dp, dc = load_model("tiny-draft")
    rng = np.random.default_rng(0)
    n_req, sys_len, tail, max_new = 16, 32, 6, 16
    share = max(1, prefix_share)
    n_groups = -(-n_req // share)

    def workload():
        sys_prompts = [np.asarray(common.corpus().prompts(rng, 1,
                                                          sys_len)[0])
                       for _ in range(n_groups)]
        return [np.concatenate([sys_prompts[i % n_groups],
                                rng.integers(0, tc.vocab_size, size=tail)
                                .astype(np.int32)])
                for i in range(n_req)]

    # share > 1: requests rotate through n_groups shared system prompts,
    # identical in both passes (steady-state serving); share == 1: the
    # timed pass gets FRESH prompts, so the cached engine measures a
    # genuinely reuse-free workload — both engines see the same requests
    warm_reqs = workload()
    timed_reqs = warm_reqs if share > 1 else workload()

    def run_engine(cache):
        eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=2,
                     max_len=512, kv_layout="paged", kv_block_size=16,
                     prefix_cache=cache)
        for r in warm_reqs:                     # warm pass: compile + (for
            eng.submit(r, max_new)              # the cached engine) prime
        eng.run()
        reqs = timed_reqs
        first_hit = eng.prefix_hit_rate()
        eng.sched.completions.clear()
        eng.stats.update(accepted=0, live_steps=0, prefill_chunks=0,
                         prefix_lookup_blocks=0, prefix_hit_blocks=0)
        for r in reqs:
            eng.submit(r, max_new)
        t0 = time.perf_counter()
        comps = eng.run()
        wall = time.perf_counter() - t0
        toks = {c.rid: c.tokens for c in comps}
        tps = sum(c.generated for c in comps) / wall
        return dict(tps=tps, toks=toks, first_hit=first_hit,
                    hit=eng.prefix_hit_rate(), lat=eng.latency_summary(),
                    acc=eng.mean_accepted())

    rows, record = [], {}
    res = {False: run_engine(False), True: run_engine(True)}
    for cache, r in res.items():
        name = "cached" if cache else "cold"
        lat = r["lat"]
        rows.append((f"serve_sched.{name}", 1e6 / r["tps"],
                     f"tps={r['tps']:.1f};hit={r['hit']:.2f};"
                     f"ttft_p50_ms={lat['ttft_p50_ms']:.1f};"
                     f"ttft_p95_ms={lat['ttft_p95_ms']:.1f};"
                     f"tok_p50_ms={lat['tok_p50_ms']:.2f}"))
        record[name] = dict(
            tokens_per_sec=round(r["tps"], 2),
            prefix_hit_rate=round(r["hit"], 4),
            first_pass_hit_rate=round(r["first_hit"], 4),
            prefix_share=prefix_share,
            mean_accepted=round(r["acc"], 4),
            ttft_p50_ms=round(lat["ttft_p50_ms"], 3),
            ttft_p95_ms=round(lat["ttft_p95_ms"], 3),
            tok_p50_ms=round(lat["tok_p50_ms"], 4),
            tok_p95_ms=round(lat["tok_p95_ms"], 4),
            queue_wait_p50_ms=round(lat["queue_wait_p50_ms"], 3))
    cold, cached = res[False], res[True]
    # deterministic greedy: the cache must be invisible in the tokens
    same = (set(cold["toks"]) == set(cached["toks"]) and
            all(np.array_equal(cold["toks"][r], cached["toks"][r])
                for r in cold["toks"]))
    assert same, "prefix-cached completions diverged from the cold path"
    record["cached"]["token_identical_to_cold"] = True
    if prefix_share > 1:
        assert cached["hit"] >= 0.5, (
            f"shared-prefix workload hit rate {cached['hit']:.2f} < 0.5")
        assert cached["tps"] >= 0.9 * cold["tps"], (
            f"prefix cache slowed serving: {cached['tps']:.1f} vs "
            f"{cold['tps']:.1f} tok/s")
    common.update_bench_serve("serve_sched", record)
    emit(rows, "serve_sched", persist=False)
    return rows


def serve_pipelined() -> List:
    """Overlap-pipelined serve loop (DESIGN.md §9): the same ragged
    self-draft workload through the paged engine, flat-K and the static
    (1,2) tree — the template the sweep picked for raw tok/s: its 4-wide
    draft/verify windows undercut flat's 8/5-wide ones at near-equal
    acceptance — each driven synchronously (depth-1) and pipelined
    (depth-2 dispatch/harvest overlap, donated buffers, one batched
    transfer per step). Asserts — in the benchmark itself, per the
    acceptance criteria — that pipelined completions are byte-identical
    to the synchronous ones for BOTH drafting shapes, and records tok/s,
    steps/sec and host-overhead p50/p95 per config under
    BENCH_serve.json's "serve_pipelined" section. The ROADMAP gate (tree
    beats flat-K in tokens/sec once host overhead is hidden) is encoded
    as the recorded ``gate.tree_pipelined_vs_flat_sync`` ratio, floored
    by ``benchmarks.run --pipelined --smoke-floor 1.0`` in CI."""
    from repro.core.spec_decode import TreeTemplate
    tp, tc = load_model("tiny-target")
    rng = np.random.default_rng(0)
    reqs = [np.asarray(common.corpus().prompts(rng, 1, int(n_tok))[0])
            for n_tok in rng.integers(8, 24, size=6)]
    max_len, max_new, reps = 512, 96, 3

    def run_engine(tree, pipelined):
        eng = Engine(tp, tc, tp, tc, mode="pard", k=TREE_K, max_batch=2,
                     max_len=max_len, kv_layout="paged", kv_block_size=64,
                     tree=tree)
        for r in reqs:                          # warm pass: compile steps
            eng.submit(r, max_new)
        eng.run(pipelined=pipelined)
        # median-of-reps timing: single passes on a busy CI box are too
        # noisy for a >= 1.0 ratio gate between near-equal configs
        tps_reps, sps_reps = [], []
        toks = None
        for _ in range(reps):
            eng.stats.update(accepted=0, live_steps=0)
            eng.sched.host_overhead_ms.clear()  # timed-pass overhead only
            steps0 = eng.stats["steps"]
            for r in reqs:
                eng.submit(r, max_new)
            t0 = time.perf_counter()
            comps = eng.run(pipelined=pipelined)
            wall = time.perf_counter() - t0
            toks = {c.rid: c.tokens for c in comps[-len(reqs):]}
            tps_reps.append(
                sum(c.generated for c in comps[-len(reqs):]) / wall)
            sps_reps.append((eng.stats["steps"] - steps0) / wall)
        lat = eng.latency_summary()
        return dict(toks=toks, tps=float(np.median(tps_reps)),
                    sps=float(np.median(sps_reps)), acc=eng.mean_accepted(),
                    oh50=lat["host_overhead_p50_ms"],
                    oh95=lat["host_overhead_p95_ms"])

    rows, record = [], {}
    res = {}
    for shape, tree in (("flat", None),
                        ("tree-1x2",
                         TreeTemplate.from_branching((1, 2)))):
        for pipelined in (False, True):
            loop = "pipelined" if pipelined else "sync"
            r = res[shape, pipelined] = run_engine(tree, pipelined)
            rows.append((
                f"serve_pipelined.{shape}.{loop}", 1e6 / r["tps"],
                f"tps={r['tps']:.1f};steps_per_sec={r['sps']:.1f};"
                f"host_oh_p50_ms={r['oh50']:.2f};"
                f"host_oh_p95_ms={r['oh95']:.2f}"))
            record[f"{shape}.{loop}"] = dict(
                tokens_per_sec=round(r["tps"], 2),
                steps_per_sec=round(r["sps"], 2),
                mean_accepted=round(r["acc"], 4),
                host_overhead_p50_ms=round(r["oh50"], 3),
                host_overhead_p95_ms=round(r["oh95"], 3))
        # greedy determinism: the pipeline must be invisible in the tokens
        sync_t, pipe_t = res[shape, False]["toks"], res[shape, True]["toks"]
        same = (set(sync_t) == set(pipe_t) and
                all(np.array_equal(sync_t[r], pipe_t[r]) for r in sync_t))
        assert same, (f"{shape}: pipelined completions diverged from the "
                      f"synchronous loop")
        record[f"{shape}.pipelined"]["token_identical_to_sync"] = True
    ratio = res["tree-1x2", True]["tps"] / res["flat", False]["tps"]
    record["gate"] = dict(
        tree_pipelined_vs_flat_sync=round(ratio, 4),
        tree_pipelined_tps=round(res["tree-1x2", True]["tps"], 2),
        flat_sync_tps=round(res["flat", False]["tps"], 2))
    common.update_bench_serve("serve_pipelined", record)
    emit(rows, "serve_pipelined", persist=False)
    return rows


def serve_kv_quant() -> List:
    """Quantized paged KV (DESIGN.md §10): the same ragged PARD workload
    through the paged engine under the fp32 reference cache and the int8 /
    fp8 quantized caches (per-(position, head) scales stored beside the
    pool, dequantized inside the streaming kernels). Records tokens/sec,
    pool capacity bytes (scales included — the ratio is the honest one) and
    mean accepted length per dtype under BENCH_serve.json's "kv_quant"
    section, plus a ``gate`` entry with the two CI ratios:

      * ``int8_byte_reduction_vs_fp32`` — pool bytes fp32/int8, floored at
        2.0 by ``benchmarks.run --kv-quant --smoke-floor 2.0`` (the
        acceptance criterion; measured ~3.5x: 4-byte values -> 1-byte
        values + one f32 scale per 128-value (block, head) row);
      * ``int8_vs_fp32_tps`` — int8/fp32 tokens/sec, floored at 0.95 (the
        dequant-in-kernel overhead must not eat the win).

    Greedy int8 decoding is self-consistent (spec == AR within the dtype,
    asserted by tests/test_kv_quant.py); here the benchmark additionally
    asserts the int8 run commits full-length completions for every
    request, so a quantization bug that stalls acceptance cannot record a
    plausible-looking tok/s."""
    tp, tc = load_model("tiny-target")
    dp, dc = load_model("tiny-draft")
    rng = np.random.default_rng(0)
    reqs = [np.asarray(common.corpus().prompts(rng, 1, int(n_tok))[0])
            for n_tok in rng.integers(8, 24, size=6)]
    max_len, max_new, reps = 512, 48, 3

    rows, record = [], {}
    for dtype in ("fp32", "int8", "fp8"):
        eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=2,
                     max_len=max_len, kv_layout="paged", kv_block_size=64,
                     kv_dtype=dtype)
        for r in reqs:                          # warm pass: compile steps
            eng.submit(r, max_new)
        eng.run()
        eng.peak_kv_bytes_in_use = eng.kv_bytes_in_use()
        # median-of-reps timing: the >= 0.95 tok/s ratio gate compares
        # near-equal configs, too tight for single passes on a busy CI box
        tps_reps, comps = [], []
        for _ in range(reps):
            eng.stats.update(accepted=0, live_steps=0)
            for r in reqs:
                eng.submit(r, max_new)
            t0 = time.perf_counter()
            comps = eng.run()
            wall = time.perf_counter() - t0
            tps_reps.append(
                sum(c.generated for c in comps[-len(reqs):]) / wall)
        tps = float(np.median(tps_reps))
        cap = eng.kv_capacity_bytes()
        peak = eng.peak_kv_bytes_in_use
        acc = eng.mean_accepted()
        assert all(c.generated == max_new for c in comps[-len(reqs):]), \
            f"{dtype}: short completions — acceptance stalled"
        rows.append((f"serve_kv_quant.{dtype}", 1e6 / tps,
                     f"tps={tps:.1f};kv_capacity_mb={cap / 1e6:.2f};"
                     f"kv_peak_mb={peak / 1e6:.2f};mean_acc={acc:.2f}"))
        record[dtype] = dict(
            tokens_per_sec=round(tps, 2), kv_capacity_bytes=cap,
            kv_peak_bytes_in_use=peak, mean_accepted=round(acc, 4))
    record["gate"] = dict(
        int8_byte_reduction_vs_fp32=round(
            record["fp32"]["kv_capacity_bytes"]
            / record["int8"]["kv_capacity_bytes"], 4),
        fp8_byte_reduction_vs_fp32=round(
            record["fp32"]["kv_capacity_bytes"]
            / record["fp8"]["kv_capacity_bytes"], 4),
        int8_vs_fp32_tps=round(
            record["int8"]["tokens_per_sec"]
            / record["fp32"]["tokens_per_sec"], 4))
    common.update_bench_serve("kv_quant", record)
    emit(rows, "serve_kv_quant", persist=False)
    return rows


def serve_sharded() -> List:
    """Tensor-parallel serving on a host device mesh (DESIGN.md §11): the
    same ragged mixed greedy + seeded-sampled PARD workload through the
    paged engine on ("data", "model") submeshes of 1, 2 and 4 forced host
    devices (run under XLA_FLAGS=--xla_force_host_platform_device_count=4;
    ``ensure_host_devices`` raises if the backend came up short). The
    serving ruleset shards only projection OUTPUT dims and all-gathers
    activations before every contraction, so the benchmark asserts — per
    the acceptance criteria — that completions are bitwise-identical
    across all three mesh shapes, then records tokens/sec, tokens/sec per
    chip and the scaling efficiency (per-chip throughput relative to the
    1-device mesh) under BENCH_serve.json's "serve_sharded" section. On
    the forced-CPU mesh the collectives are emulated through host memory,
    so efficiency is a smoke floor (``--scenario sharded --smoke-floor``),
    not a hardware claim — the honest per-chip numbers come from a real
    multi-chip mesh.

    The THROUGHPUT ruleset (DESIGN.md §13) then reruns tp1/tp2/tp4 with
    row-parallel down-projections at canonical-chunk granularity. Its
    measurable gate is not wall-clock but the collective-accounting audit
    (tools/comm_audit.py): the gate-bearing numbers come from
    ``audit_forward`` (params as explicit sharded jit arguments, scan-body
    collectives scaled by trip count — the per-step bill a deployment with
    resident sharded weights pays), with the fused-step ``audit_engine``
    recorded alongside as a diagnostic (closure-constant params let XLA
    fold exact's gathers there). The gate block carries the
    exact/throughput forward byte ratio, the throughput
    all-reduces-per-layer bound, the greedy exact-match rate of
    throughput-tp4 vs the throughput-tp1 reference (the canonical-chunk
    numerics make every mesh size round the same f32 sum once, so this is
    1.0 in practice; vs the EXACT ruleset the throughput numerics differ
    by design and only the mean-accepted drift is bounded), all enforced
    by ``benchmarks.run --scenario sharded --smoke-floor`` in the
    shard-gate CI job."""
    from repro.launch import mesh as mesh_mod
    from repro.serving.config import EngineConfig, SamplingParams
    from tools import comm_audit

    mesh_mod.ensure_host_devices(4)
    tgt, tc = load_model("tiny-target")
    dp, dc = load_model("tiny-draft")
    rng = np.random.default_rng(0)
    reqs = [np.asarray(common.corpus().prompts(rng, 1, int(n_tok))[0])
            for n_tok in rng.integers(8, 24, size=6)]
    max_len, max_new, reps = 512, 48, 3

    def run_engine(n, ruleset="exact", audit=False):
        cfg = EngineConfig(mode="pard", k=4, max_batch=2, max_len=max_len,
                           kv_layout="paged", kv_block_size=64, seed=3,
                           mesh=mesh_mod.make_host_mesh(model=n, data=1),
                           tp_ruleset=ruleset)
        eng = Engine(tgt, tc, dp, dc, config=cfg)

        def submit_all():
            # mixed batch: even requests greedy, odd ones sampled with
            # per-request pinned seeds (identity must hold for both paths)
            ids = set()
            for i, r in enumerate(reqs):
                rid = eng.submit(r, params=SamplingParams(
                    max_new=max_new,
                    temperature=0.0 if i % 2 == 0 else 0.8,
                    seed=None if i % 2 == 0 else 100 + i))
                if i % 2 == 0:
                    ids.add(rid)
            return ids

        submit_all()                            # warm pass: compile steps
        eng.run()
        tps_reps, toks, greedy = [], None, set()
        for _ in range(reps):
            eng.stats.update(accepted=0, live_steps=0)
            greedy = submit_all()
            t0 = time.perf_counter()
            comps = eng.run()
            wall = time.perf_counter() - t0
            toks = {c.rid: c.tokens for c in comps[-len(reqs):]}
            tps_reps.append(
                sum(c.generated for c in comps[-len(reqs):]) / wall)
        out = dict(toks=toks, tps=float(np.median(tps_reps)),
                   acc=eng.mean_accepted(), greedy=greedy)
        if audit:
            out["comm"] = comm_audit.audit_engine(eng)
        return out

    def greedy_match_rate(base, other):
        """Position-wise token agreement over the GREEDY completions of the
        final timed pass (rids align: identical submission sequences)."""
        match = total = 0
        for rid in sorted(other["greedy"]):
            a = np.asarray(base["toks"][rid])
            b = np.asarray(other["toks"][rid])
            m = min(len(a), len(b))
            match += int(np.sum(a[:m] == b[:m]))
            total += max(len(a), len(b))
        return match / max(1, total)

    rows, record, res = [], {"config": _recording_config()}, {}
    for n in (1, 2, 4):
        r = res[n] = run_engine(n, audit=(n == 4))
        eff = (r["tps"] / n) / res[1]["tps"]
        rows.append((f"serve_sharded.tp{n}", 1e6 / r["tps"],
                     f"tps={r['tps']:.1f};tps_per_chip={r['tps'] / n:.1f};"
                     f"scaling_eff={eff:.3f};mean_acc={r['acc']:.2f}"))
        record[f"tp{n}"] = dict(
            tokens_per_sec=round(r["tps"], 2),
            tokens_per_sec_per_chip=round(r["tps"] / n, 2),
            scaling_efficiency=round(eff, 4),
            mean_accepted=round(r["acc"], 4))
        if n > 1:
            base = res[1]["toks"]
            same = (set(base) == set(r["toks"]) and
                    all(np.array_equal(base[rid], r["toks"][rid])
                        for rid in base))
            assert same, (f"tp={n}: completions diverged from the 1-device "
                          f"mesh — sharding leaked into the tokens")
            record[f"tp{n}"]["token_identical_to_tp1"] = True

    thr = {n: run_engine(n, ruleset="throughput", audit=(n == 4))
           for n in (1, 2, 4)}
    for n, r in thr.items():
        m = greedy_match_rate(thr[1], r)          # vs the thr-tp1 reference
        m_exact = greedy_match_rate(res[1], r)    # vs exact-tp1 (diagnostic)
        eff = (r["tps"] / n) / res[1]["tps"]
        rows.append((f"serve_sharded.tp{n}.throughput", 1e6 / r["tps"],
                     f"tps={r['tps']:.1f};scaling_eff={eff:.3f};"
                     f"greedy_match={m:.4f};mean_acc={r['acc']:.2f}"))
        record[f"tp{n}.throughput"] = dict(
            tokens_per_sec=round(r["tps"], 2),
            tokens_per_sec_per_chip=round(r["tps"] / n, 2),
            scaling_efficiency=round(eff, 4),
            mean_accepted=round(r["acc"], 4),
            greedy_exact_match_rate_vs_throughput_tp1=round(m, 4),
            greedy_exact_match_rate_vs_exact_tp1=round(m_exact, 4))

    # gate-bearing forward audits (params as explicit sharded arguments,
    # scan trip count applied) + the fused-step audits as diagnostics
    mesh4 = mesh_mod.make_host_mesh(model=4, data=1)
    fwd = {rs: comm_audit.audit_forward(tgt, tc, mesh4, rs)
           for rs in ("exact", "throughput")}
    record["comm_audit"] = {
        "forward_exact_tp4": fwd["exact"],
        "forward_throughput_tp4": fwd["throughput"],
        "fused_step_exact_tp4": {k: res[4]["comm"][k]
                                 for k in ("counts", "bytes", "total_count",
                                           "total_bytes", "n_layers",
                                           "all_reduces_per_layer")},
        "fused_step_throughput_tp4": {
            k: thr[4]["comm"][k]
            for k in ("counts", "bytes", "total_count", "total_bytes",
                      "n_layers", "all_reduces_per_layer")},
    }
    exact_b = fwd["exact"]["total_bytes"]
    thr_b = fwd["throughput"]["total_bytes"]
    record["gate"] = dict(
        token_identical_across_meshes=True,
        scaling_efficiency_tp4=record["tp4"]["scaling_efficiency"],
        tp1_tps=record["tp1"]["tokens_per_sec"],
        tp4_tps=record["tp4"]["tokens_per_sec"],
        comm_bytes_exact_tp4=exact_b,
        comm_bytes_throughput_tp4=thr_b,
        comm_bytes_ratio_exact_vs_throughput_tp4=round(
            exact_b / max(1, thr_b), 4),
        all_reduces_per_layer_throughput_tp4=fwd["throughput"][
            "all_reduces_per_layer"],
        throughput_tp4_tps=round(thr[4]["tps"], 2),
        throughput_tp4_greedy_exact_match_rate=round(
            greedy_match_rate(thr[1], thr[4]), 4),
        throughput_mean_accepted_rel_delta=round(
            (thr[4]["acc"] - res[4]["acc"]) / res[4]["acc"], 4))
    common.update_bench_serve("serve_sharded", record)
    emit(rows, "serve_sharded", persist=False)
    return rows


def serve_dp() -> List:
    """Data-parallel engine replicas behind one scheduler (DESIGN.md §12):
    the same saturated shared-prefix mixed greedy + seeded-sampled PARD
    workload through dp=1 and dp=2 paged prefix-cached engines on 4 forced
    host devices. Asserts — per the acceptance criteria — that dp=2
    commits the IDENTICAL token set as dp=1 for the same request set
    (routing can never change tokens: greedy decoding is deterministic
    and sampled rows derive their PRNG streams from (seed, rid),
    independent of replica/slot/batch composition), then records
    aggregate tokens/sec for both, their ratio, and the warm
    cross-replica prefix hit rate under BENCH_serve.json's "serve_dp"
    section. On a single-core CPU host the two replicas' device work
    serializes, so the dp-gate's throughput floor is deliberately loose
    (like shard-gate's) — the >= 1.5x aggregate-throughput expectation is
    a statement about parallel-capable runners / real accelerators, and
    the measured ratio is recorded honestly either way; the token-set
    identity half of the gate is exact everywhere."""
    from repro.launch import mesh as mesh_mod
    from repro.serving.config import EngineConfig, SamplingParams

    mesh_mod.ensure_host_devices(4)
    tgt, tc = load_model("tiny-target")
    dpar, dc = load_model("tiny-draft")
    rng = np.random.default_rng(0)
    # saturated queue: 12 requests through 2 slots per replica, 3 distinct
    # 64-token system prompts (each exactly one KV block) with unique
    # 8-token tails — the warm pass seeds each prefix into some replica's
    # pool, the timed passes route same-prefix requests back to its owner
    sys_p = [np.asarray(common.corpus().prompts(rng, 1, 64)[0], np.int32)
             for _ in range(3)]
    reqs = [np.concatenate([
        sys_p[i % 3],
        np.asarray(common.corpus().prompts(rng, 1, 8)[0], np.int32)])
        for i in range(12)]
    max_new, reps = 32, 3

    def run_engine(n):
        cfg = EngineConfig(mode="pard", k=4, max_batch=2, max_len=512,
                           kv_layout="paged", kv_block_size=64, seed=3,
                           prefix_cache=True, pipelined=True, dp=n)
        eng = Engine(tgt, tc, dpar, dc, config=cfg)

        def submit_all():
            # mixed batch: even requests greedy, odd ones sampled with
            # per-request pinned seeds (identity must hold for both paths)
            for i, r in enumerate(reqs):
                eng.submit(r, params=SamplingParams(
                    max_new=max_new,
                    temperature=0.0 if i % 2 == 0 else 0.8,
                    seed=None if i % 2 == 0 else 100 + i))

        submit_all()        # warm pass: compile steps + seed the prefixes
        eng.run()
        eng.stats.update(accepted=0, live_steps=0, affinity_routed=0,
                         prefix_lookup_blocks=0, prefix_hit_blocks=0)
        tps_reps, toks = [], None
        for _ in range(reps):
            submit_all()
            t0 = time.perf_counter()
            comps = eng.run()
            wall = time.perf_counter() - t0
            toks = {c.rid: c.tokens for c in comps[-len(reqs):]}
            tps_reps.append(
                sum(c.generated for c in comps[-len(reqs):]) / wall)
        return dict(toks=toks, tps=float(np.median(tps_reps)),
                    acc=eng.mean_accepted(), hit=eng.prefix_hit_rate(),
                    affinity=int(eng.stats["affinity_routed"]),
                    rep_steps=[int(s)
                               for s in eng.stats["replica_steps"]])

    res = {n: run_engine(n) for n in (1, 2)}
    base, other = res[1]["toks"], res[2]["toks"]
    same = (set(base) == set(other) and
            all(np.array_equal(base[rid], other[rid]) for rid in base))
    assert same, ("dp=2 completions diverged from dp=1 — replica routing "
                  "leaked into the tokens")
    ratio = res[2]["tps"] / res[1]["tps"]
    rows, record = [], {}
    for n in (1, 2):
        r = res[n]
        rows.append((f"serve_dp.dp{n}", 1e6 / r["tps"],
                     f"tps={r['tps']:.1f};warm_hit={r['hit']:.3f};"
                     f"mean_acc={r['acc']:.2f}"))
        record[f"dp{n}"] = dict(
            tokens_per_sec=round(r["tps"], 2),
            warm_prefix_hit_rate=round(r["hit"], 4),
            mean_accepted=round(r["acc"], 4),
            affinity_routed=r["affinity"],
            replica_steps=r["rep_steps"])
    record["dp2"]["token_identical_to_dp1"] = True
    record["gate"] = dict(
        token_set_identical=True,
        aggregate_tps_ratio_dp2_vs_dp1=round(ratio, 4),
        warm_cross_replica_prefix_hit_rate=round(res[2]["hit"], 4),
        dp1_tps=record["dp1"]["tokens_per_sec"],
        dp2_tps=record["dp2"]["tokens_per_sec"])
    common.update_bench_serve("serve_dp", record)
    emit(rows, "serve_dp", persist=False)
    return rows


ALL = {"table1": table1, "table2": table2, "table3": table3,
       "table4": table4, "table5": table5, "table6": table6,
       "fig6a": fig6a, "fig6b": fig6b, "serve": serve,
       "serve_tree": serve_tree, "serve_adaptive": serve_adaptive,
       "serve_sched": serve_sched, "serve_pipelined": serve_pipelined,
       "serve_kv_quant": serve_kv_quant, "serve_sharded": serve_sharded,
       "serve_dp": serve_dp}
