"""Tokens/sec delta between two BENCH_serve.json trajectories.

  python -m benchmarks.serve_delta PREVIOUS.json CURRENT.json

Prints a GitHub-flavoured markdown table (one row per section.mode) to
stdout — the bench-smoke CI job appends it to the job summary after
``gh run download``-ing the previous ``bench-serve`` artifact from main.
Only the sections bench-smoke actually regenerates (``benchmarks.run
--tree [--temperature]`` rewrites "tree"/"tree_sampled") are tabulated:
other sections in the file are committed dev-machine numbers, and showing
them here would present a repo-file diff as a CI-measured perf delta.
Tolerates an absent/corrupt previous file (first run on a repo, expired
artifact): prints a note and exits 0 so the job never fails on missing
history. Expected CI sections absent from the CURRENT trajectory are
named in a trailing note (not silently dropped) so a gate job that
failed to persist its section is visible in the summary.

Two provenance layers are understood (and tolerated when absent): the
top-level "env" block ``benchmarks.run`` stamps (jax/backend/device/sha
of the recording machine) is echoed as a footer, and any section carrying
a "config" stamp (``benchmarks.tables._recording_config``) is checked
against the LIVE EngineConfig defaults — a mismatch prints a stale-
recording warning, because numbers recorded under old engine defaults
presented next to current ones is exactly how the seed "serve" section
quietly went misleading.
"""
import json
import sys

# the sections the bench-smoke job re-measures in CI (see ci.yml);
# serve_sched entries additionally carry TTFT/latency fields,
# serve_pipelined ones steps/sec + host-overhead percentiles, and
# kv_quant ones pool capacity bytes + the gate ratios, but only
# tokens/sec is tabulated here (absence-tolerant like the others: a
# previous artifact written before a section existed shows "new")
CI_SECTIONS = ("tree", "tree_sampled", "tree_adaptive", "serve_sched",
               "serve_pipelined", "kv_quant", "serve_sharded", "serve_dp")


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def live_defaults():
    """Current EngineConfig defaults for the keys recordings stamp into
    their "config" block. Imports from src/ next to this file so it works
    without PYTHONPATH; returns None (stale check skipped, delta still
    prints) when the engine code is unimportable."""
    import dataclasses
    import os
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "src"))
    try:
        from repro.serving.config import EngineConfig
    except Exception:                            # noqa: BLE001
        return None
    return {f.name: f.default for f in dataclasses.fields(EngineConfig)
            if f.name in ("kv_dtype", "pipelined", "tp_ruleset")}


def stale_sections(cur):
    """(section, {key: (recorded, live)}) for every section whose recorded
    config stamp disagrees with the live engine defaults."""
    live = live_defaults()
    if live is None:
        return []
    out = []
    for section, entries in sorted(cur.items()):
        recorded = entries.get("config") if isinstance(entries, dict) else None
        if not isinstance(recorded, dict):
            continue                 # unstamped (pre-provenance) section
        diffs = {k: (v, live[k]) for k, v in recorded.items()
                 if k in live and v != live[k]}
        if diffs:
            out.append((section, diffs))
    return out


def main() -> int:
    if len(sys.argv) != 3:
        print("usage: python -m benchmarks.serve_delta PREV.json CUR.json",
              file=sys.stderr)
        return 2
    prev, cur = load(sys.argv[1]), load(sys.argv[2])
    if cur is None:
        print(f"serve-delta: no current trajectory at {sys.argv[2]}",
              file=sys.stderr)
        return 2
    print("### Serving tokens/sec vs previous main artifact\n")
    if prev is None:
        print(f"_no previous `bench-serve` artifact at `{sys.argv[1]}` — "
              f"delta skipped (first run or expired artifact)_")
        return 0
    print("| benchmark | previous tok/s | current tok/s | delta |")
    print("|---|---:|---:|---:|")
    skipped = []
    for section in CI_SECTIONS:
        if section not in cur:
            # name what's absent instead of silently tolerating it — an
            # expected CI section missing from the current trajectory means
            # a gate job didn't run (or didn't persist), and that should be
            # visible in the summary rather than a quietly shorter table
            skipped.append(section)
            continue
        for mode in sorted(cur.get(section, {})):
            c = cur[section][mode].get("tokens_per_sec")
            if c is None:
                continue
            p = prev.get(section, {}).get(mode, {}).get("tokens_per_sec")
            if p is None:
                print(f"| {section}.{mode} | — | {c:.1f} | new |")
            elif p > 0:
                pct = 100.0 * (c - p) / p
                print(f"| {section}.{mode} | {p:.1f} | {c:.1f} | {pct:+.1f}% |")
            else:
                print(f"| {section}.{mode} | {p:.1f} | {c:.1f} | n/a |")
    if skipped:
        print(f"\n_sections absent from the current trajectory (not "
              f"re-measured by this run): {', '.join(skipped)}_")
    for section, diffs in stale_sections(cur):
        detail = ", ".join(f"{k}: recorded `{a!r}` vs live default `{b!r}`"
                           for k, (a, b) in sorted(diffs.items()))
        print(f"\n:warning: _`{section}` was recorded under a config that "
              f"no longer matches the live engine defaults ({detail}) — "
              f"re-record it_")
    env = cur.get("env")
    if isinstance(env, dict):
        print(f"\n_recorded on jax {env.get('jax')} "
              f"({env.get('backend')}/{env.get('device_kind')} x"
              f"{env.get('device_count')}), "
              f"sha {str(env.get('git_sha'))[:9]}_")
    return 0


if __name__ == "__main__":
    sys.exit(main())
