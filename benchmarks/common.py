"""Shared benchmark utilities: artifact loading, timing, prompt sets.

CPU realism note (EXPERIMENTS.md): absolute tokens/s on this container is
CPU-bound and ~3 orders of magnitude below the paper's A100 numbers; what
must reproduce is the ORDERING and the RATIOS (PARD > VSD > AR+ > AR;
PARD ≈ K× fewer draft forwards; acceptance orderings; COD's ~3x token
reduction at equal accuracy). Each table prints the paper's corresponding
numbers alongside for direct comparison.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import MarkovCorpus
from repro.models import init_params
from repro.training import checkpoint

ART = os.path.join(os.path.dirname(__file__), "artifacts")
RESULTS = os.path.join(os.path.dirname(__file__), "results")

CORPUS = dict(vocab_size=512, seed=0, determinism=3.0, branching=4)


def corpus():
    return MarkovCorpus(**CORPUS)


def has_artifacts() -> bool:
    return os.path.exists(os.path.join(ART, "manifest.json"))


def load_model(name: str, arch: str = None):
    """Load params for artifact ``name`` (arch defaults to name)."""
    cfg = get_config(arch or name)
    init = init_params(jax.random.PRNGKey(0), cfg)
    path = os.path.join(ART, f"{name}.npz")
    if os.path.exists(path):
        return checkpoint.restore(path, init), cfg
    return init, cfg


def load_eagle(target_cfg):
    from repro.core.eagle import init_eagle
    init = init_eagle(jax.random.PRNGKey(9), target_cfg)
    path = os.path.join(ART, "eagle_head.npz")
    if os.path.exists(path):
        return checkpoint.restore(path, init)
    return init


def prompts(batch: int, length: int = 16, seed: int = 5):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    return jnp.asarray(corpus().prompts(rng, batch, length))


def timed(fn, *args, warmup: int = 1, reps: int = 1, **kw):
    """Returns (result, seconds) — best of ``reps`` after ``warmup``."""
    for _ in range(warmup):
        out = fn(*args, **kw)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(jax.tree.leaves(out[0])[0]
                              if isinstance(out, tuple) else out)
        best = min(best, time.perf_counter() - t0)
    return out, best


def emit(rows, table: str, persist: bool = True):
    """Print the required ``name,us_per_call,derived`` CSV and (unless the
    table persists its own canonical record — see update_bench_serve)
    mirror it under benchmarks/results/."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if not persist:
        return
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, f"bench_{table}.json"), "w") as f:
        json.dump([{"name": n, "us_per_call": u, "derived": d}
                   for n, u, d in rows], f, indent=1)


BENCH_SERVE = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")


def update_bench_serve(section: str, record: Dict) -> None:
    """Merge ``record`` under ``section`` into the canonical serving
    trajectory file, BENCH_serve.json at the repo root (the one location —
    the gitignored benchmarks/results/ mirror is NOT written for serve
    tables). CI uploads this file and gates on its accepted lengths."""
    data = {}
    if os.path.exists(BENCH_SERVE):
        with open(BENCH_SERVE) as f:
            data = json.load(f)
    data[section] = record
    with open(BENCH_SERVE, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
