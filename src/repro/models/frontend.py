"""Modality frontend STUBS (the one sanctioned carve-out).

Whisper's mel-spectrogram + conv feature extractor and the VLM's ViT/SigLIP
vision encoder + projector are not implemented; ``frontend_embed_spec``
returns ShapeDtypeStructs (dry-run) and ``fake_frontend_embed`` returns
deterministic embeddings (tests/examples) of the exact shape the language
backbone consumes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig


def frontend_embed_shape(cfg: ModelConfig, batch: int):
    if cfg.is_encoder_decoder:          # audio: mel frames after conv stride
        return (batch, cfg.encoder_seq, cfg.d_model)
    if cfg.cross_attn_period:           # vlm: projected image patches
        return (batch, cfg.cross_kv_len, cfg.d_model)
    return None


def frontend_embed_spec(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    shape = frontend_embed_shape(cfg, batch)
    if shape is None:
        return None
    return jax.ShapeDtypeStruct(shape, dtype)


def fake_frontend_embed(cfg: ModelConfig, batch: int, seed: int = 0,
                        dtype=jnp.bfloat16):
    shape = frontend_embed_shape(cfg, batch)
    if shape is None:
        return None
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32
                             ).astype(dtype) * 0.02
