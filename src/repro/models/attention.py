"""Attention blocks: GQA (global / sliding-window), MLA, cross-attention.

All flavours share one masked-attention core (``attend``) driven by absolute
positions, so training (no cache), prefill (cache write, full seq) and decode
(small q against a long cache) are the same code path. The PARD training
mask (Fig. 4/5 of the paper) enters through ``mask_info`` — per-token
(segment, base) metadata — and is computed functionally, never materialised
by the caller.

KV caches come in two layouts (DESIGN.md §5):

  * contiguous — one full-length buffer per batch row, indexed by absolute
    position; speculative rollback is just resetting ``cache_pos`` (stale
    entries are masked out by the validity test ``kv_index < kv_len``);
  * paged — fixed-size KV blocks in a shared pool ``[num_blocks, block,
    ...]`` with a per-row block table ``[B, max_blocks]`` mapping absolute
    position ``p`` to ``(table[b, p // block], p % block)``. Block 0 is the
    reserved garbage block: unallocated table entries point at it, so writes
    past a row's allocation land there and are never attended (reads are
    bounded by ``kv_len``). The serving pool lives in serving/kv_pool.py.

The paged layout is selected by passing ``block_tables``/``kv_block_size``
through ``forward`` — the same rollback-by-``cache_pos`` semantics hold
because validity is still ``kv_index < kv_len``.

Chunked prefill (DESIGN.md §8) needs nothing new here: every path is
driven by PER-ROW ``cache_pos``/positions, so one forward mixes decoding
rows (small verify window against a long cache) with prefilling rows (a
prompt chunk at the row's cursor) — the serving executor's fused step is
just such a batch. Under tree verification a prefilling row carries a
causal all-lower-bits ancestor mask and ``win_len`` = its chunk's real
token count, making the chunk an ordinary causal window to
``tree_allowed`` and both tree kernels.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import apply_rope, softcap

Array = jax.Array

NEG_INF = -1e30

# attention backend: "xla" (jnp reference, default) or "pallas" (the
# kernels/ implementations; interpret-mode on CPU, native on TPU). Switch
# with set_attention_backend — tests assert both paths agree.
_BACKEND = "xla"


def set_attention_backend(name: str) -> None:
    global _BACKEND
    assert name in ("xla", "pallas")
    _BACKEND = name


def _pallas_ok(q, k, mask_info, scale) -> bool:
    """The Pallas kernels cover the standard GQA cases: no PARD metadata
    (training masks use ops.pard_attention via the loss path), head_dim
    uniform q/k (excludes MLA's mixed dims handled by the xla path)."""
    return (_BACKEND == "pallas" and mask_info is None
            and q.shape[-1] == k.shape[-1])


# ---------------------------------------------------------------------------
# Quantized KV storage (DESIGN.md §10)
# ---------------------------------------------------------------------------

# names accepted by Engine(kv_dtype=) / --kv-dtype. "bf16" keeps the
# historical unquantized layout byte-for-byte; int8/fp8 store each KV vector
# quantized against a per-(position, head) float32 scale carried in sibling
# "*_scale" cache leaves (quantize at append, dequantize at read — fused
# into the Pallas streaming bodies on the pallas backend).
KV_DTYPES = {
    "bf16": jnp.bfloat16,
    "fp32": jnp.float32,
    "int8": jnp.int8,
    "fp8": jnp.float8_e4m3fn,
}

# largest representable magnitude per quantized storage dtype: one scale
# unit maps amax onto it
_QUANT_MAXVAL = {jnp.dtype(jnp.int8): 127.0,
                 jnp.dtype(jnp.float8_e4m3fn): 448.0}


def resolve_kv_dtype(kv_dtype):
    """Accept a KV_DTYPES name or any dtype; return the storage dtype."""
    if isinstance(kv_dtype, str):
        return jnp.dtype(KV_DTYPES[kv_dtype])
    return jnp.dtype(kv_dtype)


def kv_dtype_is_quantized(dtype) -> bool:
    return jnp.dtype(dtype) in _QUANT_MAXVAL


def quantize_kv(x, qdtype):
    """Per-(position, head) symmetric quantization over the trailing axis.

    x: [..., D] -> (q [..., D] qdtype, scale [...] float32) such that
    ``dequantize_kv(q, scale)`` reconstructs x. int8 scales are amax/127
    with round+clip; fp8 (e4m3) scales are amax/448 with the cast doing the
    mantissa rounding. All-zero vectors take scale 1 so the garbage block's
    zeros stay exactly zero, and a scale is never 0 (dequant never NaNs).
    """
    maxval = _QUANT_MAXVAL[jnp.dtype(qdtype)]
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.where(amax > 0, amax / maxval, 1.0)
    scaled = xf / scale[..., None]
    if jnp.dtype(qdtype) == jnp.dtype(jnp.int8):
        q = jnp.clip(jnp.round(scaled), -maxval, maxval).astype(jnp.int8)
    else:
        q = scaled.astype(qdtype)
    return q, scale


def dequantize_kv(q, scale):
    """Inverse of quantize_kv: [..., D] values x [...] scales -> float32."""
    return q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)


class PardMaskInfo(NamedTuple):
    """Per-token PARD-COD metadata (see core/cod.py).

    segment[i] = s >= 1: which prediction subtask the token belongs to
                 (s==1: real tokens; s>=2: mask tokens predicting the s-th
                 next token).
    base[i]    = n: context length the token conditions on. For s==1 tokens
                 base == position == index in the original sequence.
    A query (s_q, n_q) may attend key (s_k, n_k) iff:
      s_k == 1 and n_k <  n_q              (real context)
      s_k  > 1 and s_k <  s_q and n_k == n_q   (earlier masks, same base)
      s_k == s_q and n_k == n_q            (self)
    Padding tokens carry segment == 0 and never attend / are attended.
    """
    segment: Array  # [B, T] int32
    base: Array     # [B, T] int32


class TreeAttnInfo(NamedTuple):
    """Packed candidate-tree metadata for speculative tree verification
    (DESIGN.md §6). The verify window's KV occupies consecutive cache slots
    ``win_start .. win_start + Tq - 1`` even though sibling branches share
    logical positions, so within-window visibility is an ancestor relation,
    not a positional one.

    win_start: [B] int32 — cache index of window slot 0 (the re-processed
               last committed token; == the verify forward's ``cache_pos``).
               Cache entries below it are committed context, always visible.
    anc:       [B, Tq] uint32 — per query slot s, bit j set iff window slot
               j is an ancestor-or-self of s (bit 0 = the root). Windows are
               <= 32 slots, so one uint32 packs the whole tree.
    win_len:   [B] int32 (optional) — per-row count of MEANINGFUL window
               slots. With per-request tree templates (DESIGN.md §7) the
               batch window is padded to the bank's widest template; slots
               >= win_len belong to no template node, are never accepted,
               and are masked out of visibility entirely — the Pallas
               kernels additionally clamp each row's KV sweep to
               ``win_start + win_len``, so narrow-template rows stream
               fewer bytes. None = every slot meaningful (single template).
    """
    win_start: Array
    anc: Array
    win_len: Optional[Array] = None


def tree_allowed(q_pos, kv_pos, tree_info: TreeAttnInfo, window=0):
    """Boolean [B, Tq, Tk] visibility under tree verification. Context keys
    (cache index < win_start) obey the optional sliding window against the
    query's *logical* position; window keys obey the ancestor bitmask
    (ancestors are <= max_depth logical positions back — inside any
    realistic sliding window, so the window test applies to context only)."""
    tq = q_pos.shape[1]
    ws = tree_info.win_start.astype(jnp.int32)[:, None, None]    # [B,1,1]
    kvp = kv_pos[:, None, :]                                     # [B,1,Tk]
    ctx = kvp < ws
    if window:
        ctx &= kvp > (q_pos[:, :, None] - window)
    j = kvp - ws
    wl = tq if tree_info.win_len is None \
        else tree_info.win_len.astype(jnp.int32)[:, None, None]
    in_win = (j >= 0) & (j < wl) & (j < tq)
    bits = (tree_info.anc.astype(jnp.uint32)[:, :, None]
            >> jnp.clip(j, 0, tq - 1).astype(jnp.uint32)) & jnp.uint32(1)
    return ctx | (in_win & (bits == 1))


def pard_mask(q_seg, q_base, k_seg, k_base):
    """Boolean [.., Tq, Tk] PARD training mask from metadata (broadcasts)."""
    qs, qb = q_seg[..., :, None], q_base[..., :, None]
    ks, kb = k_seg[..., None, :], k_base[..., None, :]
    real_ctx = (ks == 1) & (kb < qb)
    mask_chain = (ks > 1) & (ks < qs) & (kb == qb)
    self_tok = (ks == qs) & (kb == qb)
    valid = (qs > 0) & (ks > 0)
    return valid & (real_ctx | mask_chain | self_tok)


def attend(q, k, v, q_pos, kv_pos, kv_len, *, causal=True, window=0,
           attn_softcap=0.0, scale=None, mask_info=None, kv_mask_info=None,
           tree_info=None, k_scale=None, v_scale=None):
    """Masked multi-head attention core (pure jnp reference path).

    q:      [B, Tq, Hq, Dk]
    k, v:   [B, Tk, Hkv, Dk] / [B, Tk, Hkv, Dv]
    q_pos:  [B, Tq] absolute positions of queries
    kv_pos: [B, Tk] absolute positions of keys
    kv_len: [B] or scalar — number of valid cache entries (Tk used)
    tree_info: optional TreeAttnInfo — tree-verification masking (ancestor
            bitmask inside the window, plain context visibility before it)
            replacing the causal rule for the speculative verify window
    k_scale, v_scale: optional [B, Tk, Hkv] per-(position, head) dequant
            scales for quantized k/v (DESIGN.md §10). The decode/tree Pallas
            kernels fuse the dequant into their KV stream; every other path
            dequantizes up front (the reference semantics).
    """
    b, tq, hq, dk = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    if scale is None:
        scale = 1.0 / math.sqrt(dk)

    if k_scale is not None and not (
            _pallas_ok(q, k, mask_info, scale)
            and (tree_info is not None or (causal and tq != k.shape[1]))):
        # quantized cache on a path without a dequant-fused kernel
        k = dequantize_kv(k, k_scale)
        v = dequantize_kv(v, v_scale)
        k_scale = v_scale = None

    if _pallas_ok(q, k, mask_info, scale) and tree_info is not None:
        from ..kernels import ops
        kv_len_arr = jnp.broadcast_to(jnp.asarray(kv_len), (b,)).astype(jnp.int32)
        return ops.tree_attention(q, k, v, kv_len_arr, q_pos,
                                  tree_info.win_start, tree_info.anc,
                                  win_len=tree_info.win_len,
                                  k_scale=k_scale, v_scale=v_scale,
                                  window=window, softcap=attn_softcap,
                                  scale=scale)
    if _pallas_ok(q, k, mask_info, scale) and causal:
        from ..kernels import ops
        kv_len_arr = jnp.broadcast_to(jnp.asarray(kv_len), (b,)).astype(jnp.int32)
        if tq == k.shape[1]:          # full self-attention (training/prefill)
            return ops.flash_attention(q, k, v, causal=True, window=window,
                                       softcap=attn_softcap, scale=scale)
        # small-q decode/verify against a long cache
        return ops.decode_attention(q, k, v, kv_len_arr, q_pos,
                                    k_scale=k_scale, v_scale=v_scale,
                                    window=window, softcap=attn_softcap,
                                    scale=scale)

    qg = q.reshape(b, tq, hkv, g, dk)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = softcap(logits, attn_softcap)

    if mask_info is not None:
        allowed = pard_mask(mask_info.segment, mask_info.base,
                            (kv_mask_info or mask_info).segment,
                            (kv_mask_info or mask_info).base)      # [B,Tq,Tk]
    elif tree_info is not None:
        allowed = tree_allowed(q_pos, kv_pos, tree_info, window=window)
    else:
        allowed = jnp.ones((b, tq, k.shape[1]), bool)
        if causal:
            allowed &= kv_pos[:, None, :] <= q_pos[:, :, None]
        if window:
            allowed &= kv_pos[:, None, :] > (q_pos[:, :, None] - window)
    kv_len = jnp.asarray(kv_len)
    if kv_len.ndim == 0:
        kv_len = jnp.full((b,), kv_len)
    valid = jnp.arange(k.shape[1])[None, :] < kv_len[:, None]       # [B,Tk]
    allowed &= valid[:, None, :]

    logits = jnp.where(allowed[:, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    # rows with no allowed key (padding queries) produce ~uniform probs over
    # masked keys; their output is garbage but they are never read.
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, tq, hq, -1).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def _dense(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) / math.sqrt(fan_in)


def init_gqa(key, cfg, cross: bool = False):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": _dense(k1, (d, hq, hd), d),
        "wk": _dense(k2, (d, hkv, hd), d),
        "wv": _dense(k3, (d, hkv, hd), d),
        "wo": _dense(k4, (hq, hd, d), hq * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq, hd), jnp.float32)
        p["bk"] = jnp.zeros((hkv, hd), jnp.float32)
        p["bv"] = jnp.zeros((hkv, hd), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def init_gqa_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    c = {"k": jnp.zeros((batch, max_len, hkv, hd), dtype),
         "v": jnp.zeros((batch, max_len, hkv, hd), dtype)}
    if kv_dtype_is_quantized(dtype):
        c["k_scale"] = jnp.ones((batch, max_len, hkv), jnp.float32)
        c["v_scale"] = jnp.ones((batch, max_len, hkv), jnp.float32)
    return c


def _write_cache(buf, new, cache_pos):
    """buf: [B, max, ...]; new: [B, T, ...]; cache_pos: [B] int32."""
    b, t = new.shape[0], new.shape[1]

    def row(buf_r, new_r, p):
        return jax.lax.dynamic_update_slice(
            buf_r, new_r.astype(buf_r.dtype),
            (p,) + (0,) * (buf_r.ndim - 1))

    return jax.vmap(row)(buf, new, cache_pos)


def write_cache_paged(pages, new, cache_pos, block_tables, block_size):
    """Scatter new KV into a block-paged pool through per-row block tables.

    pages: [NB, bs, ...]; new: [B, T, ...]; cache_pos: [B] int32;
    block_tables: [B, MBS] int32. Rows own disjoint blocks, so the flattened
    scatter indices never collide across the batch; positions mapping past a
    row's table (or to unallocated entries) land in the reserved garbage
    block 0, whose contents are never attended.
    """
    b, t = new.shape[0], new.shape[1]
    flat = paged_flat_index(block_tables, cache_pos[:, None]
                            + jnp.arange(t)[None, :], block_size).reshape(-1)
    pf = pages.reshape((-1,) + pages.shape[2:])
    pf = pf.at[flat].set(new.reshape((-1,) + new.shape[2:]).astype(pages.dtype))
    return pf.reshape(pages.shape)


def paged_flat_index(block_tables, pos, block_size):
    """Map absolute positions [B, T] to flat pool-entry indices through the
    per-row block tables. Positions past a row's table resolve to the
    reserved garbage block 0 (never attended: reads are bounded by kv_len)."""
    ent = pos // block_size
    mbs = block_tables.shape[1]
    blk = jnp.take_along_axis(block_tables, jnp.clip(ent, 0, mbs - 1),
                              axis=1)                            # [B, T]
    blk = jnp.where(ent >= mbs, 0, blk)      # past the table -> garbage block
    return blk * block_size + pos % block_size


def gather_pages(pages, block_tables):
    """Per-row contiguous view of a paged pool (the reference read path).

    pages: [NB, bs, ...]; block_tables: [B, MBS] -> [B, MBS * bs, ...].
    """
    g = jnp.take(pages, block_tables, axis=0)                    # [B, MBS, bs, ...]
    return g.reshape((g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:])


def _qk_rmsnorm(x, scale, eps):
    v = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(v + eps) * scale).astype(x.dtype)


# queries per row in the decode/verify windows stay tiny (<= 2K); above this
# the paged Pallas kernel's q tile would not fit VMEM comfortably and the
# gather-based path is used instead (prefill-sized q blocks).
_PAGED_KERNEL_MAX_TQ = 32


def _paged_attend(q, k_pages, v_pages, block_tables, q_pos, kv_len, *,
                  causal=True, window=0, attn_softcap=0.0, scale=None,
                  tree_info=None, k_scale=None, v_scale=None):
    """Attention against a block-paged KV pool.

    Uses the Pallas paged decode kernel for small query windows on the
    pallas backend (the kernel's mask is causal, so only when ``causal``);
    otherwise gathers the row's blocks into a contiguous view and reuses
    the standard ``attend`` core (semantic reference). The gathered
    temporary is the same size as a contiguous cache buffer, so the
    reference path's peak memory matches the contiguous layout — the
    paged layout's HBM win is the persistent pool, and the per-step copy
    is avoided wherever the kernel path is active (TPU decode/verify).
    """
    b, tq = q.shape[:2]
    if (_BACKEND == "pallas" and causal
            and q.shape[-1] == k_pages.shape[-1]
            and tq <= _PAGED_KERNEL_MAX_TQ):
        from ..kernels import ops
        kv_len_arr = jnp.broadcast_to(jnp.asarray(kv_len), (b,)).astype(jnp.int32)
        if tree_info is not None:
            return ops.tree_attention_paged(
                q, k_pages, v_pages, block_tables, kv_len_arr, q_pos,
                tree_info.win_start, tree_info.anc,
                win_len=tree_info.win_len, k_scale=k_scale, v_scale=v_scale,
                window=window, softcap=attn_softcap, scale=scale)
        return ops.decode_attention_paged(
            q, k_pages, v_pages, block_tables, kv_len_arr, q_pos,
            k_scale=k_scale, v_scale=v_scale,
            window=window, softcap=attn_softcap, scale=scale)
    k = gather_pages(k_pages, block_tables)
    v = gather_pages(v_pages, block_tables)
    if k_scale is not None:
        k = dequantize_kv(k, gather_pages(k_scale, block_tables))
        v = dequantize_kv(v, gather_pages(v_scale, block_tables))
    s = k.shape[1]
    kv_pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    return attend(q, k, v, q_pos, kv_pos, kv_len, causal=causal,
                  window=window, attn_softcap=attn_softcap, scale=scale,
                  tree_info=tree_info)


def gqa_apply(params, cfg, x, positions, *, layer_window=0, cache=None,
              cache_pos=None, mask_info=None, causal=True, use_rope=True,
              block_tables=None, kv_block_size=0, tree_info=None):
    """Self attention. Returns (y, new_cache)."""
    b, t, _ = x.shape
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = _qk_rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = _qk_rmsnorm(k, params["k_norm"], cfg.norm_eps)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    scale = cfg.attn_scale or None
    if cache is None:
        out = attend(q, k, v, positions, positions, t, causal=causal,
                     window=layer_window, attn_softcap=cfg.attn_softcap,
                     scale=scale, mask_info=mask_info)
        new_cache = None
    elif block_tables is not None:
        if "k_scale" in cache:
            # quantized pool: quantize on append, so prefill chunks, decode
            # windows and tree-window compaction all produce quantized
            # blocks; the freshly written window reads back through the
            # same dequant path as committed context (DESIGN.md §10)
            k, sk = quantize_kv(k, cache["k"].dtype)
            v, sv = quantize_kv(v, cache["v"].dtype)
            new_cache = {
                "k_scale": write_cache_paged(cache["k_scale"], sk, cache_pos,
                                             block_tables, kv_block_size),
                "v_scale": write_cache_paged(cache["v_scale"], sv, cache_pos,
                                             block_tables, kv_block_size)}
        else:
            new_cache = {}
        new_cache["k"] = write_cache_paged(cache["k"], k, cache_pos,
                                           block_tables, kv_block_size)
        new_cache["v"] = write_cache_paged(cache["v"], v, cache_pos,
                                           block_tables, kv_block_size)
        out = _paged_attend(q, new_cache["k"], new_cache["v"], block_tables,
                            positions, cache_pos + t, causal=causal,
                            window=layer_window,
                            attn_softcap=cfg.attn_softcap, scale=scale,
                            tree_info=tree_info,
                            k_scale=new_cache.get("k_scale"),
                            v_scale=new_cache.get("v_scale"))
    else:
        if "k_scale" in cache:
            k, sk = quantize_kv(k, cache["k"].dtype)
            v, sv = quantize_kv(v, cache["v"].dtype)
            new_cache = {"k_scale": _write_cache(cache["k_scale"], sk,
                                                 cache_pos),
                         "v_scale": _write_cache(cache["v_scale"], sv,
                                                 cache_pos)}
        else:
            new_cache = {}
        new_cache["k"] = _write_cache(cache["k"], k, cache_pos)
        new_cache["v"] = _write_cache(cache["v"], v, cache_pos)
        new_k, new_v = new_cache["k"], new_cache["v"]
        max_len = new_k.shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(max_len)[None, :], (b, max_len))
        kv_len = cache_pos + t
        out = attend(q, new_k, new_v, positions, kv_pos, kv_len, causal=causal,
                     window=layer_window, attn_softcap=cfg.attn_softcap,
                     scale=scale, tree_info=tree_info,
                     k_scale=new_cache.get("k_scale"),
                     v_scale=new_cache.get("v_scale"))
    # sharded serving seam (DESIGN.md §11/§13): exact ruleset all-gathers
    # the head-sharded context BEFORE the wo contraction (bitwise cross-mesh
    # identity); throughput ruleset contracts it row-parallel at canonical
    # chunk granularity and the post-contraction gather becomes the block's
    # single psum; plain einsum without an activation mesh
    from ..kernels import ops
    y = ops.rowparallel_einsum("bthk,hkd->btd", out,
                               params["wo"].astype(x.dtype),
                               x_axis=-2, w_axis=0)
    return ops.gather_activation(y), new_cache


# ---------------------------------------------------------------------------
# Cross attention (static encoder / image KV)
# ---------------------------------------------------------------------------

def init_cross_attn(key, cfg):
    p = init_gqa(key, cfg)
    if cfg.arch_type == "vlm":  # llama-vision gates cross-attn output
        p["gate"] = jnp.zeros((), jnp.float32)
    return p


def precompute_cross_kv(params, cfg, enc_out):
    """enc_out: [B, S, D] -> static cross KV."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(enc_out.dtype))
    return {"k": k, "v": v}


def cross_attn_apply(params, cfg, x, enc_out=None, cross_kv=None):
    """Cross attention against encoder/image states. Pass either raw
    ``enc_out`` [B, S, D] (KV computed here) or a precomputed ``cross_kv``
    (decode-time optimisation, see ``precompute_cross_kv``)."""
    b, t, _ = x.shape
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
    if cross_kv is None:
        cross_kv = precompute_cross_kv(params, cfg, enc_out)
    k, v = cross_kv["k"], cross_kv["v"]
    s = k.shape[1]
    pos = jnp.zeros((b, t), jnp.int32)
    kv_pos = jnp.zeros((b, s), jnp.int32)
    out = attend(q, k, v, pos, kv_pos, s, causal=False)
    from ..kernels import ops
    y = ops.rowparallel_einsum("bthk,hkd->btd", out,
                               params["wo"].astype(x.dtype),
                               x_axis=-2, w_axis=0)
    if "gate" in params:
        y = jnp.tanh(params["gate"]).astype(y.dtype) * y
    return ops.gather_activation(y)


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V2 style)
# ---------------------------------------------------------------------------

def init_mla(key, cfg):
    d, h = cfg.d_model, cfg.n_heads
    r_kv, r_q = cfg.kv_lora_rank, cfg.q_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    p = {}
    if r_q:
        p["w_dq"] = _dense(ks[0], (d, r_q), d)
        p["q_lora_norm"] = jnp.ones((r_q,), jnp.float32)
        p["w_uq"] = _dense(ks[1], (r_q, h, dn + dr), r_q)
    else:
        p["w_q"] = _dense(ks[1], (d, h, dn + dr), d)
    p["w_dkv"] = _dense(ks[2], (d, r_kv + dr), d)
    p["kv_lora_norm"] = jnp.ones((r_kv,), jnp.float32)
    p["w_uk"] = _dense(ks[3], (r_kv, h, dn), r_kv)
    p["w_uv"] = _dense(ks[4], (r_kv, h, dv), r_kv)
    p["wo"] = _dense(ks[5], (h, dv, d), h * dv)
    return p


def init_mla_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    width = cfg.kv_lora_rank + cfg.qk_rope_head_dim
    c = {"ckv": jnp.zeros((batch, max_len, width), dtype)}
    if kv_dtype_is_quantized(dtype):
        # one scale per compressed-KV vector (the latent IS the "head")
        c["ckv_scale"] = jnp.ones((batch, max_len), jnp.float32)
    return c


def _rms(x, scale, eps):
    v = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(v + eps) * scale).astype(x.dtype)


def mla_apply(params, cfg, x, positions, *, cache=None, cache_pos=None,
              mask_info=None, block_tables=None, kv_block_size=0,
              tree_info=None):
    b, t, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r_kv = cfg.kv_lora_rank

    if "w_dq" in params:
        cq = _rms(jnp.einsum("btd,dr->btr", x, params["w_dq"].astype(x.dtype)),
                  params["q_lora_norm"], cfg.norm_eps)
        q = jnp.einsum("btr,rhk->bthk", cq, params["w_uq"].astype(x.dtype))
    else:
        q = jnp.einsum("btd,dhk->bthk", x, params["w_q"].astype(x.dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_full = jnp.einsum("btd,dr->btr", x, params["w_dkv"].astype(x.dtype))
    ckv, k_rope = ckv_full[..., :r_kv], ckv_full[..., r_kv:]
    ckv = _rms(ckv, params["kv_lora_norm"], cfg.norm_eps)
    # rope on the shared key channel (1 "head")
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    compressed = jnp.concatenate([ckv, k_rope], axis=-1)     # [B,T,r_kv+dr]

    if cache is not None and block_tables is not None:
        # paged MLA: the compressed KV pages gather into a per-row view;
        # the projection to full K/V below is shared with the other paths.
        # Quantized pools dequantize at the gather (MLA's mixed head dims
        # never hit the fused GQA kernels).
        if "ckv_scale" in cache:
            qc, sc = quantize_kv(compressed, cache["ckv"].dtype)
            pages = write_cache_paged(cache["ckv"], qc, cache_pos,
                                      block_tables, kv_block_size)
            spages = write_cache_paged(cache["ckv_scale"], sc, cache_pos,
                                       block_tables, kv_block_size)
            new_cache = {"ckv": pages, "ckv_scale": spages}
            kv_src = dequantize_kv(gather_pages(pages, block_tables),
                                   gather_pages(spages, block_tables)
                                   ).astype(x.dtype)
        else:
            pages = write_cache_paged(cache["ckv"], compressed, cache_pos,
                                      block_tables, kv_block_size)
            new_cache = {"ckv": pages}
            kv_src = gather_pages(pages, block_tables)
        s = kv_src.shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        kv_len = cache_pos + t
    elif cache is not None:
        if "ckv_scale" in cache:
            qc, sc = quantize_kv(compressed, cache["ckv"].dtype)
            buf = _write_cache(cache["ckv"], qc, cache_pos)
            sbuf = _write_cache(cache["ckv_scale"], sc, cache_pos)
            new_cache = {"ckv": buf, "ckv_scale": sbuf}
            kv_src = dequantize_kv(buf, sbuf).astype(x.dtype)
        else:
            buf = _write_cache(cache["ckv"], compressed, cache_pos)
            new_cache = {"ckv": buf}
            kv_src = buf
        s = new_cache["ckv"].shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        kv_len = cache_pos + t
    else:
        new_cache = None
        kv_src = compressed
        kv_pos = positions
        kv_len = t

    ckv_all, k_rope_all = kv_src[..., :r_kv], kv_src[..., r_kv:]
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv_all.astype(x.dtype),
                        params["w_uk"].astype(x.dtype))
    v = jnp.einsum("bsr,rhk->bshk", ckv_all.astype(x.dtype),
                   params["w_uv"].astype(x.dtype))
    k_rope_b = jnp.broadcast_to(k_rope_all[:, :, None, :].astype(x.dtype),
                                (b, kv_src.shape[1], h, dr))
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = 1.0 / math.sqrt(dn + dr)

    out = attend(qfull, k, v, positions, kv_pos, kv_len, causal=True,
                 scale=scale, mask_info=mask_info if cache is None else None,
                 tree_info=tree_info if cache is not None else None)
    from ..kernels import ops
    y = ops.rowparallel_einsum("bthk,hkd->btd", out,
                               params["wo"].astype(x.dtype),
                               x_axis=-2, w_axis=0)
    return ops.gather_activation(y), new_cache
