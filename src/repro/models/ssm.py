"""Mamba2 (SSD — state-space duality) block. arXiv:2405.21060.

The block follows the reference Mamba2 layout with n_groups=1:
  in_proj -> [z | x | B | C | dt], causal depthwise conv over [x|B|C],
  SSD scan, gated RMSNorm, out_proj.

Three execution paths share the same math:
  * ``ssd_scan_ref``     — token-by-token lax.scan (oracle, tests)
  * ``ssd_scan_chunked`` — chunked jnp (training/prefill; what the Pallas
                           kernel in kernels/ssd.py tiles for VMEM)
  * ``ssd_step``         — single-token recurrent decode; verification of K
                           speculative tokens uses ``ssd_scan_chunked`` with
                           an explicit initial state, so a PARD verify pass
                           is ONE forward even for SSM layers.

The decode-time state is the (conv_cache, ssm_state) pair; speculative
rollback re-runs the scan from the iteration-start snapshot over accepted
tokens only (see serving/engine.py).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Array = jax.Array


def init_mamba2(key, cfg):
    d = cfg.d_model
    d_in = cfg.ssm_inner
    n = cfg.ssm_state
    h = cfg.ssm_nheads
    conv_dim = d_in + 2 * n
    ks = jax.random.split(key, 6)
    proj_out = 2 * d_in + 2 * n + h
    p = {
        "in_proj": jax.random.normal(ks[0], (d, proj_out), jnp.float32) / math.sqrt(d),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "ssm_norm": jnp.ones((d_in,), jnp.float32),
        "out_proj": jax.random.normal(ks[2], (d_in, d), jnp.float32) / math.sqrt(d_in),
    }
    return p


def init_mamba2_state(cfg, batch, dtype=jnp.float32):
    d_in, n, h, p = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    conv_dim = d_in + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, h, p, n), dtype),
    }


# ---------------------------------------------------------------------------
# SSD scans
# ---------------------------------------------------------------------------

def ssd_scan_ref(x, dt, A, B, C, init_state=None, collect_states: bool = False):
    """Token-by-token oracle.

    x:  [b, t, h, p]   dt: [b, t, h]   A: [h]
    B, C: [b, t, n]
    Returns (y [b,t,h,p], final_state [b,h,p,n]); with ``collect_states``
    the second element is the per-token state [b,t,h,p,n] (used for
    speculative rollback of SSM layers — gather at the accepted index).
    """
    b, t, h, p = x.shape
    n = B.shape[-1]
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def step(S, inp):
        xt, dtt, Bt, Ct = inp              # [b,h,p], [b,h], [b,n], [b,n]
        decay = jnp.exp(dtt * A)[:, :, None, None]          # [b,h,1,1]
        upd = (dtt[:, :, None] * xt)[..., None] * Bt[:, None, None, :]
        S = decay * S + upd
        y = jnp.einsum("bhpn,bn->bhp", S, Ct)
        return S, (y, S) if collect_states else (y, None)

    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(B.astype(jnp.float32), 1, 0),
          jnp.moveaxis(C.astype(jnp.float32), 1, 0))
    S, (ys, states) = jax.lax.scan(step, init_state.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)
    if collect_states:
        return y, jnp.moveaxis(states, 0, 1)   # [b,t,h,p,n]
    return y, S


def ssd_chunk_body(x, dt, A, B, C, S_in):
    """Exact SSD over one chunk given incoming state.

    x: [b, l, h, p]; dt: [b, l, h]; B, C: [b, l, n]; S_in: [b, h, p, n].
    Returns (y [b,l,h,p], S_out).
    """
    dtA = dt.astype(jnp.float32) * A                       # [b,l,h]
    cum = jnp.cumsum(dtA, axis=1)                          # [b,l,h]
    # intra-chunk kernel: w[i,j] = exp(cum_i - cum_j) for j<=i.
    # Mask INSIDE the exp: masked (j>i) entries have positive diff that can
    # overflow to inf, and grad-of-where would then produce NaN cotangents.
    diff = cum[:, :, None, :] - cum[:, None, :, :]         # [b,i,j,h]
    seq = x.shape[1]
    causal = jnp.tril(jnp.ones((seq, seq), bool))
    w = jnp.exp(jnp.where(causal[None, :, :, None], diff, -1e30))
    cb = jnp.einsum("bin,bjn->bij", C.astype(jnp.float32), B.astype(jnp.float32))
    gate = w * cb[..., None]                               # [b,i,j,h]
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]  # [b,l,h,p]
    y_intra = jnp.einsum("bijh,bjhp->bihp", gate, xdt)
    # incoming state contribution
    y_state = jnp.einsum("bhpn,bin,bih->bihp", S_in.astype(jnp.float32),
                         C.astype(jnp.float32), jnp.exp(cum))
    # state update
    decay_to_end = jnp.exp(cum[:, -1:, :] - cum)           # [b,l,h]
    S_out = S_in.astype(jnp.float32) * jnp.exp(cum[:, -1])[:, :, None, None] + \
        jnp.einsum("bjh,bjhp,bjn->bhpn", decay_to_end, xdt, B.astype(jnp.float32))
    return (y_intra + y_state).astype(x.dtype), S_out


def ssd_scan_chunked(x, dt, A, B, C, init_state=None, chunk: int = 64):
    """Chunked SSD: lax.scan over chunks of ``chunk`` tokens."""
    b, t, h, p = x.shape
    n = B.shape[-1]
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)
    if t % chunk:
        pad = chunk - t % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    tc = x.shape[1] // chunk

    def body(S, inp):
        xc, dtc, Bc, Cc = inp
        y, S = ssd_chunk_body(xc, dtc, A, Bc, Cc, S)
        return S, y

    def split(a):
        return jnp.moveaxis(a.reshape(a.shape[0], tc, chunk, *a.shape[2:]), 1, 0)

    S, ys = jax.lax.scan(body, init_state.astype(jnp.float32),
                         (split(x), split(dt), split(B), split(C)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, tc * chunk, h, p)[:, :t]
    return y, S


# ---------------------------------------------------------------------------
# Full block
# ---------------------------------------------------------------------------

def _causal_conv(seq, w, b, conv_state=None):
    """seq: [B, T, C]; w: [W, C] depthwise; returns ([B,T,C], new_conv_state)."""
    width = w.shape[0]
    if conv_state is None:
        ctx = jnp.pad(seq, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        ctx = jnp.concatenate([conv_state.astype(seq.dtype), seq], axis=1)
    # depthwise conv: out[t] = sum_k ctx[t+k] * w[k]
    t = seq.shape[1]
    out = jnp.zeros_like(seq, dtype=jnp.float32)
    for k in range(width):
        out = out + ctx[:, k:k + t].astype(jnp.float32) * w[k]
    out = out + b
    new_state = ctx[:, -(width - 1):] if width > 1 else None
    return jax.nn.silu(out).astype(seq.dtype), new_state


def mamba2_apply(params, cfg, x, *, state=None, chunk=None,
                 collect_states: bool = False):
    """x: [B, T, D]. state: dict(conv, ssm) or None (zero init, training).

    Returns (y, new_state). new_state is None when state is None (training
    path does not track states). With ``collect_states`` (speculative verify
    path) new_state holds PER-TOKEN states:
      conv: [B, T, W-1, C]   ssm: [B, T, H, P, N]
    so the engine can gather the state at the last accepted token.
    """
    b, t, _ = x.shape
    d_in, n, h, p = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    proj = jnp.einsum("btd,de->bte", x, params["in_proj"].astype(x.dtype))
    z, xs, Bmat, Cmat, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1)

    conv_in = jnp.concatenate([xs, Bmat, Cmat], axis=-1)
    conv_state = state["conv"] if state is not None else None
    conv_out, new_conv = _causal_conv(conv_in, params["conv_w"], params["conv_b"],
                                      conv_state)
    xs, Bmat, Cmat = jnp.split(conv_out, [d_in, d_in + n], axis=-1)

    xh = xs.reshape(b, t, h, p)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    ssm_state = state["ssm"] if state is not None else None
    if collect_states:
        y, new_ssm = ssd_scan_ref(xh, dtv, A, Bmat, Cmat, init_state=ssm_state,
                                  collect_states=True)
    else:
        y, new_ssm = ssd_scan_chunked(xh, dtv, A, Bmat, Cmat,
                                      init_state=ssm_state,
                                      chunk=chunk or cfg.ssm_chunk)
    y = y + xh.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(b, t, d_in).astype(x.dtype)

    # gated RMSNorm (mamba2 norm_before_gate=False: norm(y * silu(z)))
    g = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(g.astype(jnp.float32)), axis=-1, keepdims=True)
    g = (g.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)
         * params["ssm_norm"]).astype(x.dtype)

    # sharded serving seams (DESIGN.md §11/§13): exact ruleset gathers the
    # gated hidden before the out_proj contraction (no partial-sum strategy,
    # bitwise cross-mesh identity); throughput keeps it channel-sharded for
    # the row-parallel out_proj and psums once; identity without a mesh
    from ..kernels import ops as _ops
    out = _ops.gather_activation(_ops.rowparallel_einsum(
        "bte,ed->btd", g, params["out_proj"].astype(x.dtype),
        x_axis=-1, w_axis=0))
    new_state = None
    if state is not None:
        if collect_states:
            # per-token conv windows: state after token t = ctx[t+1 : t+W]
            width = params["conv_w"].shape[0]
            ctx = jnp.concatenate([state["conv"].astype(conv_in.dtype), conv_in],
                                  axis=1)                    # [B, W-1+T, C]
            conv_steps = jnp.stack(
                [jax.lax.dynamic_slice_in_dim(ctx, i + 1, width - 1, axis=1)
                 for i in range(t)], axis=1)                 # [B, T, W-1, C]
            new_state = {"conv": conv_steps.astype(state["conv"].dtype),
                         "ssm": new_ssm.astype(state["ssm"].dtype)}
        else:
            new_state = {"conv": new_conv.astype(state["conv"].dtype),
                         "ssm": new_ssm.astype(state["ssm"].dtype)}
    return out, new_state
