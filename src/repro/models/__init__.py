from .config import ModelConfig, LayerSpec, layer_plan, scan_plan
from .transformer import init_params, init_caches, forward, encode
from .frontend import (fake_frontend_embed, frontend_embed_shape,
                       frontend_embed_spec)
