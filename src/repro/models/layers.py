"""Shared building blocks: norms, RoPE, embeddings, dense MLP, MoE.

Everything is functional: ``init_*`` returns a param pytree (plain dicts of
jnp arrays), ``*_apply`` consumes it. Param leaf names are load-bearing —
the sharding rules in ``repro.sharding.specs`` map leaf names to logical
mesh axes.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Array = jax.Array

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm_apply(params, x: Array, eps: float = 1e-5, gemma_style: bool = False) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    scale = params["scale"]
    if gemma_style:  # gemma multiplies by (1 + scale)
        y = y * (1.0 + scale)
    else:
        y = y * scale
    return y.astype(dtype)


def init_layernorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm_apply(params, x: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(dtype)


def make_norm(cfg):
    """Returns (init_fn, apply_fn) per the config's norm flavour."""
    if cfg.use_layernorm:
        return init_layernorm, lambda p, x: layernorm_apply(p, x, cfg.norm_eps)
    gemma = cfg.post_block_norms  # gemma2 uses (1+scale) RMSNorm
    return init_rmsnorm, lambda p, x: rmsnorm_apply(p, x, cfg.norm_eps, gemma_style=gemma)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq] (int)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                    # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    angles = angles[..., None, :]                          # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-math.log(10000.0) / d))
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# Softcap
# ---------------------------------------------------------------------------

def softcap(x: Array, cap: float) -> Array:
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, cfg):
    v, d = cfg.padded_vocab, cfg.d_model
    scale = 1.0 / math.sqrt(d)
    p = {"embedding": jax.random.normal(key, (v, d), jnp.float32) * scale}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["unembed"] = jax.random.normal(k2, (v, d), jnp.float32) * scale
    return p


def embed_apply(params, tokens: Array, cfg, dtype=jnp.bfloat16) -> Array:
    x = jnp.take(params["embedding"].astype(dtype), tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dtype)
    return x


def unembed_apply(params, x: Array, cfg) -> Array:
    table = params.get("unembed", params["embedding"]).astype(x.dtype)
    logits = jnp.einsum("...d,vd->...v", x, table)
    logits = softcap(logits, cfg.final_softcap)
    # mask padded vocab rows so they can never be sampled
    if cfg.padded_vocab != cfg.vocab_size:
        neg = jnp.asarray(-1e9, logits.dtype)
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad, neg, logits)
    return logits


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU; whisper uses GELU — flag via act)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, gated: bool = True):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    p = {"wi": jax.random.normal(k1, (d_model, d_ff), jnp.float32) * s_in,
         "wo": jax.random.normal(k3, (d_ff, d_model), jnp.float32) * s_out}
    if gated:
        p["wg"] = jax.random.normal(k2, (d_model, d_ff), jnp.float32) * s_in
    return p


def mlp_apply(params, x: Array, act: str = "silu") -> Array:
    h = jnp.einsum("...d,df->...f", x, params["wi"].astype(x.dtype))
    if "wg" in params:
        g = jnp.einsum("...d,df->...f", x, params["wg"].astype(x.dtype))
        if act == "gelu":
            h = jax.nn.gelu(g) * h
        else:
            h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h) if act == "gelu" else jax.nn.silu(h)
    # sharded serving seam (DESIGN.md §11/§13): exact ruleset all-gathers
    # the d_ff-sharded hidden BEFORE the down-projection (bitwise identity);
    # throughput ruleset contracts it row-parallel at canonical chunk
    # granularity, and the post-contraction gather becomes the MLP's single
    # psum; plain einsum without an activation mesh
    from ..kernels import ops
    y = ops.rowparallel_einsum("...f,fd->...d", h,
                               params["wo"].astype(x.dtype),
                               x_axis=-1, w_axis=0)
    return ops.gather_activation(y)


# ---------------------------------------------------------------------------
# MoE — GShard-style dense dispatch with capacity (TPU-friendly, static shapes)
# ---------------------------------------------------------------------------

def init_moe(key, cfg):
    e = cfg.moe_num_experts
    d, f = cfg.d_model, (cfg.moe_d_ff or cfg.d_ff)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "router": jax.random.normal(k1, (d, e), jnp.float32) * s_in,
        "we_i": jax.random.normal(k2, (e, d, f), jnp.float32) * s_in,
        "we_g": jax.random.normal(k3, (e, d, f), jnp.float32) * s_in,
        "we_o": jax.random.normal(k4, (e, f, d), jnp.float32) * s_out,
    }
    if cfg.moe_num_shared:
        p["shared"] = init_mlp(k5, d, f * cfg.moe_num_shared)
    return p


def moe_apply(params, x: Array, cfg, return_aux: bool = False,
              dropless: bool = False, group_size: int = 256):
    """x: [B, T, D]. Top-k routing with GROUPED GShard one-hot dispatch:
    tokens are split into groups of ``group_size``; each group dispatches to
    per-group expert capacity ``cap = factor * g * k / e``. Everything is
    einsum/one-hot — no sort, no scatter — which is what GSPMD partitions
    well (a distributed argsort at 1M tokens compiles pathologically, and
    the ungrouped one-hot dispatch tensor [n, e, n*k/e] is O(n^2)).

    Dispatch-einsum overhead is G*g*e*cap*d = n*e*cap*d, a few percent of
    the expert FLOPs at g=256.

    ``dropless=True`` (decode/verify) uses ONE group with capacity = n so no
    token can ever be dropped — routing must be independent of batch
    composition or lossless speculative decoding would diverge from AR.
    """
    b, t, d = x.shape
    e, k = cfg.moe_num_experts, cfg.moe_top_k
    xt = x.reshape(b * t, d)
    n = b * t
    logits = jnp.einsum("nd,de->ne", xt, params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # [n, e]
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # [n, k]
    gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)

    if dropless:
        g = n
        cap = n
    else:
        g = min(group_size, n)
        while n % g:
            g //= 2
        cap = max(4, int(cfg.moe_capacity_factor * g * k / e))
        cap = min(cap, g)
    ng = n // g

    idx_g = gate_idx.reshape(ng, g, k)
    gate_g = gate_vals.reshape(ng, g, k).astype(x.dtype)
    x_g = xt.reshape(ng, g, d)

    # rank of each (token, slot) within its expert, per group
    onehot = jax.nn.one_hot(idx_g, e, dtype=jnp.int32)       # [G, g, k, e]
    flat = onehot.reshape(ng, g * k, e)
    rank = (jnp.cumsum(flat, axis=1) - flat).reshape(ng, g, k, e)
    pos = jnp.sum(rank * onehot, axis=-1)                    # [G, g, k]
    keep = pos < cap
    gate_g = gate_g * keep.astype(x.dtype)

    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap, dtype=x.dtype)
    oh = onehot.astype(x.dtype)                              # [G, g, k, e]
    disp = jnp.einsum("Ggke,Ggkc->Ggec", oh, pos_oh)         # [G, g, e, cap]
    comb = jnp.einsum("Ggec,Ggk,Ggke->Ggec", disp, gate_g, oh)

    xe = jnp.einsum("Ggec,Ggd->Gecd", disp, x_g)             # [G, e, cap, d]
    hi = jnp.einsum("Gecd,edf->Gecf", xe, params["we_i"].astype(x.dtype))
    hg = jnp.einsum("Gecd,edf->Gecf", xe, params["we_g"].astype(x.dtype))
    he = jax.nn.silu(hg) * hi
    from ..kernels import ops
    # d_ff-sharded row-parallel down-projection seam (throughput ruleset)
    ye = ops.rowparallel_einsum("Gecf,efd->Gecd", he,
                                params["we_o"].astype(x.dtype),
                                x_axis=-1, w_axis=1)
    y = jnp.einsum("Ggec,Gecd->Ggd", comb, ye).reshape(n, d)

    if "shared" in params:
        y = y + mlp_apply(params["shared"], xt)
    y = ops.gather_activation(y).reshape(b, t, d)

    if return_aux:
        # Switch-style load balance loss
        me = jnp.mean(probs, axis=0)                         # [e]
        ce = jnp.mean(jnp.sum(onehot.reshape(n, k, e), axis=1
                              ).astype(jnp.float32), axis=0)
        aux = e * jnp.sum(me * ce)
        return y, {"load_balance_loss": aux,
                   "expert_fraction": ce}
    return y
