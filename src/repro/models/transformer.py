"""Transformer assembly: decoder-only LM + whisper-style encoder-decoder.

The layer stack is executed as ``lax.scan`` over repeating *periods* (see
config.scan_plan) so 95-layer models lower to a small HLO. Params, caches and
SSM states for scanned layers are stacked on a leading ``n_repeats`` axis;
prefix layers (e.g. deepseek-v2's leading dense layer) run unrolled.

Public entry points:
  init_params(key, cfg)                      -> param pytree
  init_caches(cfg, batch, max_len, dtype)    -> cache pytree (decode)
  encode(params, cfg, frontend_embed)        -> encoder output (enc-dec only)
  forward(params, cfg, tokens, ...)          -> (logits, new_caches, aux)
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .config import (ATTN_CROSS, ATTN_GLOBAL, ATTN_LOCAL, ATTN_MLA, MLP_DENSE,
                     MLP_MOE, MLP_NONE, SSM, LayerSpec, ModelConfig, scan_plan)
from . import attention as attn
from . import layers as L
from . import ssm as ssm_mod

Array = jax.Array


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, spec: LayerSpec):
    ks = jax.random.split(key, 8)
    init_norm, _ = L.make_norm(cfg)
    p: Dict[str, Any] = {"norm1": init_norm(cfg.d_model)}
    if spec.mixer in (ATTN_GLOBAL, ATTN_LOCAL):
        p["mixer"] = attn.init_gqa(ks[0], cfg)
    elif spec.mixer == ATTN_MLA:
        p["mixer"] = attn.init_mla(ks[0], cfg)
    elif spec.mixer == ATTN_CROSS:
        p["mixer"] = attn.init_cross_attn(ks[0], cfg)
    elif spec.mixer == SSM:
        p["mixer"] = ssm_mod.init_mamba2(ks[0], cfg)
    else:
        raise ValueError(spec.mixer)

    if spec.mlp != MLP_NONE and not cfg.parallel_block:
        p["norm2"] = init_norm(cfg.d_model)
    if spec.mlp == MLP_DENSE:
        d_ff = cfg.first_dense_d_ff or cfg.d_ff
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, d_ff, gated=cfg.mlp_gated)
    elif spec.mlp == MLP_MOE:
        p["mlp"] = L.init_moe(ks[1], cfg)

    if cfg.post_block_norms:
        p["post_norm1"] = init_norm(cfg.d_model)
        if spec.mlp != MLP_NONE:
            p["post_norm2"] = init_norm(cfg.d_model)

    if cfg.is_encoder_decoder:  # whisper decoder: self + cross per layer
        p["cross"] = attn.init_gqa(ks[2], cfg)
        p["norm_cross"] = init_norm(cfg.d_model)
    return p


def _init_encoder_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    init_norm, _ = L.make_norm(cfg)
    return {"norm1": init_norm(cfg.d_model),
            "mixer": attn.init_gqa(ks[0], cfg),
            "norm2": init_norm(cfg.d_model),
            "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, gated=cfg.mlp_gated)}


def init_params(key, cfg: ModelConfig):
    plan = scan_plan(cfg)
    keys = jax.random.split(key, cfg.num_layers + 4)
    init_norm, _ = L.make_norm(cfg)

    params: Dict[str, Any] = {
        "embed": L.init_embedding(keys[-1], cfg),
        "final_norm": init_norm(cfg.d_model),
    }
    params["prefix"] = [
        _init_layer(keys[i], cfg, spec) for i, spec in enumerate(plan.prefix)]

    scanned = []
    base = len(plan.prefix)
    for j, spec in enumerate(plan.period):
        # one stacked tree per period position: leading dim n_repeats
        per_repeat = [
            _init_layer(keys[base + r * len(plan.period) + j], cfg, spec)
            for r in range(plan.n_repeats)]
        scanned.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_repeat))
    params["scan"] = scanned

    if cfg.is_encoder_decoder:
        ek = jax.random.split(keys[-2], cfg.encoder_layers + 1)
        params["encoder"] = {
            "layers": [_init_encoder_layer(ek[i], cfg)
                       for i in range(cfg.encoder_layers)],
            "final_norm": init_norm(cfg.d_model),
        }
    return params


def _init_layer_cache(cfg, spec: LayerSpec, batch, max_len, dtype):
    if spec.mixer in (ATTN_GLOBAL, ATTN_LOCAL):
        return attn.init_gqa_cache(cfg, batch, max_len, dtype)
    if spec.mixer == ATTN_MLA:
        return attn.init_mla_cache(cfg, batch, max_len, dtype)
    if spec.mixer == SSM:
        return ssm_mod.init_mamba2_state(cfg, batch, jnp.float32)
    if spec.mixer == ATTN_CROSS:
        return {}
    raise ValueError(spec.mixer)


def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """``dtype`` accepts a kv_dtype name ("bf16"/"fp32"/"int8"/"fp8") or a
    jnp dtype; quantized dtypes add sibling *_scale cache leaves."""
    dtype = attn.resolve_kv_dtype(dtype)
    plan = scan_plan(cfg)
    caches = {
        "prefix": [_init_layer_cache(cfg, s, batch, max_len, dtype)
                   for s in plan.prefix],
        "scan": [jax.tree.map(
            lambda x: jnp.broadcast_to(x, (plan.n_repeats,) + x.shape).copy()
            if hasattr(x, "shape") else x,
            _init_layer_cache(cfg, s, batch, max_len, dtype))
            for s in plan.period],
    }
    return caches


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------

def _apply_layer(lp, cfg: ModelConfig, spec: LayerSpec, x, positions, *,
                 cache=None, cache_pos=None, mask_info=None, enc_out=None,
                 collect_ssm=False, block_tables=None, kv_block_size=0,
                 tree_info=None):
    _, norm = L.make_norm(cfg)
    aux = {}
    h = norm(lp["norm1"], x)

    if spec.mixer in (ATTN_GLOBAL, ATTN_LOCAL):
        window = cfg.sliding_window if spec.mixer == ATTN_LOCAL else 0
        y, new_cache = attn.gqa_apply(
            lp["mixer"], cfg, h, positions, layer_window=window, cache=cache,
            cache_pos=cache_pos, mask_info=mask_info, use_rope=cfg.use_rope,
            block_tables=block_tables, kv_block_size=kv_block_size,
            tree_info=tree_info)
    elif spec.mixer == ATTN_MLA:
        y, new_cache = attn.mla_apply(lp["mixer"], cfg, h, positions,
                                      cache=cache, cache_pos=cache_pos,
                                      mask_info=mask_info,
                                      block_tables=block_tables,
                                      kv_block_size=kv_block_size,
                                      tree_info=tree_info)
    elif spec.mixer == ATTN_CROSS:
        y = attn.cross_attn_apply(lp["mixer"], cfg, h, enc_out)
        new_cache = cache
    elif spec.mixer == SSM:
        y, new_cache = ssm_mod.mamba2_apply(lp["mixer"], cfg, h, state=cache,
                                            collect_states=collect_ssm)
    else:
        raise ValueError(spec.mixer)

    if cfg.post_block_norms:
        y = norm(lp["post_norm1"], y)

    if cfg.parallel_block and spec.mlp != MLP_NONE:
        m = L.mlp_apply(lp["mlp"], h, act=cfg.mlp_act)
        x = x + y + m
        return x, (new_cache if new_cache is not None else {}), aux

    x = x + y

    if cfg.is_encoder_decoder:
        hc = norm(lp["norm_cross"], x)
        yc = attn.cross_attn_apply(lp["cross"], cfg, hc, enc_out)
        x = x + yc

    if spec.mlp != MLP_NONE:
        h2 = norm(lp["norm2"], x)
        if spec.mlp == MLP_MOE:
            # dropless routing on decode/verify paths: routing must not
            # depend on batch shape or speculative decoding loses
            # losslessness. Long prefills use capacity routing (capacity=n
            # would make the expert batch O(n^2) — industry standard is to
            # accept capacity drops at prefill).
            dropless = cache_pos is not None and x.shape[1] <= 64
            m, moe_aux = L.moe_apply(lp["mlp"], h2, cfg, return_aux=True,
                                     dropless=dropless)
            aux["load_balance_loss"] = moe_aux["load_balance_loss"]
        else:
            m = L.mlp_apply(lp["mlp"], h2, act=cfg.mlp_act)
        if cfg.post_block_norms:
            m = norm(lp["post_norm2"], m)
        x = x + m
    return x, (new_cache if new_cache is not None else {}), aux


def encode(params, cfg: ModelConfig, frontend_embed: Array) -> Array:
    """Whisper-style encoder over precomputed frame embeddings [B, S, D]."""
    _, norm = L.make_norm(cfg)
    x = frontend_embed
    s = x.shape[1]
    x = x + L.sinusoidal_positions(s, cfg.d_model).astype(x.dtype)
    pos = jnp.broadcast_to(jnp.arange(s)[None, :], x.shape[:2])
    for lp in params["encoder"]["layers"]:
        h = norm(lp["norm1"], x)
        y, _ = attn.gqa_apply(lp["mixer"], cfg, h, pos, causal=False,
                              use_rope=False)
        x = x + y
        h2 = norm(lp["norm2"], x)
        x = x + L.mlp_apply(lp["mlp"], h2, act=cfg.mlp_act)
    return norm(params["encoder"]["final_norm"], x)


def forward(params, cfg: ModelConfig, tokens: Array, positions=None, *,
            mask_info=None, enc_out=None, caches=None, cache_pos=None,
            collect_ssm=False, remat: bool = False, dtype=jnp.bfloat16,
            last_only: bool = False, block_tables=None, kv_block_size=0,
            tree_info=None):
    """Run the decoder stack.

    tokens:       [B, T] int32
    positions:    [B, T] absolute positions (default arange)
    caches:       pytree from init_caches (None = no-cache training/prefill
                  path) or, with ``block_tables``, from
                  serving.kv_pool.init_paged_caches
    cache_pos:    [B] int32 — write offset into the caches
    block_tables: [B, MBS] int32 — per-row block tables selecting the paged
                  KV layout (attention leaves are [NB, block, ...] pools);
                  SSM states stay batch-indexed either way
    kv_block_size: tokens per KV block (static; required with block_tables)
    tree_info:    optional attention.TreeAttnInfo — the tokens are a packed
                  speculative candidate tree; pass explicit depth-based
                  ``positions`` alongside (DESIGN.md §6)

    Returns (logits [B, T, padded_vocab], new_caches, aux).
    """
    plan = scan_plan(cfg)
    b, t = tokens.shape
    if positions is None:
        base = cache_pos[:, None] if cache_pos is not None else 0
        positions = base + jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))

    x = L.embed_apply(params["embed"], tokens, cfg, dtype=dtype)
    if cfg.abs_pos:
        pe = L.sinusoidal_positions(cfg.max_seq_len, cfg.d_model).astype(x.dtype)
        x = x + jnp.take(pe, positions, axis=0)

    _, norm = L.make_norm(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {"prefix": [], "scan": []}

    def run(lp, spec, x, cache):
        return _apply_layer(lp, cfg, spec, x, positions, cache=cache,
                            cache_pos=cache_pos, mask_info=mask_info,
                            enc_out=enc_out, collect_ssm=collect_ssm,
                            block_tables=block_tables,
                            kv_block_size=kv_block_size,
                            tree_info=tree_info)

    # ---- prefix layers (unrolled) ----
    for i, spec in enumerate(plan.prefix):
        cache = caches["prefix"][i] if caches is not None else None
        x, nc, aux = run(params["prefix"][i], spec, x, cache)
        new_caches["prefix"].append(nc)
        aux_total = aux_total + aux.get("load_balance_loss", 0.0)

    # ---- scanned periods ----
    if plan.n_repeats:
        period = plan.period

        def body(carry, xs):
            x, aux_acc = carry
            lps, cs = xs
            new_cs = []
            for j, spec in enumerate(period):
                cache_j = cs[j] if caches is not None else None
                x, nc, aux = run(lps[j], spec, x, cache_j)
                new_cs.append(nc)
                aux_acc = aux_acc + aux.get("load_balance_loss", 0.0)
            return (x, aux_acc), tuple(new_cs)

        if remat:
            body = jax.checkpoint(body)

        xs = (tuple(params["scan"]),
              tuple(caches["scan"]) if caches is not None
              else tuple({} for _ in period))
        (x, aux_total), scan_caches = jax.lax.scan(body, (x, aux_total), xs)
        new_caches["scan"] = list(scan_caches)

    if last_only:
        # serving prefill: only the last position's logits are consumed;
        # skipping the [B, T, vocab] unembed is a large memory/compute win
        x = x[:, -1:]
    hidden = x  # pre-final-norm features (EAGLE-style heads condition on these)
    x = norm(params["final_norm"], x)
    logits = L.unembed_apply(params["embed"], x, cfg)
    # sharded serving: vocab-sharded logits feed softmax/argmax whose
    # distributed reductions would break bitwise cross-mesh identity —
    # all-gather them here in BOTH serving rulesets (sampling always runs
    # on full logits; no-op without an activation mesh, DESIGN.md §11/§13)
    from ..kernels import ops
    logits = ops.gather_activation(logits)
    return logits, (new_caches if caches is not None else None), \
        {"load_balance_loss": aux_total, "hidden": hidden}
