"""Model configuration and per-layer plan derivation.

A ``ModelConfig`` fully describes one architecture from the assigned pool.
``layer_plan(cfg)`` expands it into a list of ``LayerSpec`` (one per layer),
and ``scan_plan(cfg)`` groups the layers into a repeating *period* so the
transformer stack can be executed as ``lax.scan`` over stacked params
(compile-time control for 40-95 layer models).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Layer spec
# ---------------------------------------------------------------------------

# mixer kinds
ATTN_GLOBAL = "attn_global"   # full causal self attention (GQA)
ATTN_LOCAL = "attn_local"     # sliding-window causal self attention
ATTN_MLA = "attn_mla"         # multi-head latent attention (compressed KV)
ATTN_CROSS = "attn_cross"     # cross attention to static encoder/image KV
SSM = "ssm"                   # mamba2 SSD block

# mlp kinds
MLP_DENSE = "dense"
MLP_MOE = "moe"
MLP_NONE = "none"             # mamba2 blocks carry no MLP


@dataclass(frozen=True)
class LayerSpec:
    mixer: str
    mlp: str


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str              # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # --- attention flavour -------------------------------------------------
    attn_kind: str = "gqa"      # gqa | mla
    rope_theta: float = 10000.0
    sliding_window: int = 0             # 0 = no local layers
    local_global_period: int = 0        # gemma2: 2 -> alternate local/global
    attn_softcap: float = 0.0           # gemma2 attention logit softcap
    final_softcap: float = 0.0          # gemma2 final logit softcap
    attn_scale: float = 0.0             # 0 -> 1/sqrt(head_dim)
    qkv_bias: bool = False
    parallel_block: bool = False        # command-r: attn & mlp from same input
    use_layernorm: bool = False         # LayerNorm instead of RMSNorm
    mlp_act: str = "silu"               # silu | gelu
    mlp_gated: bool = True
    use_rope: bool = True
    abs_pos: bool = False               # additive sinusoidal positions (whisper)
    post_block_norms: bool = False      # gemma2 sandwich norms
    embed_scale: bool = False           # gemma: scale embeddings by sqrt(d)
    qk_norm: bool = False

    # --- MLA ---------------------------------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---------------------------------------------------------------
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_num_shared: int = 0
    moe_d_ff: int = 0                   # per-expert ffn dim (0 -> d_ff)
    moe_period: int = 1                 # MoE every `period` layers
    first_dense_layers: int = 0         # deepseek-v2: leading dense layers
    first_dense_d_ff: int = 0
    moe_capacity_factor: float = 1.25

    # --- SSM / hybrid ------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    attn_every: int = 0                 # jamba: 1 attention layer per N layers

    # --- structure ---------------------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500             # whisper: mel frames after conv
    cross_attn_period: int = 0          # llama-vision: every Nth layer cross
    cross_kv_len: int = 0               # static image/encoder KV length
    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    max_seq_len: int = 131072
    source: str = ""                    # citation

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 for clean vocab-axis sharding.
        Always reserves >=1 extra id: ``vocab_size`` itself is the PARD mask
        token (embeddable but masked out of the logits, so it can never be
        predicted)."""
        return _round_up(self.vocab_size + 1, 256)

    @property
    def mask_token_id(self) -> int:
        return self.vocab_size

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_inner // self.ssm_headdim

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test variant of the same family: <=2 layers, d_model<=512,
        <=4 experts. Keeps every structural feature (MLA, MoE, SSD, softcaps)."""
        changes = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 2),
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 1024),
            max_seq_len=512,
        )
        if self.head_dim:
            changes["head_dim"] = 64
        if self.kv_lora_rank:
            changes.update(kv_lora_rank=64, q_lora_rank=min(self.q_lora_rank, 96) if self.q_lora_rank else 0,
                           qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32)
        if self.moe_num_experts:
            changes.update(moe_num_experts=4, moe_top_k=min(self.moe_top_k, 2),
                           moe_num_shared=min(self.moe_num_shared, 1),
                           moe_d_ff=min(self.moe_d_ff or self.d_ff, 128),
                           first_dense_layers=min(self.first_dense_layers, 1))
        if self.ssm_state:
            changes.update(ssm_state=16, ssm_headdim=32, ssm_chunk=16)
        if self.attn_every:
            # keep the hybrid character: 1 attn + 1 ssm
            changes.update(num_layers=2, attn_every=2)
        if self.cross_attn_period:
            changes.update(num_layers=2, cross_attn_period=2, cross_kv_len=16)
        if self.local_global_period:
            changes.update(num_layers=2, sliding_window=64)
        if self.is_encoder_decoder:
            changes.update(encoder_layers=1, encoder_seq=24)
        if self.first_dense_layers and self.moe_num_experts:
            changes["num_layers"] = 2
        changes.update(overrides)
        return dataclasses.replace(self, **changes)


# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------

def _mixer_for_layer(cfg: ModelConfig, i: int) -> str:
    if cfg.attn_every:                       # jamba hybrid: layer i%N==attn_idx
        # 1 attention layer per `attn_every` layers; place it mid-period
        # (jamba places attention at index 4 of each 8-layer block; we use
        #  the last slot of the period for an even split at any period)
        if (i % cfg.attn_every) == cfg.attn_every - 1:
            return ATTN_GLOBAL
        return SSM
    if cfg.arch_type == "ssm":
        return SSM
    if cfg.cross_attn_period and (i % cfg.cross_attn_period) == cfg.cross_attn_period - 1:
        return ATTN_CROSS
    if cfg.attn_kind == "mla":
        return ATTN_MLA
    if cfg.local_global_period:
        # gemma2: even layers local (sliding window), odd layers global
        return ATTN_LOCAL if (i % cfg.local_global_period) != cfg.local_global_period - 1 else ATTN_GLOBAL
    if cfg.sliding_window:
        # sliding window with no period -> every layer local (the windowed
        # long-context serving variant, see launch.steps._windowed)
        return ATTN_LOCAL
    return ATTN_GLOBAL


def _mlp_for_layer(cfg: ModelConfig, i: int) -> str:
    if cfg.arch_type == "ssm":
        return MLP_NONE
    if cfg.attn_every and _mixer_for_layer(cfg, i) == SSM:
        pass  # jamba: every layer (attn or ssm) has an MLP/MoE
    if cfg.moe_num_experts:
        if i < cfg.first_dense_layers:
            return MLP_DENSE
        if (i % cfg.moe_period) == cfg.moe_period - 1 or cfg.moe_period == 1:
            return MLP_MOE
        return MLP_DENSE
    return MLP_DENSE


def layer_plan(cfg: ModelConfig) -> Tuple[LayerSpec, ...]:
    return tuple(LayerSpec(_mixer_for_layer(cfg, i), _mlp_for_layer(cfg, i))
                 for i in range(cfg.num_layers))


@dataclass(frozen=True)
class ScanPlan:
    """Decomposition of the layer stack into prefix + scanned periods.

    layers[0:prefix] run unrolled; the remaining layers form ``n_repeats``
    copies of ``period`` (a tuple of LayerSpec), executed with lax.scan over
    params stacked on a leading ``n_repeats`` axis.
    """
    prefix: Tuple[LayerSpec, ...]
    period: Tuple[LayerSpec, ...]
    n_repeats: int


def scan_plan(cfg: ModelConfig) -> ScanPlan:
    plan = layer_plan(cfg)
    n = len(plan)
    # find smallest period p and prefix q such that plan[q:] is p-periodic
    for prefix_len in range(0, n + 1):
        rest = plan[prefix_len:]
        if not rest:
            return ScanPlan(plan, (), 0)
        for p in range(1, len(rest) + 1):
            if len(rest) % p:
                continue
            period = rest[:p]
            if all(rest[i] == period[i % p] for i in range(len(rest))):
                return ScanPlan(plan[:prefix_len], period, len(rest) // p)
    raise AssertionError("unreachable")
