"""Distributed training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch tiny-target \
      --steps 100 --batch 16 --seq 128 [--pard --draft-init ckpt.npz]

On real hardware this process runs once per host (jax.distributed handles
the rest); on this container it runs the same code path on the local
device(s). ``--mesh data,model`` shards over the host mesh when more than
one device is available.
"""
import argparse

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core.cod import CodConfig
from repro.data.pipeline import MarkovCorpus
from repro.models import init_params
from repro.sharding.specs import param_specs
from repro.training import checkpoint
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.train_loop import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--pard", action="store_true",
                    help="PARD adaptation objective instead of AR")
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--r", type=float, default=0.7)
    ap.add_argument("--r-min", type=float, default=0.2)
    ap.add_argument("--init", default=None, help="checkpoint to start from")
    ap.add_argument("--out", default=None, help="checkpoint output path")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.init:
        params = checkpoint.restore(args.init, params)

    mesh = psharding = dsharding = None
    if jax.device_count() > 1:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(model=args.model_parallel)
        pspec = param_specs(params, mesh, fsdp=False)
        psharding = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                                 is_leaf=lambda x: isinstance(x, P))
        params = jax.device_put(params, psharding)
        dsharding = jax.tree.map(
            lambda _: NamedSharding(mesh, P("data", None)),
            {"tokens": 0} if not args.pard else
            {k: 0 for k in ("input_ids", "position_ids", "labels",
                            "segment", "base")})

    corpus = MarkovCorpus(vocab_size=cfg.vocab_size, seed=0, determinism=2.0)
    opt = AdamW(lr=cosine_schedule(args.lr, min(30, args.steps // 5 + 1),
                                   args.steps))
    cod = CodConfig(k=args.k, r=args.r, r_min=args.r_min)
    tr = Trainer(cfg, opt, loss_kind="pard" if args.pard else "ar", cod=cod,
                 mesh=mesh, param_sharding=psharding, data_sharding=dsharding)
    params, _, hist = tr.fit(params, corpus.batches(args.batch, args.seq,
                                                    seed=args.seed),
                             args.steps, log_every=max(args.steps // 10, 1))
    if args.out:
        checkpoint.save(args.out, params,
                        metadata={"arch": args.arch, "steps": args.steps,
                                  "pard": args.pard,
                                  "final_loss": hist[-1]["loss"]})
        print("saved", args.out)


if __name__ == "__main__":
    main()
