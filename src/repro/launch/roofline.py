"""Roofline derivation from the compiled dry-run artifact.

Three terms per (arch, shape, mesh), in seconds (TPU v5e constants):

  compute_s    = HLO_FLOPs / (chips * 197 TFLOP/s bf16)
  memory_s     = HLO_bytes / (chips * 819 GB/s HBM)
  collective_s = collective_bytes / (chips * 50 GB/s ICI)

cost_analysis() reports whole-program FLOPs/bytes (already accounting for the
SPMD partitioning — the lowered module is the per-device program times the
replica count; XLA reports the global module, so we divide by chip count).
collective_bytes is parsed from the compiled HLO text: the result bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op.

Also derives MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import re
from typing import Any, Dict

from ..models.config import MLP_MOE, ModelConfig, layer_plan
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_census(hlo_text: str) -> Dict[str, Any]:
    """Sum result bytes per collective kind from compiled HLO."""
    census = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        lhs_rhs = ls.split("=", 1)
        rhs = lhs_rhs[1].strip()
        for kind in _COLLECTIVES:
            # match "<type> <kind>(" — kind must be the op, not a substring
            m = re.match(r"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
                         + kind + r"(?:-start|-done)?\(", rhs)
            if m:
                # -done ops repeat the -start result; count only starts & sync
                if kind + "-done(" in rhs:
                    census[kind]["count"] += 0
                else:
                    census[kind]["count"] += 1
                    census[kind]["bytes"] += _shape_bytes(m.group(1))
                break
    census["total_bytes"] = sum(v["bytes"] for k, v in census.items()
                                if isinstance(v, dict))
    return census


def scan_correction(cfg: ModelConfig) -> float:
    """XLA's cost_analysis counts a while-loop (lax.scan) body ONCE, not
    times its trip count — verified empirically: gemma2-27b train reports
    ~23x fewer FLOPs than 6·N·D, matching its 23 scanned periods. All
    HLO-derived terms are scaled by (prefix + repeats*period)/(prefix +
    period) to undo this. Embed/unembed live outside the scan so this is a
    slight over-correction for them (documented approximation)."""
    from ..models.config import scan_plan
    plan = scan_plan(cfg)
    body = len(plan.prefix) + len(plan.period)
    total = len(plan.prefix) + plan.n_repeats * len(plan.period)
    return total / max(body, 1)


def model_flops_per_token(cfg: ModelConfig) -> float:
    """2·N_active per token — the forward-pass estimate (training applies
    a 3x fwd+bwd multiplier in roofline_terms)."""
    n_active = 0.0
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    for spec in layer_plan(cfg):
        if spec.mixer in ("attn_global", "attn_local"):
            n_active += d * cfg.n_heads * hd * 2          # wq + wo
            n_active += d * cfg.n_kv_heads * hd * 2       # wk + wv
        elif spec.mixer == "attn_mla":
            r_kv, r_q = cfg.kv_lora_rank, cfg.q_lora_rank
            dn, dr, dv = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                          cfg.v_head_dim)
            if r_q:
                n_active += d * r_q + r_q * cfg.n_heads * (dn + dr)
            else:
                n_active += d * cfg.n_heads * (dn + dr)
            n_active += d * (r_kv + dr)
            n_active += r_kv * cfg.n_heads * (dn + dv)
            n_active += cfg.n_heads * dv * d
        elif spec.mixer == "attn_cross":
            n_active += d * cfg.n_heads * hd * 2
            n_active += d * cfg.n_kv_heads * hd * 2
        elif spec.mixer == "ssm":
            d_in = cfg.ssm_inner
            n_active += d * (2 * d_in + 2 * cfg.ssm_state + cfg.ssm_nheads)
            n_active += d_in * d
        if spec.mlp == "dense":
            f = cfg.first_dense_d_ff or cfg.d_ff
            n_active += d * f * (3 if cfg.mlp_gated else 2)
        elif spec.mlp == MLP_MOE:
            f = cfg.moe_d_ff or cfg.d_ff
            n_active += d * f * 3 * (cfg.moe_top_k + cfg.moe_num_shared)
            n_active += d * cfg.moe_num_experts            # router
    n_active += d * cfg.padded_vocab                       # unembed
    return 2.0 * n_active


def tokens_processed(cfg: ModelConfig, shape: str, mode: str) -> float:
    from .steps import PARD_K, SHAPES
    sh = SHAPES[shape]
    if sh["kind"] == "train":
        return sh["global_batch"] * (sh["seq_len"] - 1)
    if sh["kind"] == "prefill":
        return sh["global_batch"] * sh["seq_len"]
    q = PARD_K + 1 if mode == "pard_verify" else 1
    return sh["global_batch"] * q


def roofline_terms(rec: Dict[str, Any], cfg: ModelConfig, shape: str
                   ) -> Dict[str, Any]:
    """NOTE: jax's compiled.cost_analysis() on an SPMD-partitioned module
    reports PER-DEVICE flops/bytes (verified empirically: an 8-way sharded
    matmul reports ~1/8 the flops). The collective shapes in the partitioned
    HLO are likewise per-device. So each term is simply value / per-chip
    rate — no further division by chip count."""
    chips = 1
    for m in rec["mesh"]:
        chips *= m
    # Empirically (see EXPERIMENTS.md §Roofline caveats): serve-step records
    # count the scanned while body fully, but TRAIN records (remat inside
    # scan) under-count by roughly the repeat count. The correction applies
    # to train only; the analytic compute term below is authoritative for
    # the compute axis either way.
    is_train = shape == "train_4k"
    corr = scan_correction(cfg) if is_train else 1.0
    flops = rec.get("flops", 0.0) * corr       # per device (diagnostic only)
    byts = rec.get("bytes_accessed", 0.0)      # raw HLO traffic
    coll = rec.get("collectives", {}).get("total_bytes", 0)

    toks = tokens_processed(cfg, shape, rec.get("mode", "default"))
    mult = 3.0 if is_train else 1.0                     # fwd+bwd
    mflops = model_flops_per_token(cfg) * toks * mult   # global, analytic
    # compute term: the ANALYTIC model FLOPs per chip (the roofline
    # definition); the HLO-derived term is kept for diagnostics
    compute_s = mflops / chips / PEAK_FLOPS_BF16
    memory_s = byts / HBM_BW
    collective_s = coll / ICI_BW
    terms = dict(compute_s=compute_s, memory_s=memory_s,
                 collective_s=collective_s)
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom.replace("_s", "")
    terms["scan_correction"] = corr
    terms["compute_s_hlo"] = flops / PEAK_FLOPS_BF16
    hlo_global = flops * chips
    terms["model_flops"] = mflops
    terms["useful_compute_ratio"] = (mflops / hlo_global) \
        if hlo_global else 0.0
    terms["tokens"] = toks

    # Analytic HBM floor for serving steps (weights + KV cache streamed once
    # per step). XLA-CPU "bytes accessed" reflects CPU fusion choices, which
    # can both over-count (materialised f32 attention scores) and under-count
    # (fully fused 1-token attention) relative to TPU HBM traffic — so the
    # table reports max(HLO, analytic) as memory_s and keeps both.
    from .steps import SHAPES
    sh = SHAPES[shape]
    if sh["kind"] == "decode":
        model_axis = rec["mesh"][-1]
        wb = _param_bytes(cfg) / model_axis             # bf16, TP-sharded
        cb = _kv_cache_bytes_per_device(cfg, sh["global_batch"],
                                        sh["seq_len"], rec["mesh"])
        analytic = (wb + cb) / HBM_BW
        terms["memory_s_analytic"] = analytic
        terms["memory_s_hlo"] = terms["memory_s"]
        terms["memory_s"] = max(terms["memory_s"], analytic)
        dom = max(("compute_s", "memory_s", "collective_s"),
                  key=lambda k: terms[k])
        terms["dominant"] = dom.replace("_s", "")
    return terms


def _param_bytes(cfg: ModelConfig) -> float:
    """Approximate serving weight bytes (bf16)."""
    per_tok = model_flops_per_token(cfg) / 6.0          # = N_active
    # active != total for MoE; scale up by expert ratio
    if cfg.moe_num_experts:
        f = cfg.moe_d_ff or cfg.d_ff
        routed_active = cfg.d_model * f * 3 * cfg.moe_top_k
        routed_total = cfg.d_model * f * 3 * cfg.moe_num_experts
        per_tok += (routed_total - routed_active) * \
            sum(1 for s in layer_plan(cfg) if s.mlp == MLP_MOE)
    return per_tok * 2.0


def _kv_cache_bytes_per_device(cfg: ModelConfig, batch, seq, mesh) -> float:
    """KV bytes one decode step must stream, per device, honouring the
    cache_specs sharding (batch over data when divisible, else seq; kv heads
    over model when divisible, else REPLICATED — the command-r-35b kv=8 case
    reads the full per-batch-shard cache on every device)."""
    model = mesh[-1]
    data = 1
    for m in mesh[:-1]:
        data *= m
    b_local = batch / data if batch % data == 0 else batch
    s_local = seq if batch % data == 0 else seq / data
    total = 0.0
    for spec in layer_plan(cfg):
        if spec.mixer in ("attn_global", "attn_local"):
            hkv = cfg.n_kv_heads
            h_local = hkv / model if hkv % model == 0 else hkv
            if spec.mixer == "attn_local" and cfg.sliding_window:
                s_eff = min(s_local, cfg.sliding_window)
            else:
                s_eff = s_local
            total += 2 * b_local * s_eff * h_local * cfg.resolved_head_dim * 2
        elif spec.mixer == "attn_mla":
            total += b_local * s_local * \
                (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * 2
        elif spec.mixer == "ssm":
            n_, h_, p_ = cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
            total += b_local * h_ * p_ * n_ * 4
    return total
