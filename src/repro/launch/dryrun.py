import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

MUST be run as its own process (``python -m repro.launch.dryrun``): the two
lines above run before any other import so the 512 placeholder devices exist
before jax locks the device count. Nothing here allocates a real tensor —
params, optimizer state, caches and batches are all ShapeDtypeStructs.

Per combo it records: compile wall-time, cost_analysis (FLOPs / bytes),
memory_analysis (per-device bytes), the collective-byte census parsed from
the compiled HLO, and the derived three-term roofline (launch/roofline.py).

Usage:
  python -m repro.launch.dryrun --arch gemma2-27b --shape decode_32k
  python -m repro.launch.dryrun --all [--multi-pod] [--mode pard_verify]
  python -m repro.launch.dryrun --all --both-meshes --out benchmarks/results
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED, get_config
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (SHAPES, input_specs, make_decode_step,
                                make_prefill_step, make_train_step,
                                make_verify_step, opt_state_shapes,
                                param_shapes)
from repro.sharding.specs import cache_specs, data_spec, param_specs
from repro.training.optimizer import AdamW

# long_500k policy (DESIGN.md §4): runs natively for SSM/hybrid; gemma2 runs
# the all-local windowed serving variant; pure full-attention archs skip.
LONG_OK = {"mamba2-130m": "native", "jamba-1.5-large-398b": "windowed",
           "gemma2-27b": "windowed"}
LONG_WINDOW = 4096


def _skip_reason(arch: str, shape: str) -> str | None:
    if shape == "long_500k" and arch not in LONG_OK:
        return ("pure full-attention architecture — long_500k requires "
                "sub-quadratic attention (DESIGN.md §4)")
    return None


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_one(arch: str, shape: str, *, multi_pod: bool = False,
              mode: str = "default", mesh=None,
              variant: str = "baseline") -> Dict[str, Any]:
    """``variant`` selects a §Perf hillclimb configuration:

      baseline         — paper-faithful defaults
      pard_verify      — (via mode) K+1-token PARD verification step
      kv8              — int8 KV cache (beyond-paper: halves the decode
                         memory term; real deployment adds scale tensors)
      replicated       — no model-axis weight sharding for serving (kills
                         weight all-gathers for small models where
                         collectives dominate)
      expert_parallel  — MoE experts sharded over the model axis
                         (all-to-all dispatch)
      no_remat         — training without activation checkpointing
      seq_shard_verify — (with mode=pard_verify) long-context: shard the
                         KV sequence over BOTH data and model axes
    """
    cfg = get_config(arch)
    sh = SHAPES[shape]
    kind = sh["kind"]
    b, s = sh["global_batch"], sh["seq_len"]
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    window = 0
    if shape == "long_500k" and LONG_OK.get(arch) == "windowed":
        window = LONG_WINDOW

    rec: Dict[str, Any] = dict(arch=arch, shape=shape, mode=mode,
                               multi_pod=multi_pod, variant=variant,
                               mesh=list(mesh.devices.shape), window=window)
    t0 = time.perf_counter()

    ep = variant == "expert_parallel"
    if kind == "train":
        opt = AdamW(lr=1e-4)
        step = make_train_step(cfg, opt, remat=variant != "no_remat")
        params = param_shapes(cfg)                      # fp32 master
        opt_state = opt_state_shapes(cfg, opt)
        pspec = param_specs(params, mesh, fsdp=True, expert_parallel=ep)
        # optimizer state shards exactly like params (mu/nu mirror the tree)
        from repro.training.optimizer import AdamWState
        ospec = AdamWState(P(), pspec, pspec)
        ins = input_specs(cfg, shape)
        bspec = {k: data_spec(mesh, v.shape[0], len(v.shape))
                 for k, v in ins["batch"].items()}
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(_named(mesh, pspec), _named(mesh, ospec),
                              _named(mesh, bspec)),
            ).lower(params, opt_state, ins["batch"])
    else:
        params = param_shapes(cfg, dtype=jnp.bfloat16)  # serving weights
        if variant == "replicated":
            pspec = jax.tree.map(lambda s: P(*([None] * len(s.shape))), params)
        else:
            pspec = param_specs(params, mesh, fsdp=False, expert_parallel=ep)
        cache_dtype = jnp.int8 if variant == "kv8" else jnp.bfloat16
        ins = input_specs(cfg, shape, mode=mode, cache_dtype=cache_dtype)
        caches = ins["caches"]
        cspec = cache_specs(caches, cfg, mesh, b,
                            seq_model_shard=variant == "seq_shard_verify")
        bspec = {k: data_spec(mesh, v.shape[0], len(v.shape))
                 for k, v in ins["batch"].items()}
        if kind == "prefill":
            step = make_prefill_step(cfg)
        elif mode == "pard_verify":
            step = make_verify_step(cfg, window=window)
        else:
            step = make_decode_step(cfg, window=window)
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(_named(mesh, pspec), _named(mesh, cspec),
                              _named(mesh, bspec)),
            ).lower(params, caches, ins["batch"])

    rec["lower_s"] = round(time.perf_counter() - t0, 2)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.perf_counter() - t1, 2)

    ca = compiled.cost_analysis() or {}
    rec["flops"] = float(ca.get("flops", 0.0))
    rec["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
    ma = compiled.memory_analysis()
    if ma is not None:
        rec["memory"] = dict(
            argument_bytes=int(ma.argument_size_in_bytes),
            output_bytes=int(ma.output_size_in_bytes),
            temp_bytes=int(ma.temp_size_in_bytes),
            alias_bytes=int(ma.alias_size_in_bytes),
            code_bytes=int(ma.generated_code_size_in_bytes),
        )
    hlo = compiled.as_text()
    rec["collectives"] = roofline.collective_census(hlo)
    rec["roofline"] = roofline.roofline_terms(rec, cfg, shape)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--mode", default="default",
                    choices=["default", "pard_verify"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    args = ap.parse_args()

    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    # shape-major, cheap shapes first: training compiles are 10-100x slower
    # (superlinear GSPMD propagation with depth), so serving combos bank
    # first and an interrupted sweep still covers the full serving grid
    shape_order = [s for s in ("prefill_32k", "decode_32k", "long_500k",
                               "train_4k") if s in shapes]
    for shape in shape_order:
        for arch in archs:
            reason = _skip_reason(arch, shape)
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}" + \
                    ("" if args.mode == "default" else f"__{args.mode}") + \
                    ("" if args.variant == "baseline" else f"__{args.variant}")
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[skip-done] {tag}")
                    continue
                if reason:
                    rec = dict(arch=arch, shape=shape, multi_pod=mp,
                               skipped=reason)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    print(f"[skip] {tag}: {reason}")
                    continue
                try:
                    rec = lower_one(arch, shape, multi_pod=mp, mode=args.mode,
                                    variant=args.variant)
                    status = "OK"
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = dict(arch=arch, shape=shape, multi_pod=mp,
                               error=f"{type(e).__name__}: {e}",
                               traceback=traceback.format_exc()[-4000:])
                    failures += 1
                    status = "FAIL"
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                msg = rec.get("error", "")[:120]
                extra = ""
                if "roofline" in rec:
                    r = rec["roofline"]
                    extra = (f" compute={r['compute_s']:.2e}s "
                             f"mem={r['memory_s']:.2e}s "
                             f"coll={r['collective_s']:.2e}s "
                             f"dom={r['dominant']}")
                print(f"[{status}] {tag} "
                      f"lower={rec.get('lower_s')}s "
                      f"compile={rec.get('compile_s')}s{extra} {msg}",
                      flush=True)
    print(f"dryrun complete, failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
