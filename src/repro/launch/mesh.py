"""Production mesh construction.

Single pod: TPU v5e-256 -> (16, 16) over ("data", "model").
Multi-pod:  2 pods = 512 chips -> (2, 16, 16) over ("pod", "data", "model").

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first jax init;
tests and benches must keep seeing 1 device).
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~per-chip usable)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """A tiny mesh over however many (real or placeholder) devices exist —
    for tests that want sharded execution on CPU."""
    n = jax.device_count()
    data = n // model
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
