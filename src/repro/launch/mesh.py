"""Mesh construction — production TPU shapes and host (CPU) test meshes.

Single pod: TPU v5e-256 -> (16, 16) over ("data", "model").
Multi-pod:  2 pods = 512 chips -> (2, 16, 16) over ("pod", "data", "model").

Host meshes back the sharded serving tests/benchmarks: the CPU backend is
forced to expose N placeholder devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set BEFORE the
first jax backend init — ``ensure_host_devices`` does exactly that and
nothing else), and ``make_host_mesh`` builds a ("data", "model") mesh over
any leading subset of them, so one 4-device process can compare meshes of
1, 2 and 4 side by side (the token-identity gate).

Every constructor here is a FUNCTION, not a module-level constant —
importing this module never touches jax device state (the dry-run sets
XLA_FLAGS in its own process; tier-1 tests keep seeing 1 device).
"""
from __future__ import annotations

import os
import re

import jax
import numpy as np

# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~per-chip usable)

_FORCE_FLAG = "--xla_force_host_platform_device_count"


def ensure_host_devices(n: int) -> None:
    """Arrange for the CPU backend to expose ``n`` devices.

    Appends ``--xla_force_host_platform_device_count=n`` to XLA_FLAGS when
    no forced count is set yet. Must run before the first jax backend
    init (device queries, array creation); once the backend is live the
    device count is frozen, so a too-late call that cannot be honoured
    raises instead of silently serving fewer devices.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if not re.search(rf"{_FORCE_FLAG}=(\d+)", flags):
        os.environ["XLA_FLAGS"] = f"{flags} {_FORCE_FLAG}={int(n)}".strip()
    # device_count() initializes the backend — with the flag just set when
    # it was not live yet (the count comes out right), or frozen at
    # whatever the first jax use saw (then a short count is unfixable)
    if jax.device_count() < n:
        raise RuntimeError(
            f"need {n} host devices but the backend exposes "
            f"{jax.device_count()} (XLA_FLAGS was read at first jax use; "
            f"set {_FORCE_FLAG}={n} before starting the process)")


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    devs = np.asarray(jax.devices())
    if devs.size != int(np.prod(shape)):
        raise ValueError(
            f"production mesh {shape} needs {int(np.prod(shape))} devices, "
            f"found {devs.size}")
    return jax.sharding.Mesh(devs.reshape(shape), axes)


def make_host_mesh(model: int = 1, data: int = None):
    """A small ("data", "model") mesh over the FIRST ``data * model`` host
    devices — for sharded serving/tests on CPU.

    ``data=None`` spreads the remaining devices over the data axis (the
    training default), raising when ``model`` does not divide the device
    count — the previous version floor-divided and handed jax.make_mesh an
    impossible shape. An explicit ``data`` builds exactly that shape and
    supports submeshes (``data * model`` may be less than
    ``jax.device_count()``, so one process compares mesh sizes 1/2/4).
    """
    devs = jax.devices()
    if data is None:
        if model < 1 or len(devs) % model:
            raise ValueError(
                f"model={model} must divide the {len(devs)} host devices "
                f"(or pass data= explicitly for a submesh)")
        data = len(devs) // model
    if model < 1 or data < 1:
        raise ValueError(f"mesh axes must be >= 1, got data={data} "
                         f"model={model}")
    need = data * model
    if need > len(devs):
        raise ValueError(
            f"host mesh ({data}, {model}) needs {need} devices but only "
            f"{len(devs)} exist; set XLA_FLAGS={_FORCE_FLAG}={need} "
            f"before the first jax use (launch.mesh.ensure_host_devices)")
    grid = np.asarray(devs[:need]).reshape(data, model)
    return jax.sharding.Mesh(grid, ("data", "model"))


def replica_submeshes(mesh):
    """Split a ("data", "model") mesh into one (1, model) submesh per data
    row — the per-replica meshes of data-parallel serving (DESIGN.md §12).

    Each engine replica runs its tensor-parallel program on its OWN row of
    devices: replica ``r`` gets ``mesh.devices[r:r+1, :]``, so replica
    state (DecodeState leaves, KV pools) is device_put onto that row and
    replicas never share a device. The data axis itself carries no
    collective — replicas are independent programs behind one host-side
    scheduler — which is why the split is a plain device reshape rather
    than a mesh axis the compiled steps ever see.
    """
    if "data" not in mesh.axis_names or "model" not in mesh.axis_names:
        raise ValueError(
            f"replica_submeshes needs ('data', 'model') axes, got "
            f"{mesh.axis_names}")
    devs = np.asarray(mesh.devices).reshape(
        mesh.shape["data"], mesh.shape["model"])
    return [jax.sharding.Mesh(devs[r:r + 1, :], ("data", "model"))
            for r in range(mesh.shape["data"])]
