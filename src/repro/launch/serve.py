"""Serving launcher: stand up the batched engine and stream synthetic
requests through it.

  PYTHONPATH=src python -m repro.launch.serve --target tiny-target \
      --draft tiny-draft --mode pard --requests 16 --max-new 48 \
      [--target-ckpt a.npz --draft-ckpt b.npz] [--tp 2 --devices 4]

Engine construction goes through the typed ``EngineConfig`` surface
(``EngineConfig.from_args``) and per-request options through
``SamplingParams`` — this launcher doubles as the usage example for both.
``--tp N`` serves tensor-parallel over a (data=1, model=N) mesh
(DESIGN.md §11); ``--dp N`` serves N data-parallel engine replicas behind
one scheduler (DESIGN.md §12); on a CPU-only host pair either with
``--devices M`` to force M >= tp * dp host devices.

Prints per-request latency and aggregate tokens/s — the same metrics as the
paper's Tables 1-4 (benchmarks/ runs this machinery systematically).
"""
import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", required=True)
    ap.add_argument("--draft", default=None)
    ap.add_argument("--target-ckpt", default=None)
    ap.add_argument("--draft-ckpt", default=None)
    ap.add_argument("--mode", default="pard", choices=["ar", "vsd", "pard"])
    ap.add_argument("--tree", default=None, metavar="B1,B2,...",
                    help="tree-structured PARD drafting: per-depth branching "
                         "factors of the candidate tree (e.g. 2,2,2,1); "
                         "overrides --k with the tree depth")
    ap.add_argument("--adaptive-tree", action="store_true",
                    help="per-request tree templates from the default "
                         "chain/balanced/wide bank at depth --k, re-selected "
                         "from EWMA acceptance statistics at admission and "
                         "between windows (DESIGN.md §7); excludes --tree")
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy); works with "
                         "--tree via multi-round sibling acceptance")
    ap.add_argument("--greedy-requests", type=int, default=0, metavar="N",
                    help="submit the first N requests with temperature 0 "
                         "(the rest use --temperature): one batch mixes "
                         "greedy and sampled rows")
    ap.add_argument("--seed", type=int, default=0)
    layout = ap.add_mutually_exclusive_group()
    layout.add_argument("--paged", dest="kv_layout", action="store_const",
                        const="paged", help="block-paged KV cache (default)")
    layout.add_argument("--contiguous", dest="kv_layout",
                        action="store_const", const="contiguous",
                        help="full-length per-slot KV rows")
    ap.set_defaults(kv_layout="paged")
    ap.add_argument("--kv-block-size", type=int, default=64)
    ap.add_argument("--kv-dtype", default="bf16",
                    choices=["bf16", "fp32", "int8", "fp8"],
                    help="KV cache storage dtype; int8/fp8 quantize on "
                         "append with per-(position, head) scales and "
                         "dequantize inside the attention kernels "
                         "(DESIGN.md §10)")
    ap.add_argument("--kv-num-blocks", type=int, default=None,
                    help="paged pool size (default: worst-case coverage)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="refcounted prompt-prefix reuse in the paged pool "
                         "(DESIGN.md §8): same-prefix requests map cached "
                         "blocks copy-free and only prefill their tails")
    ap.add_argument("--prefix-share", type=int, default=1, metavar="N",
                    help="workload mix: requests per distinct system "
                         "prompt (1 = every prompt unique; pair with "
                         "--prefix-cache to see hits)")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    metavar="TOKENS",
                    help="max prompt tokens consumed per step across "
                         "prefilling rows (chunked-prefill lanes; default "
                         "unthrottled)")
    ap.add_argument("--pipelined", action="store_true",
                    help="two-deep dispatch/harvest pipeline: step t+1 is "
                         "dispatched while step t is in flight (DESIGN.md "
                         "§9); token-identical to the synchronous loop")
    ap.add_argument("--tp", type=int, default=1, metavar="N",
                    help="tensor-parallel serving over a (data=1, model=N) "
                         "device mesh: target params + KV heads shard, the "
                         "draft replicates; tokens are identical to --tp 1 "
                         "(DESIGN.md §11)")
    ap.add_argument("--dp", type=int, default=1, metavar="N",
                    help="data-parallel serving: N independent engine "
                         "replicas on a (data=N, model=tp) mesh behind one "
                         "scheduler, routed prefix-affinity-then-least-"
                         "loaded; tokens are identical to --dp 1 "
                         "(DESIGN.md §12). --kv-num-blocks is per replica")
    ap.add_argument("--tp-ruleset", default="exact",
                    choices=["exact", "throughput"],
                    help="tensor-parallel sharding ruleset: 'exact' "
                         "(default) is reduction-free — tokens bitwise "
                         "identical across mesh shapes (DESIGN.md §11); "
                         "'throughput' is Megatron-style row-parallel "
                         "down-projections — one psum per attention block "
                         "/ MLP, tokens match tp1 to tolerance only "
                         "(DESIGN.md §13)")
    ap.add_argument("--devices", type=int, default=None, metavar="M",
                    help="force M host (CPU) devices before jax initializes "
                         "— development/CI stand-in for real accelerators; "
                         "must be >= --tp * --dp")
    args = ap.parse_args()

    if args.devices:
        # must run before anything touches the jax backend
        from repro.launch.mesh import ensure_host_devices
        ensure_host_devices(args.devices)

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.data.pipeline import MarkovCorpus
    from repro.models import init_params
    from repro.serving.engine import Engine, EngineConfig, SamplingParams
    from repro.training import checkpoint

    tc = get_config(args.target)
    tp = init_params(jax.random.PRNGKey(0), tc)
    if args.target_ckpt:
        tp = checkpoint.restore(args.target_ckpt, tp)
    dp = dc = None
    if args.mode != "ar":
        assert args.draft, "--draft required for vsd/pard"
        dc = get_config(args.draft)
        dp = init_params(jax.random.PRNGKey(1), dc)
        if args.draft_ckpt:
            dp = checkpoint.restore(args.draft_ckpt, dp)

    config = EngineConfig.from_args(args)
    tree = config.tree
    eng = Engine(tp, tc, dp, dc, config=config)

    corpus = MarkovCorpus(vocab_size=tc.vocab_size, seed=0, determinism=2.0)
    rng = np.random.default_rng(args.seed)
    share = max(1, args.prefix_share)
    sys_prompts = [corpus.prompts(rng, 1, args.prompt_len)[0]
                   for _ in range(-(-args.requests // share))]
    t0 = time.perf_counter()
    for i in range(args.requests):
        # per-request temperature: the first --greedy-requests rows decode
        # greedily even when the engine default samples (mixed batches)
        temp = 0.0 if i < args.greedy_requests else None
        if share > 1:
            # shared-prefix mix: `share` requests per system prompt, each
            # with a unique tail (the prefix-cache benchmark workload);
            # groups interleave round-robin so same-prefix requests arrive
            # across batch generations — concurrent identical prompts
            # cannot hit (computed gating), later arrivals do
            prompt = np.concatenate([
                sys_prompts[i % len(sys_prompts)],
                np.asarray(corpus.prompts(rng, 1, 8)[0], np.int32)])
        else:
            prompt = corpus.prompts(rng, 1, args.prompt_len)[0]
        eng.submit(prompt, params=SamplingParams(max_new=args.max_new,
                                                 temperature=temp))
    comps = eng.run()                # pipelining comes from config.pipelined
    wall = time.perf_counter() - t0

    total = sum(c.generated for c in comps)
    label = args.mode if tree is None else (
        f"{args.mode}[adaptive {tree.key}]" if args.adaptive_tree
        else f"{args.mode}[tree {args.tree}]")
    if args.temperature:
        label += f"[T={args.temperature}" + (
            f",greedy×{args.greedy_requests}]" if args.greedy_requests
            else "]")
    if args.pipelined:
        label += "[pipelined]"
    if args.tp > 1:
        label += f"[tp={args.tp}]"
    if args.dp > 1:
        label += f"[dp={args.dp}]"
    print(f"\nmode={label} requests={len(comps)} "
          f"generated={total} tokens wall={wall:.2f}s "
          f"throughput={total / wall:.1f} tok/s "
          f"steps/s={eng.stats['steps'] / wall:.1f} "
          f"mean_accepted={eng.mean_accepted():.2f}")
    lats = sorted(c.wall_done - c.wall_submitted for c in comps)
    lat = eng.latency_summary()
    print(f"latency p50={lats[len(lats) // 2]:.2f}s p max={lats[-1]:.2f}s "
          f"ttft_p50={lat['ttft_p50_ms']:.0f}ms "
          f"ttft_p95={lat['ttft_p95_ms']:.0f}ms "
          f"tok_p50={lat['tok_p50_ms']:.1f}ms "
          f"tok_p95={lat['tok_p95_ms']:.1f}ms")
    print(f"host overhead (harvest->dispatch) "
          f"p50={lat['host_overhead_p50_ms']:.2f}ms "
          f"p95={lat['host_overhead_p95_ms']:.2f}ms")
    print(f"kv layout={args.kv_layout} dtype={args.kv_dtype} "
          f"capacity={eng.kv_capacity_bytes() / 1e6:.2f}MB "
          f"peak_in_use={eng.peak_kv_bytes_in_use / 1e6:.2f}MB")
    if args.prefix_cache:
        print(f"prefix cache: hit_rate={eng.prefix_hit_rate():.2f} "
              f"({eng.stats['prefix_hit_blocks']}/"
              f"{eng.stats['prefix_lookup_blocks']} prompt blocks)")
    if args.adaptive_tree:
        hist = eng.stats["tree_hist"]
        per = {t.branching: int(h) for t, h in zip(tree.templates, hist)}
        print(f"adaptive tree: live-steps per template {per} "
              f"switches={eng.stats['tree_switches']}")
    print("engine stats:", eng.stats)


if __name__ == "__main__":
    main()
