"""Step functions + input specs for the multi-pod dry-run and launchers.

Four lowered entry points per architecture (matching the assigned shapes):

  train_step    — AR loss fwd+bwd + AdamW update     (train_4k)
  prefill_step  — cached forward, last-only logits   (prefill_32k)
  decode_step   — ONE new token against a KV cache   (decode_32k, long_500k)
  verify_step   — PARD verification: K+1 drafted tokens in one pass against
                  the same cache (the paper's serving hot path; used by the
                  §Perf analysis and --mode pard_verify)

Every input is a ShapeDtypeStruct (``input_specs``) — nothing allocates.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..core.adaptation import ar_loss
from ..models import (encode, forward, frontend_embed_spec, init_caches,
                      init_params)
from ..models.config import ModelConfig, SSM, scan_plan
from ..training.optimizer import AdamW

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}

PARD_K = 8   # paper's K_train; verify window is K+1 tokens


def _has_ssm(cfg) -> bool:
    plan = scan_plan(cfg)
    return any(s.mixer == SSM for s in plan.prefix + plan.period)


# ---------------------------------------------------------------------------
# Step builders (pure functions of (params, ...); cfg is closed over)
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt: AdamW, *, remat: bool = True):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = ar_loss(
                p, cfg, batch["tokens"], dtype=jnp.bfloat16, aux_weight=0.01,
                frontend_embed=batch.get("frontend_embed"), remat=remat)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, om = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, **om}
    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, caches, batch):
        enc_out = _enc_out(params, cfg, batch)
        b = batch["tokens"].shape[0]
        logits, caches, _ = forward(
            params, cfg, batch["tokens"], caches=caches,
            cache_pos=jnp.zeros((b,), jnp.int32), enc_out=enc_out,
            last_only=True)
        return logits[:, -1], caches
    return prefill_step


def make_decode_step(cfg: ModelConfig, *, window: int = 0):
    """One-token AR decode (the AR+ baseline's steady state)."""
    cfg = cfg if not window else _windowed(cfg, window)

    def decode_step(params, caches, batch):
        enc_out = _enc_out(params, cfg, batch)
        logits, caches, _ = forward(
            params, cfg, batch["tokens"], caches=caches,
            cache_pos=batch["cache_pos"], enc_out=enc_out)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt, caches
    return decode_step


def make_verify_step(cfg: ModelConfig, *, k: int = PARD_K, window: int = 0):
    """PARD verification: K+1 tokens (last committed + K draft proposals)
    verified in ONE forward against the cache; returns per-position argmax
    (greedy acceptance happens host-side / in the engine)."""
    cfg = cfg if not window else _windowed(cfg, window)
    collect = _has_ssm(cfg)

    def verify_step(params, caches, batch):
        enc_out = _enc_out(params, cfg, batch)
        logits, caches, _ = forward(
            params, cfg, batch["tokens"], caches=caches,
            cache_pos=batch["cache_pos"], enc_out=enc_out, collect_ssm=collect)
        tgt = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # [B, K+1]
        return tgt, caches
    return verify_step


def _windowed(cfg: ModelConfig, window: int) -> ModelConfig:
    """Long-context serving variant: every attention layer becomes
    sliding-window (the gemma2/jamba long_500k path; DESIGN.md §4)."""
    import dataclasses
    return dataclasses.replace(cfg, sliding_window=window,
                               local_global_period=0)


def _enc_out(params, cfg, batch):
    fe = batch.get("frontend_embed")
    if fe is None:
        return None
    if cfg.is_encoder_decoder:
        return encode(params, cfg, fe)
    return fe


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs only — no allocation)
# ---------------------------------------------------------------------------

def param_shapes(cfg: ModelConfig, dtype=None):
    sds = jax.eval_shape(functools.partial(init_params, cfg=cfg),
                         jax.random.PRNGKey(0))
    if dtype is not None:
        sds = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), sds)
    return sds


def opt_state_shapes(cfg: ModelConfig, opt: AdamW):
    params = param_shapes(cfg)
    return jax.eval_shape(opt.init, params)


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int,
                 dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(init_caches, cfg, batch, max_len, dtype=dtype))


def input_specs(cfg: ModelConfig, shape_name: str, *, mode: str = "default",
                k: int = PARD_K, cache_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Returns {fn-kwargs-name: ShapeDtypeStruct} for the lowered step."""
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    batch: Dict[str, Any] = {}
    fe = frontend_embed_spec(cfg, b)

    if kind == "train":
        batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if fe is not None:
            batch["frontend_embed"] = fe
        return {"batch": batch}

    if kind == "prefill":
        batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if fe is not None:
            batch["frontend_embed"] = fe
        return {"caches": cache_shapes(cfg, b, s, dtype=cache_dtype),
                "batch": batch}

    # decode / verify: q_len 1 or K+1 against a cache of s positions
    q = 1 if mode != "pard_verify" else k + 1
    batch["tokens"] = jax.ShapeDtypeStruct((b, q), jnp.int32)
    batch["cache_pos"] = jax.ShapeDtypeStruct((b,), jnp.int32)
    if fe is not None:
        batch["frontend_embed"] = fe
    return {"caches": cache_shapes(cfg, b, s, dtype=cache_dtype),
            "batch": batch}
