"""EAGLE-style target-DEPENDENT draft head — the paper's main comparison
point (Fig. 1a, Tables 3/5).

A single transformer layer autoregresses over the target's last-layer
features: input at step t is ``W_fuse [e(x_t); f_{t-1}]`` where f is the
target hidden state (predicted recursively by the head beyond the committed
prefix), and logits reuse the target's unembedding. This captures EAGLE's
two defining properties relative to PARD:

  * higher information (it sees target features) but LOWER standalone
    accuracy than a real pretrained small LM (the paper's Fig. 1a), and
  * target-coupling: the head is trained per target model.

The draft phase is autoregressive (K sequential 1-layer passes) — cheap per
pass but K passes, unlike PARD's single pass.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..models import forward
from ..models import layers as L
from ..models import attention as attn
from ..models.config import ModelConfig

Array = jax.Array


def init_eagle(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p = {
        "fuse": jax.random.normal(ks[0], (2 * d, d), jnp.float32) / math.sqrt(2 * d),
        "layer": {
            "norm1": L.init_rmsnorm(d),
            "mixer": attn.init_gqa(ks[1], cfg),
            "norm2": L.init_rmsnorm(d),
            "mlp": L.init_mlp(ks[2], d, cfg.d_ff, gated=True),
        },
        "out_norm": L.init_rmsnorm(d),
    }
    return p


def _layer_apply(lp, cfg, x, positions, cache, cache_pos):
    h = L.rmsnorm_apply(lp["norm1"], x, cfg.norm_eps)
    y, new_cache = attn.gqa_apply(lp["mixer"], cfg, h, positions,
                                  cache=cache, cache_pos=cache_pos)
    x = x + y
    h2 = L.rmsnorm_apply(lp["norm2"], x, cfg.norm_eps)
    x = x + L.mlp_apply(lp["mlp"], h2)
    return x, new_cache


def eagle_forward(eagle_params, target_params, cfg: ModelConfig, tokens,
                  feats, positions, *, cache=None, cache_pos=None):
    """tokens: [B, T] (x_t); feats: [B, T, D] (f_{t-1}, the target feature
    at the PREVIOUS position). Returns (logits, new_feats f̂_t, cache)."""
    e = L.embed_apply(target_params["embed"], tokens, cfg, dtype=feats.dtype)
    x = jnp.concatenate([e, feats], axis=-1)
    x = jnp.einsum("btd,de->bte", x, eagle_params["fuse"].astype(feats.dtype))
    x, new_cache = _layer_apply(eagle_params["layer"], cfg, x, positions,
                                cache, cache_pos)
    f_hat = x
    h = L.rmsnorm_apply(eagle_params["out_norm"], x, cfg.norm_eps)
    logits = L.unembed_apply(target_params["embed"], h, cfg)
    return logits, f_hat, new_cache


def eagle_loss(eagle_params, target_params, cfg: ModelConfig, tokens,
               *, feat_weight: float = 0.1):
    """Distillation on a token batch: teacher-forced features from the
    target, CE to the target's argmax + feature regression (EAGLE recipe)."""
    t_logits, _, aux = forward(target_params, cfg, tokens, dtype=jnp.float32)
    f = aux["hidden"]                                  # [B, T, D]
    b, t = tokens.shape
    # head inputs at position i: token x_i, feature f_{i-1}
    feats_in = jnp.concatenate([jnp.zeros_like(f[:, :1]), f[:, :-1]], axis=1)
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    logits, f_hat, _ = eagle_forward(eagle_params, target_params, cfg,
                                     tokens, feats_in, pos)
    # predict the target's next-token argmax (greedy distillation)
    labels = jnp.argmax(t_logits[:, 1:], axis=-1)
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    ce = -jnp.mean(jnp.take_along_axis(logp, labels[..., None], -1))
    reg = jnp.mean(jnp.abs(f_hat[:, :-1].astype(jnp.float32) -
                           f[:, 1:].astype(jnp.float32)))
    return ce + feat_weight * reg, {"ce": ce, "feat_l1": reg}


class EagleDecoder:
    """Greedy speculative decoding with an EAGLE head (chain, like the
    paper's Table 3 comparison). Target-side verification is identical to
    SpecDecoder; the draft phase is K sequential head passes."""

    def __init__(self, target_params, cfg: ModelConfig, eagle_params, *,
                 k: int = 4, max_len: int = 1024):
        self.tp, self.cfg, self.ep = target_params, cfg, eagle_params
        self.k, self.max_len = k, max_len
        self._step = None

    def _build_step(self):
        k, cfg = self.k, self.cfg
        from ..models import init_caches
        from .acceptance import _row_take
        from .spec_decode import _row_write

        def step(gen, n, done, tcache, ecache, feat_prev):
            # ---- draft: K sequential head passes --------------------------
            # The head's KV cache persists across iterations: entries for
            # ACCEPTED positions were computed from committed context, so the
            # usual cache_pos rollback semantics apply (rejected tail is
            # re-covered next iteration).
            cur = jnp.take_along_axis(gen, (n - 1)[:, None], axis=1)  # [B,1]
            feats = feat_prev[:, None]                                # [B,1,D]
            props = []
            epos = n - 1
            for j in range(k):
                lg, f_hat, ecache = eagle_forward(
                    self.ep, self.tp, cfg, cur.astype(jnp.int32), feats,
                    epos[:, None] + j, cache=ecache,
                    cache_pos=epos + j)
                pj = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)
                props.append(pj)
                cur = pj[:, None]
                feats = f_hat[:, -1:]
            props = jnp.stack(props, axis=1)                          # [B,K]

            # ---- verify ---------------------------------------------------
            last = jnp.take_along_axis(gen, (n - 1)[:, None], axis=1)
            vin = jnp.concatenate([last.astype(jnp.int32), props], axis=1)
            logits, tcache, aux = forward(self.tp, cfg, vin, caches=tcache,
                                          cache_pos=n - 1)
            hidden = aux["hidden"]                                    # [B,K+1,D]
            tgt = jnp.argmax(logits[:, :k], axis=-1).astype(jnp.int32)
            match = (props == tgt).astype(jnp.int32)
            accepted = jnp.cumprod(match, axis=1)
            a = jnp.sum(accepted, axis=1)
            all_argmax = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            commit_tok = _row_take(all_argmax, a)

            j = jnp.arange(k + 1)[None, :]
            props_ext = jnp.concatenate([props, props[:, -1:]], axis=1)
            vec = jnp.where(j < a[:, None], props_ext,
                            jnp.where(j == a[:, None], commit_tok[:, None], 0))
            old = jax.vmap(lambda g, p: jax.lax.dynamic_slice(
                g, (p,), (k + 1,)))(gen, n)
            vec = jnp.where(done[:, None], old, vec)
            gen = _row_write(gen, vec.astype(gen.dtype), n)
            # feature at the last committed token (input index a)
            feat_next = _row_take(hidden, a)
            feat_next = jnp.where(done[:, None], feat_prev, feat_next)
            new_n = jnp.where(done, n, n + a + 1)
            hist = jnp.sum(jnp.where(done[:, None], 0, accepted), axis=0)
            return (gen, new_n, tcache, ecache, feat_next,
                    jnp.where(done, 0, a), hist)

        return jax.jit(step)

    def generate(self, prompt, max_new: int):
        from ..models import init_caches
        from .spec_decode import SpecStats
        b, p = prompt.shape
        k = self.k
        tcache = init_caches(self.cfg, b, self.max_len)
        ecache = attn.init_gqa_cache(self.cfg, b, self.max_len)

        logits, tcache, aux = jax.jit(
            lambda t, c: forward(self.tp, self.cfg, t, caches=c,
                                 cache_pos=jnp.zeros((t.shape[0],), jnp.int32))
        )(prompt[:, :-1], tcache)
        hidden = aux["hidden"]                # f_0 .. f_{P-2}
        feat_prev = hidden[:, -1]             # f_{P-2}

        # head prefill: populate the head's KV cache over the prompt
        # (teacher-forced features, same layout as eagle_loss)
        feats_in = jnp.concatenate(
            [jnp.zeros_like(hidden[:, :1]), hidden[:, :-1]], axis=1)
        pos = jnp.broadcast_to(jnp.arange(p - 1)[None], (b, p - 1))
        _, _, ecache = jax.jit(
            lambda t, f, pp, c: eagle_forward(
                self.ep, self.tp, self.cfg, t, f, pp, cache=c,
                cache_pos=jnp.zeros((t.shape[0],), jnp.int32))
        )(prompt[:, :-1], feats_in, pos, ecache)

        if self._step is None:
            self._step = self._build_step()

        L_buf = p + max_new + 2 * k + 2
        gen = jnp.zeros((b, L_buf), jnp.int32)
        gen = gen.at[:, :p].set(prompt)
        n = jnp.full((b,), p, jnp.int32)
        done = jnp.zeros((b,), bool)
        target_n = p + max_new
        iters, acc_total, live_iters = 0, 0, 0
        acc_hist = jnp.zeros((k,), jnp.int32)
        while True:
            live = int(jnp.sum(~done))
            gen, n, tcache, ecache, feat_prev, a, hist = self._step(
                gen, n, done, tcache, ecache, feat_prev)
            iters += 1
            live_iters += live
            acc_total += int(jnp.sum(a))
            acc_hist = acc_hist + hist
            done = n >= target_n
            if bool(jnp.all(done)) or iters > max_new + 2:
                break
        stats = SpecStats(iterations=iters,
                          tokens_generated=int(jnp.sum(
                              jnp.minimum(n, target_n) - p)),
                          draft_forwards=iters * k, target_forwards=iters,
                          accept_hist=jax.device_get(acc_hist),
                          acceptance_rate=acc_total / max(live_iters, 1) / k,
                          mean_accepted=acc_total / max(live_iters, 1) + 1.0)
        return gen[:, :target_n], stats
