"""PARD adaptation objective — paper §3.2.1, Eq. 8.

The packed COD batch (core/cod.py) trains all K subtasks simultaneously:
cross-entropy at every token with a label, with the Fig. 4 attention pattern
supplied as (segment, base) metadata. ``per_subtask_norm=True`` reproduces
Eq. 8 exactly (each subtask's loss is averaged over its own token count,
then subtasks are summed); ``False`` is a plain token-mean, useful for
loss-curve comparisons at different r (same estimator across drop rates).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import forward
from ..models.attention import PardMaskInfo
from .cod import IGNORE


def pard_adaptation_loss(params, cfg, batch, *, k_max: int = 0,
                         per_subtask_norm: bool = True, dtype=jnp.bfloat16):
    """batch: dict of [B, T] arrays from cod.pack_batch (jnp or np).

    Returns (loss, metrics).
    """
    seg = jnp.asarray(batch["segment"])
    base = jnp.asarray(batch["base"])
    mask_info = PardMaskInfo(seg, base)
    logits, _, aux = forward(
        params, cfg, jnp.asarray(batch["input_ids"]),
        positions=jnp.asarray(batch["position_ids"]),
        mask_info=mask_info, dtype=dtype)

    labels = jnp.asarray(batch["labels"])
    valid = labels != IGNORE
    safe_labels = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    tok_nll = -jnp.take_along_axis(logp, safe_labels[..., None], axis=-1)[..., 0]
    tok_nll = jnp.where(valid, tok_nll, 0.0)

    metrics = {}
    if per_subtask_norm and k_max:
        total = jnp.zeros((), jnp.float32)
        for s in range(1, k_max + 1):
            sel = valid & (seg == s)
            cnt = jnp.maximum(jnp.sum(sel), 1)
            ls = jnp.sum(jnp.where(sel, tok_nll, 0.0)) / cnt
            metrics[f"loss_subtask_{s}"] = ls
            total = total + ls
        loss = total
    else:
        loss = jnp.sum(tok_nll) / jnp.maximum(jnp.sum(valid), 1)

    metrics["token_mean_nll"] = jnp.sum(tok_nll) / jnp.maximum(jnp.sum(valid), 1)
    metrics["n_loss_tokens"] = jnp.sum(valid)
    if "load_balance_loss" in aux:
        metrics["load_balance_loss"] = aux["load_balance_loss"]
    return loss, metrics


def ar_loss(params, cfg, tokens, *, dtype=jnp.bfloat16, aux_weight: float = 0.0,
            frontend_embed=None, remat: bool = False):
    """Plain next-token AR loss (Eq. 1) — used for pretraining the tiny
    target/draft models and as the non-PARD baseline objective.

    ``frontend_embed`` feeds the audio/vision stub: run through the encoder
    for enc-dec configs, used directly as cross-attention KV for VLMs."""
    tokens = jnp.asarray(tokens)
    enc_out = None
    if frontend_embed is not None:
        from ..models import encode  # local import to avoid cycle
        if cfg.is_encoder_decoder:
            enc_out = encode(params, cfg, frontend_embed)
        else:
            enc_out = frontend_embed
    logits, _, aux = forward(params, cfg, tokens[:, :-1], dtype=dtype,
                             enc_out=enc_out, remat=remat)
    labels = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    if aux_weight and "load_balance_loss" in aux:
        loss = loss + aux_weight * aux["load_balance_loss"]
    return loss, {"nll": jnp.mean(nll)}
