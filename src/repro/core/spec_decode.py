"""Speculative decoding: AR baseline, vanilla SD (AR draft), PARD.

All step functions use fixed shapes (jit-once):

  * the generation buffer ``gen [B, L]`` holds committed tokens; ``n [B]``
    counts them. Commits write a full (K+1)-slot window at offset n — slots
    beyond the accepted count hold garbage that is overwritten before it can
    ever be read (reads are always < n).
  * KV caches are contiguous; speculative rollback = the next call's
    ``cache_pos`` simply re-covers the rejected entries (validity is
    ``index < cache_pos + q_len``, so stale KV is invisible).
  * SSM/hybrid layers cannot roll back by position: the verify forward runs
    with ``collect_ssm=True`` and the engine gathers the per-token state at
    the last accepted index (DESIGN.md §3).

PARD draft (paper Eq. 7): ONE forward of
  [ new committed tokens (A <= K+1) | mask x (K-1) | pad ]   (2K slots)
produces all K proposals: slot A-1 (last real token) proposes token 1, the
K-1 mask slots propose the rest. Plain causal attention over this window
equals the paper's mask-token factorisation because exactly one chain is in
flight at inference time.

VSD draft: the same window advances the committed tokens, then K-1 extra
single-token AR calls — K draft forwards/iteration vs PARD's 1 (Eq. 3 vs 4).

Tree drafting (``TreeTemplate`` / ``TemplateBank``): instead of keeping
only the per-depth argmax chain, the SAME single draft forward populates a
static top-k candidate tree (top-b_d tokens at depth d), and verification
runs one target forward over the packed tree with ancestor-mask attention
(kernels/tree_attention.py, DESIGN.md §6). Greedy verification commits the
longest root path matching the target argmax — still exactly lossless vs
AR — and raises accepted tokens per target forward whenever the target's
argmax lands in the draft's top-b_d but not its top-1. The tree shape is
PER ROW (DESIGN.md §7): ``DecodeState.tree_idx`` selects each row's
template from a ``TemplateBank`` inside the one jitted step, so a batch
mixes chains and wide trees and the serving engine reshapes a request
between windows from its acceptance statistics.

Greedy (temperature 0) verification is exactly lossless vs AR decoding.
Temperature > 0 is PER ROW (``DecodeState.temp``; one batch mixes greedy
and sampled requests): the flat chain uses Leviathan speculative sampling
and the tree uses multi-round recursive rejection sampling over sibling
candidates — both in core/acceptance.py, both provably committing tokens
from the target model's own sampling distribution. Sampling draws come from
per-row PRNG keys (``DecodeState.rngs``), so a request's output depends
only on its own seed and step count, never on batch composition or KV
layout (the seeded-determinism tests in tests/test_sampled_tree.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import forward, init_caches
from ..models.attention import (TreeAttnInfo, paged_flat_index,
                                resolve_kv_dtype)
from ..models.config import (ATTN_GLOBAL, ATTN_LOCAL, ATTN_MLA, SSM,
                             ModelConfig, scan_plan)
from . import acceptance

# re-exported: the flat T>0 acceptance rule lives in core/acceptance.py now
speculative_accept = acceptance.speculative_accept

Array = jax.Array

_ATTN_MIXERS = (ATTN_GLOBAL, ATTN_LOCAL, ATTN_MLA)


def _row_write(buf: Array, vec: Array, pos: Array) -> Array:
    """buf: [B, L]; vec: [B, W]; pos: [B] -> buf with vec written at pos."""
    return jax.vmap(lambda b, v, p: jax.lax.dynamic_update_slice(b, v, (p,)))(
        buf, vec, pos)


def gather_ssm_states(cfg: ModelConfig, collected, accept_idx: Array):
    """Select per-token SSM states at the last accepted index.

    ``collected`` is the new_caches pytree from a ``collect_ssm`` forward:
    SSM entries hold per-token states (conv: [B, T, W-1, C], ssm:
    [B, T, H, P, N]; scanned layers carry a leading repeats dim) while
    attention entries are normal caches. Returns the cache pytree with every
    SSM state set to the state after ``accept_idx[b]`` input tokens.
    """
    plan = scan_plan(cfg)

    def row_gather(leaf):       # [B, T, ...] -> [B, ...]
        return jax.vmap(lambda r, i: jax.lax.dynamic_index_in_dim(
            r, i, 0, False))(leaf, accept_idx)

    def pick(tree, scanned: bool):
        def gather_leaf(leaf):
            if scanned:         # [R, B, T, ...]
                return jax.vmap(row_gather)(leaf)
            return row_gather(leaf)
        return jax.tree.map(gather_leaf, tree)

    out = {"prefix": [], "scan": []}
    for i, spec in enumerate(plan.prefix):
        c = collected["prefix"][i]
        out["prefix"].append(pick(c, False) if spec.mixer == SSM else c)
    for j, spec in enumerate(plan.period):
        c = collected["scan"][j]
        out["scan"].append(pick(c, True) if spec.mixer == SSM else c)
    return out


def _draft_window(gen, n, m, k, mask_id):
    """[B, 2K] PARD draft window: new committed tokens + mask chain."""
    i = jnp.arange(2 * k)[None, :]
    idx = m[:, None] + i
    a = (n - m)[:, None]                          # committed, unprocessed
    tok = jnp.take_along_axis(gen, jnp.clip(idx, 0, gen.shape[1] - 1),
                              axis=1)
    is_real = i < a
    is_mask = (i >= a) & (i < a + (k - 1))
    tok = jnp.where(is_real, tok, jnp.where(is_mask, mask_id, 0))
    return tok.astype(jnp.int32)


def _chunk_window(gen, pf, cl, width):
    """[B, width] prompt chunk starting at the prefill cursor ``pf``; slots
    past the per-row real count ``cl`` are zero pads whose KV writes land
    beyond the cursor and are re-covered by the next chunk / first decode
    window (the standard rollback invariant). ``pf + width`` never reaches
    the buffer end: the engine validates prompt + max_new + window slack
    <= max_len and width <= slack, so the dynamic slice never clamps."""
    tok = jax.vmap(lambda g, p: jax.lax.dynamic_slice(g, (p,), (width,)))(
        gen, pf)
    return jnp.where(jnp.arange(width)[None, :] < cl[:, None], tok,
                     0).astype(jnp.int32)


def _phase(state: "DecodeState"):
    """(prefilling [B], pf [B]) from the state's prefill cursor fields."""
    return state.pf_pos < state.pf_len, state.pf_pos


def _pick_next(logits: Array, temp: Array, keys: Array) -> Array:
    """[B, V] logits -> [B] next token: argmax for temp == 0 rows, a sample
    from softmax(logits / temp) under the row's own key otherwise. The
    sampling branch only executes when some row actually samples, so
    all-greedy batches pay nothing for it."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def samp():
        s = acceptance.row_categorical(
            keys, acceptance.scale_logits(logits, temp))
        return jnp.where(temp > 0, s, greedy)

    return jax.lax.cond(jnp.any(temp > 0), samp, lambda: greedy)


def _topk_indices(logits: Array, k: int) -> Array:
    """Indices of the ``k`` largest logits along the last axis, descending,
    lowest-index tie-break — exactly ``lax.top_k``'s order — via ``k``
    argmax-and-mask passes. XLA:CPU lowers ``top_k`` to a full sort of the
    vocab axis (the single most expensive op in a tree step on small
    models); for the tiny branching factors trees use, a few fused reduce
    passes are far cheaper on every backend."""
    idx = []
    cur = logits
    ar = jnp.arange(logits.shape[-1], dtype=jnp.int32)
    for j in range(k):
        i = jnp.argmax(cur, axis=-1).astype(jnp.int32)
        idx.append(i)
        if j + 1 < k:
            cur = jnp.where(ar == i[..., None], -jnp.inf, cur)
    return jnp.stack(idx, axis=-1)


def _has_ssm(cfg: ModelConfig) -> bool:
    plan = scan_plan(cfg)
    return any(s.mixer == SSM for s in plan.prefix + plan.period)


# ---------------------------------------------------------------------------
# Candidate trees — static templates for tree-structured PARD drafting
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TreeTemplate:
    """Static top-k candidate tree for PARD tree drafting (DESIGN.md §6).

    Built from per-depth branching factors: every node at depth d-1 expands
    into one child per top-k rank c < branching[d-1] of the draft's depth-d
    proposal distribution. PARD's mask-chain draft yields ONE distribution
    per depth (conditioning is on the mask chain, not the sampled branch),
    so siblings across different parents share candidate tokens — but each
    node needs its own slot because the target's verification logits DO
    condition on the actual path.

    Slot 0 is the root (the re-processed last committed token); nodes are
    laid out breadth-first, so a node's parent always precedes it. The whole
    window (1 + num_nodes slots) must fit a uint32 ancestor bitmask: <= 32.
    """
    branching: Tuple[int, ...]
    parent: Any          # np [S] int32; parent[0] = -1
    depth: Any           # np [S] int32; depth[0] = 0
    choice: Any          # np [S] int32; top-k rank at the node's depth
    anc: Any             # np [S] uint32 packed ancestor-or-self bitmask

    @staticmethod
    def from_branching(branching) -> "TreeTemplate":
        """Build the template from per-depth branching factors: every
        node at depth d-1 expands into one child per top-k rank
        ``c < branching[d-1]`` (DESIGN.md §6). Slot 0 is the root; the
        packed ancestor bitmask caps a template at 32 slots."""
        branching = tuple(int(x) for x in branching)
        assert branching and all(x >= 1 for x in branching), branching
        parent, depth, choice = [-1], [0], [0]
        prev, slot = [0], 1
        for d, bd in enumerate(branching, start=1):
            new = []
            for p in prev:
                for c in range(bd):
                    parent.append(p)
                    depth.append(d)
                    choice.append(c)
                    new.append(slot)
                    slot += 1
            prev = new
        assert slot <= 32, (
            f"tree template needs {slot} window slots but the packed "
            f"ancestor bitmask holds 32 (shrink the branching factors)")
        anc = [1]
        for s in range(1, slot):
            anc.append(anc[parent[s]] | (1 << s))
        return TreeTemplate(
            branching=branching,
            parent=np.asarray(parent, np.int32),
            depth=np.asarray(depth, np.int32),
            choice=np.asarray(choice, np.int32),
            anc=np.asarray(anc, np.uint32))

    @staticmethod
    def flat(k: int) -> "TreeTemplate":
        """Degenerate single-branch chain — token-identical to the flat-K
        path (asserted in tests and the serve_tree benchmark)."""
        return TreeTemplate.from_branching((1,) * k)

    @property
    def num_slots(self) -> int:
        """Window slots the packed tree occupies (1 root + num_nodes)."""
        return len(self.parent)          # 1 + num_nodes

    @property
    def num_nodes(self) -> int:
        """Candidate nodes (slots minus the root)."""
        return len(self.parent) - 1

    @property
    def max_depth(self) -> int:
        """Deepest candidate depth — the flat-K analogue of K."""
        return len(self.branching)

    @property
    def is_chain(self) -> bool:
        """True for a single-branch template (the flat-K degenerate)."""
        return all(b == 1 for b in self.branching)


@dataclasses.dataclass(frozen=True)
class TemplateBank:
    """Static bank of candidate-tree templates selectable PER ROW
    (DESIGN.md §7).

    All templates share one depth K (pad branchings with trailing 1s), so
    the single PARD draft window — whose length is 2K — serves every row.
    Slot metadata is padded to the widest template (``max_slots``) and
    stacked, and the jitted tree step gathers each row's arrays by
    ``DecodeState.tree_idx``: one compiled step serves a batch mixing tree
    shapes. Padded slots carry zeroed metadata (anc == 0, depth == 0) and
    are additionally masked by ``nslots``, so they can never be accepted;
    their KV writes land beyond the row's meaningful window and are
    re-covered like any rejected branch.
    """
    templates: Tuple[TreeTemplate, ...]
    parent: Any      # np [T, S] int32 (padded slots 0; slot 0 = -1)
    depth: Any       # np [T, S] int32 (padded slots 0)
    choice: Any      # np [T, S] int32
    anc: Any         # np [T, S] uint32 (padded slots 0)
    child_map: Any   # np [T, S, MB] int32 (0 = absent child)
    nslots: Any      # np [T] int32

    @staticmethod
    def from_templates(templates) -> "TemplateBank":
        """Pack templates (TreeTemplates or raw branching tuples) into
        one bank of stacked per-slot arrays; all templates must share one
        depth so a row can re-select without reshaping the window."""
        templates = tuple(
            t if isinstance(t, TreeTemplate) else
            TreeTemplate.from_branching(t) for t in templates)
        assert templates, "a template bank needs at least one template"
        depths = {t.max_depth for t in templates}
        assert len(depths) == 1, (
            "bank templates must share one depth (pad branchings with "
            f"trailing 1s): {[t.branching for t in templates]}")
        n_t = len(templates)
        s = max(t.num_slots for t in templates)
        mb = max(max(t.branching) for t in templates)
        parent = np.zeros((n_t, s), np.int32)
        depth = np.zeros((n_t, s), np.int32)
        choice = np.zeros((n_t, s), np.int32)
        anc = np.zeros((n_t, s), np.uint32)
        cmap = np.zeros((n_t, s, mb), np.int32)
        for i, t in enumerate(templates):
            ns = t.num_slots
            parent[i, :ns] = t.parent
            depth[i, :ns] = t.depth
            choice[i, :ns] = t.choice
            anc[i, :ns] = t.anc
            cm = acceptance.tree_child_map(t)
            cmap[i, :ns, :cm.shape[1]] = cm
        return TemplateBank(
            templates=templates, parent=parent, depth=depth, choice=choice,
            anc=anc, child_map=cmap,
            nslots=np.asarray([t.num_slots for t in templates], np.int32))

    @staticmethod
    def default(k: int = 4) -> "TemplateBank":
        """The canonical three-shape bank at depth ``k``: a flat-K chain
        (deep, no hedging), a balanced tree and a shallow-wide tree — the
        shapes the adaptive controller arbitrates between. Widths shrink
        until the 32-slot window cap admits them, and each later shape
        must also fit the padded window the earlier picks established:
        the bank pads every template's slot metadata to the widest
        member, so a wide hedge that overruns the balanced tree's slot
        count would tax EVERY adaptive step with padded verify slots
        even when the controller never selects it (at k=4 this picks
        `(3,2,1,1)`, 22 slots, over `(4,2,1,1)`, 29)."""
        def nslots(br):
            slots, width = 1, 1
            for x in br:
                width *= x
                slots += width
            return slots

        shapes, cap = [(1,) * k], 32
        for heads in [[(2, 2, 2), (2, 2), (2,)],
                      [(4, 2), (3, 2), (3,), (2, 2, 2), (2, 2)]]:
            for head in heads:
                br = (head + (1,) * (k - len(head)))[:k]
                if len(head) <= k and nslots(br) <= cap and br not in shapes:
                    shapes.append(br)
                    cap = min(cap, nslots(br))
                    break
        return TemplateBank.from_templates(shapes)

    def __len__(self) -> int:
        return len(self.templates)

    @property
    def max_depth(self) -> int:
        """The bank's single shared template depth."""
        return self.templates[0].max_depth

    @property
    def max_slots(self) -> int:
        """Widest template's slot count — the packed window width."""
        return int(self.parent.shape[1])

    @property
    def max_branching(self) -> int:
        """Widest per-depth branching across the bank (child-map width)."""
        return int(self.child_map.shape[2])

    @property
    def key(self) -> str:
        """Stable id for jit caches / labels."""
        return "|".join("x".join(map(str, t.branching))
                        for t in self.templates)


def compact_tree_caches(cfg: ModelConfig, caches, src_pos, dst_start, depth,
                        tables, block_size):
    """Copy the winning tree path's KV onto the committed positions.

    A tree-verification forward writes the window's KV at per-node cache
    slots ``win_start + s``; the accepted path's slots are generally
    non-contiguous — whether greedy argmax-matching or multi-round sampled
    acceptance picked it (``src_pos`` is acceptance-agnostic: slot of the
    accepted node per depth, identity copy for rejected depths).
    Compaction makes the committed prefix contiguous again:
    for d = 1..depth the entry at ``src_pos[:, d-1]`` is copied to position
    ``dst_start + d - 1`` (rejected depths carry src == dst, an identity
    copy; sources never precede their destination, and the gather completes
    before the scatter). Losing branches' slots land beyond the new
    committed count and are re-covered by the next window's ``cache_pos`` —
    the same rollback invariant as the flat path (kv_pool I4 routes frozen
    rows' copies to the garbage block).

    Touches attention leaves only; SSM states cannot appear under a tree
    target (positional rollback is a precondition, see _build_tree_step).
    """
    plan = scan_plan(cfg)
    dst_pos = dst_start[:, None] + jnp.arange(depth, dtype=jnp.int32)[None]

    def move_contig(leaf):           # [B, S, ...]
        taken = jax.vmap(lambda row, i: row[i])(leaf, src_pos)
        zeros = (0,) * (leaf.ndim - 2)
        return jax.vmap(lambda row, tk, p: jax.lax.dynamic_update_slice(
            row, tk, (p,) + zeros))(leaf, taken, dst_start)

    def move_paged(leaf):            # [NB, bs, ...]
        src = paged_flat_index(tables, src_pos, block_size).reshape(-1)
        dst = paged_flat_index(tables, dst_pos, block_size).reshape(-1)
        pf = leaf.reshape((-1,) + leaf.shape[2:])
        pf = pf.at[dst].set(pf[src])
        return pf.reshape(leaf.shape)

    move = move_contig if tables is None else move_paged

    def one(spec, entry, scanned):
        if spec.mixer not in _ATTN_MIXERS:
            return entry
        fn = jax.vmap(move) if scanned else move
        return jax.tree.map(fn, entry)

    return {
        "prefix": [one(s, caches["prefix"][i], False)
                   for i, s in enumerate(plan.prefix)],
        "scan": [one(s, caches["scan"][j], True)
                 for j, s in enumerate(plan.period)],
    }


# ---------------------------------------------------------------------------
# Decode state — the unified core shared with the serving engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DecodeState:
    """Everything one decode step reads and writes, as one pytree.

    Both ``SpecDecoder.generate_*`` (uniform batch, run-to-completion) and
    the continuous-batching serving engine (ragged slots, admission /
    release between steps) advance a ``DecodeState`` through the SAME jitted
    step functions (``SpecDecoder._build_ar_step`` /  ``_build_spec_step``).

      gen    [B, L]  committed tokens (prompt + generated)
      n      [B]     committed count (reads are always < n)
      m      [B]     draft progress: committed tokens already processed by
                     the draft (n - m = the new-token window)
      done   [B]     frozen rows — steps rewrite their gen/n/m unchanged
      tcache, dcache cache pytrees (contiguous rows or paged pools)
      tables [B, MBS] block tables for the paged KV layout, or None for
                     contiguous (DESIGN.md §5); shared by target and draft
                     since both cache the same absolute positions.
      temp   [B]     per-row sampling temperature (0 = greedy; one batch
                     mixes greedy and sampled requests)
      rngs   [B, 2]  per-row PRNG keys — each step splits every row's key
                     once, so a row's sampling stream depends only on its
                     own seed and its step count (seeded determinism across
                     batch compositions and KV layouts).
      tree_idx [B]   per-row template index into the decoder's
                     ``TemplateBank`` (None when tree drafting is off): the
                     tree step gathers each row's packed tree metadata by
                     this index, so one batch mixes tree shapes and the
                     serving engine's adaptive controller reshapes a
                     request between windows by a single scatter.
      pf_pos [B]     chunked-prefill cursor: prompt tokens already written
                     to the KV caches. A row with ``pf_pos < pf_len`` is in
                     the PREFILLING phase: the chunked step builders feed it
                     prompt chunks instead of draft/verify windows inside
                     the SAME jitted forward as the decoding rows
                     (DESIGN.md §8), commit nothing for it, and advance the
                     cursor on device. ``pf_pos == pf_len`` = decoding.
      pf_len [B]     prompt tokens the row must prefill (prompt length - 1:
                     the last prompt token is re-processed by the first
                     verify window, exactly like the uniform-batch prefill).
    """
    gen: Array
    n: Array
    m: Array
    done: Array
    tcache: Any
    dcache: Any = None
    tables: Optional[Array] = None
    temp: Optional[Array] = None
    rngs: Optional[Array] = None
    tree_idx: Optional[Array] = None
    pf_pos: Optional[Array] = None
    pf_len: Optional[Array] = None


# every field is pytree data (derived from the dataclass so new fields can
# never silently fall out of the jitted steps)
jax.tree_util.register_dataclass(
    DecodeState, [f.name for f in dataclasses.fields(DecodeState)], [])


def prefill_row(params, cfg: ModelConfig, toks: Array, plen, caches, *,
                tables=None, block_size=0, enc_out=None):
    """Prefill ``toks`` [B, T] (right-padded past ``plen``) into ``caches``.

    Shared by SpecDecoder prefills (uniform batch, ``plen=None``: every
    token real, final SSM state already correct) and the engine's bucketed
    per-request admission (T >= plen). Attention KV written at padded
    positions >= plen is never valid (kv_len bookkeeping; in the paged
    layout it lands in the row's own future blocks or the garbage block).
    SSM state cannot be masked after the fact, so with padding present it is
    rolled back to the state after the last REAL token (DESIGN.md §3).
    """
    has = _has_ssm(cfg) and plen is not None
    _, cache, _ = forward(params, cfg, toks, caches=caches,
                          cache_pos=jnp.zeros((toks.shape[0],), jnp.int32),
                          block_tables=tables, kv_block_size=block_size,
                          collect_ssm=has, enc_out=enc_out, last_only=True)
    if has:
        idx = jnp.broadcast_to(jnp.asarray(plen, jnp.int32) - 1,
                               (toks.shape[0],))
        cache = gather_ssm_states(cfg, cache, idx)
    return cache


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SpecStats:
    """Aggregate statistics for one ``generate_*`` run: forward counts,
    acceptance histogram/rates, and wall-clock — the numbers the
    benchmarks and the paper's tables report."""

    iterations: int
    tokens_generated: int
    draft_forwards: int
    target_forwards: int
    accept_hist: Any          # [K] — how often draft position j was accepted
    acceptance_rate: float    # mean accepted drafts / K per iteration
    mean_accepted: float      # mean committed tokens per iteration (a+1)
    round_hist: Any = None    # [max_b] — accepts per sibling rank (tree:
    #                           multi-round rounds / top-k ranks; chain: [1])
    host_overhead_p50_ms: float = 0.0   # wall time between one iteration's
    host_overhead_p95_ms: float = 0.0   # blocking reads and the next dispatch
    # sharded serving only (tools/comm_audit.py, DESIGN.md §13): per-step
    # collective op counts and byte volumes of the compiled fused step —
    # {"all-reduce": n, ...} / total bytes moved. None off-mesh.
    collective_counts: Any = None
    collective_bytes_per_step: Any = None


class SpecDecoder:
    """Bundles target + draft and exposes AR / VSD / PARD generation.

    All public ``generate_*`` methods take ``prompt [B, P]`` (uniform length;
    the batched serving engine in serving/engine.py handles ragged requests)
    and return (tokens [B, P + max_new], SpecStats).
    """

    def __init__(self, target_params, target_cfg: ModelConfig,
                 draft_params=None, draft_cfg: ModelConfig = None, *,
                 k: int = 8, max_len: int = 2048, temperature: float = 0.0,
                 enc_out=None, draft_enc_out=None, kv_block_size: int = 0,
                 tree: Optional[TreeTemplate] = None,
                 prefill_chunk: int = 8, kv_dtype: str = "bf16",
                 mesh=None, tp_ruleset: str = "exact"):
        self.tp, self.tc = target_params, target_cfg
        self.dp, self.dc = draft_params, draft_cfg
        # sharded serving (DESIGN.md §11/§13): the target is tensor-parallel
        # over the mesh's "model" axis under the selected serving ruleset
        # ("exact" = reduction-free output-dim rules, "throughput" =
        # row-parallel down-projections); the draft replicates (it is
        # small, and replicating avoids any cross-device work inside the
        # latency-critical draft window).
        self.mesh = mesh
        self.tp_ruleset = tp_ruleset
        if mesh is not None:
            from ..sharding import specs as _specs
            self.tp = jax.device_put(
                self.tp,
                _specs.to_named(
                    _specs.param_specs(self.tp, mesh, serving=True,
                                       ruleset=tp_ruleset), mesh))
            if self.dp is not None:
                self.dp = jax.device_put(
                    self.dp,
                    _specs.to_named(_specs.replicated_specs(self.dp), mesh))
        if tree is not None:
            # normalise: branching iterable / TreeTemplate / TemplateBank
            # all become a TemplateBank — ONE tree-step implementation
            # serves static single-template and per-row adaptive decoding
            if not isinstance(tree, TemplateBank):
                if not isinstance(tree, TreeTemplate):
                    tree = TreeTemplate.from_branching(tree)
                tree = TemplateBank.from_templates((tree,))
            if _has_ssm(target_cfg):
                raise NotImplementedError(
                    "tree verification relies on positional KV rollback; "
                    "an SSM/hybrid target cannot roll back a packed tree "
                    "window (DESIGN.md §6)")
            # the draft window must produce one proposal distribution per
            # tree depth: K is the bank's depth, whatever was passed
            k = tree.max_depth
        self.tree: Optional[TemplateBank] = tree
        self.k = k
        self.max_len = max_len
        self.temperature = temperature
        self.enc_out = enc_out
        self.draft_enc_out = draft_enc_out
        # 0 = contiguous caches; > 0 = paged pools, steps consume the block
        # tables carried in DecodeState.tables (the serving engine's layout)
        self.kv_block_size = kv_block_size
        # window width of the chunked AR step (engine mode="ar" only; spec
        # and tree chunk widths are bounded by the draft/verify windows —
        # see chunk_width)
        self.prefill_chunk = prefill_chunk
        # KV cache storage dtype ("bf16"/"fp32"/"int8"/"fp8"); quantized
        # dtypes add *_scale cache leaves and change step pytree structure,
        # so it participates in the jit-cache key (_fn)
        self.kv_dtype = kv_dtype
        if draft_cfg is not None:
            assert draft_cfg.vocab_size == target_cfg.vocab_size, \
                "speculative decoding requires a shared tokenizer/vocab"
        self._jit_cache: Dict[str, Any] = {}

    @property
    def window_slack(self) -> int:
        """Positions a step may touch beyond the committed count: the 2K
        draft mask window vs the verify window (K+1 flat, the bank's widest
        template for a tree), +2 slack. Sizes cache rows and contiguous
        allocations; the paged engine allocates per request via
        ``row_slack`` instead (I3). AR decoders (no draft) additionally
        cover the chunked AR step's window: its decode rows carry
        ``prefill_chunk - 1`` pad slots whose KV writes land past the
        committed count and are re-covered next step."""
        verify = self.tree.max_slots if self.tree is not None else self.k + 1
        slack = max(2 * self.k, verify)
        if self.dp is None:
            slack = max(slack, self.prefill_chunk)
        return slack + 2

    @property
    def chunk_width(self) -> int:
        """Prompt tokens one chunked engine step consumes per prefilling
        row (DESIGN.md §8). A single cursor feeds BOTH models, so the
        chunk is bounded by the narrower of the 2K draft mask window and
        the target verify window (K+1 flat / bank max_slots tree); AR
        engines have no draft forward and use ``prefill_chunk``."""
        if self.dp is None:
            return self.prefill_chunk
        verify = self.tree.max_slots if self.tree is not None else self.k + 1
        return min(2 * self.k, verify)

    def row_slack(self, tmpl_idx: int) -> int:
        """Window slack for ONE request pinned to bank template
        ``tmpl_idx``: its own verify window instead of the bank-wide
        widest. Paged allocations sized with this still satisfy I3 — the
        batch writes the widest window, but a row's writes past its own
        template land in the garbage block and are never read (the row's
        ancestor masks and acceptance only cover its own slots)."""
        assert self.tree is not None, "row_slack applies to tree drafting"
        return max(2 * self.k, int(self.tree.nslots[tmpl_idx])) + 2

    @property
    def min_row_slack(self) -> int:
        """The smallest per-request slack any bank template needs (the
        admission feasibility bound for ``Engine.submit``)."""
        if self.tree is None:
            return self.window_slack
        return min(self.row_slack(i) for i in range(len(self.tree)))

    # -- jitted primitives ------------------------------------------------
    def _fn(self, name, builder, donate=()):
        name = f"{name}@{self.kv_dtype}@{self.tp_ruleset}"
        if name not in self._jit_cache:
            fn = jax.jit(builder, donate_argnums=donate)
            if self.mesh is not None:
                # trace under the activation mesh + ruleset so the
                # forward's partial/gather_activation hints bake into the
                # computation (DESIGN.md §11/§13)
                mesh, ruleset = self.mesh, self.tp_ruleset

                def fn(*a, _jitted=fn, **kw):
                    from ..kernels import ops as _ops
                    with _ops.activation_mesh(mesh, ruleset):
                        return _jitted(*a, **kw)
            self._jit_cache[name] = fn
        return self._jit_cache[name]

    def _target_forward(self, tokens, caches, cache_pos, tables=None,
                        collect_ssm=False, positions=None, tree_info=None):
        return forward(self.tp, self.tc, tokens, positions=positions,
                       caches=caches, cache_pos=cache_pos,
                       enc_out=self.enc_out, collect_ssm=collect_ssm,
                       block_tables=tables, kv_block_size=self.kv_block_size,
                       tree_info=tree_info)

    def _draft_forward(self, tokens, caches, cache_pos, tables=None,
                       collect_ssm=False):
        return forward(self.dp, self.dc, tokens, caches=caches,
                       cache_pos=cache_pos, enc_out=self.draft_enc_out,
                       collect_ssm=collect_ssm, block_tables=tables,
                       kv_block_size=self.kv_block_size)

    # ----------------------------------------------------------------- AR
    def _build_ar_step(self, chunked: bool = False):
        """One AR decode step over a DecodeState (the AR+ baseline and the
        engine's mode="ar" — one shared implementation). Rows with
        ``state.temp == 0`` commit the argmax; rows with temp > 0 sample
        from softmax(logits / temp) under their own PRNG key.

        ``chunked=True`` (engine only): the window widens to
        ``prefill_chunk`` slots so PREFILLING rows consume prompt chunks in
        the same forward (DESIGN.md §8). Decoding rows carry their last
        token at slot 0 plus pads whose KV writes land past the committed
        count and are re-covered next step (causal masking keeps slot 0's
        logits exact); the uniform-batch path keeps the 1-wide window."""
        w = self.prefill_chunk if chunked else 1

        def step(state: DecodeState) -> DecodeState:
            gen, n, done, temp = state.gen, state.n, state.done, state.temp
            next_keys, use = acceptance.split_row_keys(state.rngs)
            last = jnp.take_along_axis(gen, (n - 1)[:, None], axis=1)
            toks = last.astype(jnp.int32)
            cp = n - 1
            if chunked:
                prefilling, pf = _phase(state)
                cl = jnp.minimum(w, state.pf_len - pf)
                toks = jnp.pad(toks, ((0, 0), (0, w - 1)))
                toks = jnp.where(prefilling[:, None],
                                 _chunk_window(gen, pf, cl, w), toks)
                cp = jnp.where(prefilling, pf, cp)
                # sampling streams are untouched while prefilling (see
                # _build_spec_step)
                next_keys = jnp.where(prefilling[:, None], state.rngs,
                                      next_keys)
            logits, tcache, _ = self._target_forward(
                toks, state.tcache, cp, state.tables)
            nxt = _pick_next(logits[:, 0], temp, use)
            gen2 = jax.vmap(
                lambda g, t, p: jax.lax.dynamic_update_slice(g, t[None], (p,))
            )(gen, nxt, n)
            frozen = (done | prefilling) if chunked else done
            gen = jnp.where(frozen[:, None], gen, gen2)
            n = jnp.where(frozen, n, n + 1)
            return dataclasses.replace(
                state, gen=gen, n=n, tcache=tcache, rngs=next_keys,
                pf_pos=(state.pf_pos if not chunked else
                        jnp.where(prefilling, pf + cl, state.pf_pos)))
        return step

    def init_state(self, prompt: Array, gen_len: int,
                   with_draft: bool = True, seed: int = 0) -> DecodeState:
        """Contiguous-layout DecodeState for a uniform-length batch (the
        engine builds its own paged state from serving.kv_pool). Row b's
        PRNG key derives from (seed, b)."""
        b, p = prompt.shape
        gen = jnp.zeros((b, gen_len), jnp.int32)
        gen = gen.at[:, :p].set(prompt)
        return DecodeState(
            gen=gen, n=jnp.full((b,), p, jnp.int32),
            m=jnp.full((b,), p - 1, jnp.int32), done=jnp.zeros((b,), bool),
            tcache=init_caches(self.tc, b, self.max_len,
                               dtype=resolve_kv_dtype(self.kv_dtype)),
            dcache=(init_caches(self.dc, b, self.max_len,
                                dtype=resolve_kv_dtype(self.kv_dtype))
                    if with_draft and self.dc is not None else None),
            temp=jnp.full((b,), self.temperature, jnp.float32),
            rngs=acceptance.make_row_keys(seed, np.arange(b)),
            tree_idx=(jnp.zeros((b,), jnp.int32)
                      if self.tree is not None else None),
            pf_pos=jnp.zeros((b,), jnp.int32),
            pf_len=jnp.zeros((b,), jnp.int32))

    def generate_ar(self, prompt: Array, max_new: int, seed: int = 0):
        """Plain autoregressive decoding (the losslessness reference):
        ``[B, P] -> ([B, P + max_new] tokens, SpecStats)``."""
        b, p = prompt.shape
        state = self.init_state(prompt, p + max_new + 1, with_draft=False,
                                seed=seed)

        # AR prefill covers the WHOLE prompt: its last logits commit the
        # first new token, so exactly max_new forwards produce max_new
        # tokens (unlike spec prefills, which stop at prompt[:-1] and let
        # the first verify window re-read x_{P-1})
        def pre(toks, c, temp, keys):
            logits, c, _ = self._target_forward(
                toks, c, jnp.zeros((toks.shape[0],), jnp.int32))
            return _pick_next(logits[:, -1], temp, keys), c
        prefill = self._fn("ar_prefill", pre, donate=(1,))
        step = self._fn("ar_step", self._build_ar_step(), donate=(0,))

        next_keys, use = acceptance.split_row_keys(state.rngs)
        first, tcache = prefill(prompt, state.tcache, state.temp, use)
        state = dataclasses.replace(
            state, gen=state.gen.at[:, p].set(first),
            n=state.n + 1, tcache=tcache, rngs=next_keys)
        for _ in range(max_new - 1):
            state = step(state)
        tokens = state.gen[:, :p + max_new]
        stats = SpecStats(max_new, max_new * b, 0, max_new, None, 0.0, 1.0)
        return tokens, stats

    def _pard_depth_logits(self, gen, n, m, dcache, tables, pfinfo=None):
        """ONE PARD draft forward (Eq. 7): proposal logits for every depth
        1..K. Slot A-1 (the last real token) proposes depth 1, the K-1 mask
        slots the rest. Returns (lg [B, K, V], new draft cache).

        ``pfinfo = (prefilling, pf, cl)`` (chunked engine steps only):
        prefilling rows consume a ``cl``-token prompt chunk at cursor ``pf``
        through the SAME forward instead of the mask window — their proposal
        logits are garbage and masked out by the caller's commit logic."""
        k, dc = self.k, self.dc
        d_has_ssm = _has_ssm(dc)
        tok = _draft_window(gen, n, m, k, dc.mask_token_id)
        pos = m
        ssm_idx = n - m - 1          # state after the last real token (A-1)
        if pfinfo is not None:
            prefilling, pf, cl = pfinfo
            chunk = _chunk_window(gen, pf, cl, 2 * k)
            tok = jnp.where(prefilling[:, None], chunk, tok)
            pos = jnp.where(prefilling, pf, pos)
            ssm_idx = jnp.where(prefilling, cl - 1, ssm_idx)
        logits, dcache, _ = self._draft_forward(
            tok, dcache, pos, tables, collect_ssm=d_has_ssm)
        if d_has_ssm:
            dcache = gather_ssm_states(dc, dcache, ssm_idx)
        a = n - m
        sl = (a - 1)[:, None] + jnp.arange(k)[None, :]
        lg = jax.vmap(lambda row, s: row[s])(logits, sl)   # [B, K, V]
        return lg, dcache

    # ------------------------------------------------------------- shared
    def _build_spec_step(self, mode: str, chunked: bool = False,
                         greedy_only: bool = False):
        """One flat speculative step. ``chunked=True`` (the serving
        engine's unified step, DESIGN.md §8) additionally consumes a
        ``chunk_width``-token prompt chunk for every PREFILLING row
        (``state.pf_pos < state.pf_len``) inside the same draft + verify
        forwards: prefilling rows substitute chunk tokens / cursor
        positions for the draft and verify windows, commit nothing, and
        advance ``pf_pos`` on device — admission never runs a standalone
        prefill forward and decoding rows never stall.

        ``greedy_only=True``: compile-time removal of the sampled branches
        and the per-step PRNG key splitting (see _build_tree_step) — token-
        identical for batches where no live row samples."""
        k = self.k
        tc, dc = self.tc, self.dc
        mask_id = dc.mask_token_id
        t_has_ssm = _has_ssm(tc)
        d_has_ssm = _has_ssm(dc)
        cw = self.chunk_width                           # == k + 1 (flat)

        def propose_pard(gen, n, m, dcache, tables, temp, dkeys, pfinfo):
            lg, dcache = self._pard_depth_logits(gen, n, m, dcache, tables,
                                                 pfinfo)
            greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            if greedy_only:
                return greedy, None, dcache, 1              # 1 draft forward
            scaled = acceptance.scale_logits(lg, temp)      # [B, K, V]

            def samp():
                s = jax.vmap(lambda kk, row: jax.random.categorical(kk, row))(
                    dkeys, scaled).astype(jnp.int32)        # [B, K]
                return jnp.where((temp > 0)[:, None], s, greedy)

            props = jax.lax.cond(jnp.any(temp > 0), samp, lambda: greedy)
            return props, scaled, dcache, 1                 # 1 draft forward

        def propose_vsd(gen, n, m, dcache, tables, temp, dkeys, pfinfo):
            # call 1: advance committed window, propose token 1
            tok = _draft_window(gen, n, m, k, mask_id)[:, :k + 1]  # reals only
            pos = m
            ssm_idx = n - m - 1
            if pfinfo is not None:
                prefilling, pf, cl = pfinfo
                tok = jnp.where(prefilling[:, None],
                                _chunk_window(gen, pf, cl, k + 1), tok)
                pos = jnp.where(prefilling, pf, pos)
                ssm_idx = jnp.where(prefilling, cl - 1, ssm_idx)
            logits, dcache, _ = self._draft_forward(
                tok, dcache, pos, tables, collect_ssm=d_has_ssm)
            a = n - m
            if d_has_ssm:
                # roll SSM state back to "after the last real token"; the AR
                # proposal calls below advance a throwaway copy, the next
                # iteration restarts from this snapshot.
                dcache = gather_ssm_states(dc, dcache, ssm_idx)
            snapshot = dcache
            lg_list = [jax.vmap(lambda row, i: row[i])(logits, a - 1)]
            props = []
            cur_pos = n
            for j in range(k - 1 + 1):
                lgj = lg_list[-1]
                if greedy_only:
                    pj = jnp.argmax(lgj, axis=-1).astype(jnp.int32)
                else:
                    pj = _pick_next(lgj, temp,
                                    acceptance.fold_row_keys(dkeys, j))
                props.append(pj)
                if j == k - 1:
                    break
                lgn, dcache, _ = self._draft_forward(pj[:, None], dcache,
                                                     cur_pos, tables)
                cur_pos = cur_pos + 1
                lg_list.append(lgn[:, 0])
            props = jnp.stack(props, axis=1)                # [B, K]
            if greedy_only:
                return props, None, snapshot, k             # K draft forwards
            scaled = acceptance.scale_logits(
                jnp.stack(lg_list, axis=1), temp)           # [B, K, V]
            return props, scaled, snapshot, k               # K draft forwards

        propose = propose_pard if mode == "pard" else propose_vsd

        def step(state: DecodeState):
            gen, n, m, done = state.gen, state.n, state.m, state.done
            tcache, dcache, tables = state.tcache, state.dcache, state.tables
            temp = state.temp
            if greedy_only:
                next_keys = state.rngs          # streams never consumed
                dkeys = akeys = None
            else:
                next_keys, use = acceptance.split_row_keys(state.rngs)
                dkeys = acceptance.fold_row_keys(use, 0)
                akeys = acceptance.fold_row_keys(use, 1)
            pfinfo = None
            if chunked:
                prefilling, pf = _phase(state)
                cl = jnp.minimum(cw, state.pf_len - pf)
                pfinfo = (prefilling, pf, cl)
                # a prefilling row does not consume its sampling stream, so
                # a request's sampled trajectory is invariant to HOW its
                # prompt was prefilled (chunk schedule, prefix-cache hits)
                if not greedy_only:
                    next_keys = jnp.where(prefilling[:, None], state.rngs,
                                          next_keys)
            props, scaled_q, dcache, n_draft = propose(gen, n, m, dcache,
                                                       tables, temp, dkeys,
                                                       pfinfo)

            # verify window: [last committed, d_1..d_K]
            last = jnp.take_along_axis(gen, (n - 1)[:, None], axis=1)
            vin = jnp.concatenate([last.astype(jnp.int32), props], axis=1)
            vpos = n - 1
            if chunked:
                vin = jnp.where(prefilling[:, None],
                                _chunk_window(gen, pf, cl, k + 1), vin)
                vpos = jnp.where(prefilling, pf, vpos)
            logits, tcache_new, _ = self._target_forward(
                vin, tcache, vpos, tables, collect_ssm=t_has_ssm)

            # acceptance (core/acceptance.py): greedy rule for temp == 0
            # rows, Leviathan sampling for temp > 0 rows — row-selected so
            # one batch mixes both; the sampled branch (softmaxes + accept
            # draws) only executes when some row actually samples
            a_g, acc_g, commit_g = acceptance.greedy_chain_accept(
                logits, props)

            if greedy_only:
                a, accepted, commit_tok = a_g, acc_g, commit_g
            else:
                def samp_accept():
                    qprob = jax.nn.softmax(scaled_q, axis=-1)   # [B, K, V]
                    p_full = acceptance.temp_softmax(logits, temp)
                    return acceptance.leviathan_accept(p_full, qprob, props,
                                                       akeys)

                a_s, acc_s, commit_s = jax.lax.cond(
                    jnp.any(temp > 0), samp_accept,
                    lambda: (jnp.zeros_like(a_g), jnp.zeros_like(acc_g),
                             jnp.zeros_like(commit_g)))
                sampled = temp > 0
                a = jnp.where(sampled, a_s, a_g)
                accepted = jnp.where(sampled[:, None], acc_s, acc_g)
                commit_tok = jnp.where(sampled, commit_s, commit_g)

            # frozen rows commit nothing: done rows stay done, prefilling
            # rows consumed a prompt chunk instead of a verify window
            frozen = (done | prefilling) if chunked else done

            # committed tokens this iteration: d_1..d_a, then commit_tok
            j = jnp.arange(k + 1)[None, :]
            props_ext = jnp.concatenate([props, props[:, -1:]], axis=1)
            vec = jnp.where(j < a[:, None], props_ext,
                            jnp.where(j == a[:, None], commit_tok[:, None], 0))
            # frozen rows: rewrite what's already there
            old = jax.vmap(lambda g, p: jax.lax.dynamic_slice(g, (p,), (k + 1,)))(
                gen, n)
            vec = jnp.where(frozen[:, None], old, vec)
            gen = _row_write(gen, vec.astype(gen.dtype), n)

            n_commit = jnp.where(frozen, 0, a + 1)
            new_m = jnp.where(frozen, m, n)
            new_n = n + n_commit

            if t_has_ssm:
                # state after input index a (last committed token processed);
                # prefilling rows keep the state after their chunk's last
                # REAL token (pads excluded — DESIGN.md §3 unchanged)
                ssm_idx = a if not chunked else jnp.where(prefilling, cl - 1,
                                                          a)
                tcache_new = gather_ssm_states(tc, tcache_new, ssm_idx)
            # frozen rows keep old caches? their cache contents are untouched
            # at positions < n and never read beyond; safe to keep new buffers.
            acc_hist = jnp.sum(
                jnp.where(frozen[:, None], 0, accepted), axis=0)  # [K]
            # chain = one sibling per depth: round 0 holds every accept
            round_hist = jnp.sum(
                jnp.where(frozen, 0, a))[None].astype(jnp.int32)
            # per-row accepted rank (chain: rank 0 everywhere it accepted;
            # -1 rejected/frozen) — the adaptive tree controller's signal,
            # shaped like the tree step's so callers share one unpacking
            rank = jnp.where(
                (jnp.arange(1, k + 1)[None, :] <= a[:, None])
                & ~frozen[:, None], 0, -1).astype(jnp.int32)
            new_state = dataclasses.replace(
                state, gen=gen, n=new_n, m=new_m, tcache=tcache_new,
                dcache=dcache, rngs=next_keys,
                pf_pos=(state.pf_pos if not chunked else
                        jnp.where(prefilling, pf + cl, state.pf_pos)))
            return new_state, jnp.where(frozen, 0, a), acc_hist, round_hist, \
                rank, n_draft

        return step

    # --------------------------------------------------------------- tree
    def _build_tree_step(self, chunked: bool = False,
                         greedy_only: bool = False):
        """One tree-verification step over PER-ROW templates (DESIGN.md
        §6/§7).

        ``greedy_only=True`` compiles a variant with the sampled machinery
        removed at trace time — no ``lax.cond`` fusion barriers, no per-step
        threefry key splitting (the per-row serial while-loops XLA:CPU
        lowers them to). Callers select it when no live row samples (host
        knowledge at dispatch time); tokens are identical either way because
        greedy output never reads the PRNG streams, and a sampled row's key
        is freshly (seed, rid)-derived at admission.

        Each row's packed tree metadata (ancestor bitmasks, parent/depth/
        choice arrays, child map, slot count) is gathered from the static
        ``TemplateBank`` by ``state.tree_idx``, so one jitted step serves a
        batch mixing tree shapes — a bank of one reproduces the old static
        behaviour exactly. Draft: ONE PARD forward (the flat mask window)
        yields one proposal distribution per depth. Greedy rows
        (state.temp == 0) populate their template with the top-b_d tokens
        per depth; sampled rows draw every node i.i.d. from its depth's
        softmax(logits / temp). Verify: ONE target forward over the packed
        tree with ancestor-mask attention, logical positions root+depth;
        per-row window lengths (``TreeAttnInfo.win_len``) bound each row's
        KV sweep to its own template. Commit (core/acceptance.py,
        row-selected): greedy rows keep the longest root path matching the
        target argmax — exactly the AR greedy sequence — while sampled rows
        run multi-round recursive rejection sampling over each surviving
        node's children, committing tokens distributed exactly as the
        target model's own sampling distribution. Only the winning path's
        KV survives: compact_tree_caches moves it onto the committed
        positions; losing branches (and slots past a row's template) are
        re-covered by the next window's cache_pos like flat-K rejects.

        ``chunked=True``: prefilling rows ride the same two forwards with
        prompt chunks (DESIGN.md §8). In the packed tree window a chunk is
        just a CAUSAL "tree": ancestor bitmask = all-lower-bits, win_len =
        the chunk's real token count, positions = cursor + slot — so the
        tree kernels serve mixed prefill/decode batches unchanged.
        """
        bank = self.tree
        tc, dc = self.tc, self.dc
        assert bank is not None
        d, s = bank.max_depth, bank.max_slots
        max_b = bank.max_branching
        cw = self.chunk_width                       # min(2K, max_slots)
        # causal ancestor-or-self bitmask: window slot i sees slots 0..i
        chain_anc = (~jnp.uint32(0)) >> jnp.uint32(31 - jnp.arange(s))
        bank_parent = jnp.asarray(bank.parent)                     # [T, S]
        bank_depth = jnp.asarray(bank.depth)
        bank_choice = jnp.asarray(bank.choice)
        bank_anc = jnp.asarray(bank.anc)                           # [T, S]
        bank_cmap = jnp.asarray(bank.child_map)                    # [T,S,MB]
        bank_nslots = jnp.asarray(bank.nslots)                     # [T]

        def step(state: DecodeState):
            gen, n, m, done = state.gen, state.n, state.m, state.done
            tcache, dcache, tables = state.tcache, state.dcache, state.tables
            temp = state.temp
            if greedy_only:
                next_keys = state.rngs          # streams never consumed
                dkeys = akeys = None
            else:
                next_keys, use = acceptance.split_row_keys(state.rngs)
                dkeys = acceptance.fold_row_keys(use, 0)
                akeys = acceptance.fold_row_keys(use, 1)

            # per-row template metadata, gathered from the static bank
            sel = state.tree_idx
            parent, depth = bank_parent[sel], bank_depth[sel]      # [B, S]
            choice, anc = bank_choice[sel], bank_anc[sel]
            cmap, nslots = bank_cmap[sel], bank_nslots[sel]
            node_depth = depth[:, 1:]                              # [B, N]

            pfinfo = None
            if chunked:
                prefilling, pf = _phase(state)
                cl = jnp.minimum(cw, state.pf_len - pf)
                pfinfo = (prefilling, pf, cl)
                # prefilling rows keep their sampling stream untouched (see
                # _build_spec_step): sampled output is prefill-schedule- and
                # prefix-cache-invariant
                if not greedy_only:
                    next_keys = jnp.where(prefilling[:, None], state.rngs,
                                          next_keys)

            # draft: depth distributions -> per-row template tokens. One
            # top-max_b per depth covers every template's ranks;
            # _topk_indices and argmax share lowest-index tie-breaking, so
            # rank 0 IS the flat path's argmax (degenerate-chain identity).
            lg, dcache = self._pard_depth_logits(gen, n, m, dcache, tables,
                                                 pfinfo)
            topk = _topk_indices(lg, max_b)                        # [B,D,MB]
            di = jnp.maximum(node_depth - 1, 0)
            per_node = jnp.take_along_axis(
                topk, di[:, :, None], axis=1)                      # [B,N,MB]
            props_g = jnp.take_along_axis(
                per_node, choice[:, 1:, None], axis=2)[..., 0]     # [B, N]
            if greedy_only:
                props = props_g
            else:
                # sampled rows: i.i.d. candidates per node (multi-round
                # acceptance requires sibling draws from q, not top-k); the
                # per-node draws only execute when some row actually samples
                scaled = acceptance.scale_logits(lg, temp)         # [B,D,V]
                any_sampled = jnp.any(temp > 0)
                props_s = jax.lax.cond(
                    any_sampled,
                    lambda: acceptance.sample_tree_props_rows(
                        scaled, node_depth, dkeys),
                    lambda: props_g)
                sampled = temp > 0
                props = jnp.where(sampled[:, None], props_s, props_g)

            # verify: one target forward over the packed tree; per-row
            # win_len bounds each row's window to its own template
            last = jnp.take_along_axis(gen, (n - 1)[:, None], axis=1)
            vin = jnp.concatenate([last.astype(jnp.int32), props], axis=1)
            positions = (n - 1)[:, None] + depth
            win_start, win_anc, win_len = n - 1, anc, nslots
            if chunked:
                # prefilling rows: a cl-token causal chunk through the same
                # packed window (pads past cl are invisible and re-covered).
                # Slice at the chunk width cw — guaranteed inside the gen
                # buffer by the slack validation — and pad to the window:
                # slicing at s (up to 32) could clamp near max_len and
                # silently shift the chunk.
                chunk = _chunk_window(gen, pf, cl, cw)
                chunk = jnp.pad(chunk, ((0, 0), (0, s - cw)))
                vin = jnp.where(prefilling[:, None], chunk, vin)
                positions = jnp.where(
                    prefilling[:, None],
                    pf[:, None] + jnp.arange(s)[None, :], positions)
                win_start = jnp.where(prefilling, pf, win_start)
                win_anc = jnp.where(prefilling[:, None], chain_anc[None, :],
                                    win_anc)
                win_len = jnp.where(prefilling, cl, win_len)
            tinfo = TreeAttnInfo(win_start=win_start, anc=win_anc,
                                 win_len=win_len)
            logits, tcache_new, _ = self._target_forward(
                vin, tcache, win_start, tables, positions=positions,
                tree_info=tinfo)

            # acceptance (core/acceptance.py), row-selected greedy/sampled;
            # the multi-round machinery only executes when a row samples
            a_g, tok_g, slot_g, commit_g, rank_g = \
                acceptance.greedy_tree_accept_rows(
                    logits, props, parent, depth, choice, anc, nslots, d)

            if greedy_only:
                a, tok_depth, src_slot = a_g, tok_g, slot_g
                commit_tok, rank = commit_g, rank_g
            else:
                def samp_accept():
                    p_full = acceptance.temp_softmax(logits, temp)  # [B,S,V]
                    q_depth = jax.nn.softmax(scaled, axis=-1)       # [B,D,V]
                    return acceptance.sampled_tree_accept_rows(
                        p_full, q_depth, props, cmap, akeys, d, max_b)

                a_s, tok_s, slot_s, commit_s, rank_s = jax.lax.cond(
                    any_sampled, samp_accept,
                    lambda: (jnp.zeros_like(a_g), jnp.zeros_like(tok_g),
                             jnp.zeros_like(slot_g), jnp.zeros_like(commit_g),
                             jnp.full_like(rank_g, -1)))
                a = jnp.where(sampled, a_s, a_g)
                tok_depth = jnp.where(sampled[:, None], tok_s, tok_g)
                src_slot = jnp.where(sampled[:, None], slot_s, slot_g)
                commit_tok = jnp.where(sampled, commit_s, commit_g)
                rank = jnp.where(sampled[:, None], rank_s, rank_g)  # [B, D]

            # frozen rows commit nothing: done rows stay done, prefilling
            # rows consumed a prompt chunk instead of a verify window
            frozen = (done | prefilling) if chunked else done

            dflt = jnp.arange(1, d + 1, dtype=jnp.int32)[None, :]
            # rejected depths and frozen rows: identity copy (src == dst)
            src_slot = jnp.where((src_slot > 0) & ~frozen[:, None],
                                 src_slot, dflt)

            # committed tokens this iteration: path d_1..d_a, then commit_tok
            j = jnp.arange(d + 1)[None, :]
            tok_ext = jnp.concatenate([tok_depth, tok_depth[:, -1:]], axis=1)
            vec = jnp.where(j < a[:, None], tok_ext,
                            jnp.where(j == a[:, None], commit_tok[:, None], 0))
            old = jax.vmap(lambda g, p: jax.lax.dynamic_slice(
                g, (p,), (d + 1,)))(gen, n)
            vec = jnp.where(frozen[:, None], old, vec)
            gen = _row_write(gen, vec.astype(gen.dtype), n)

            # only the winning path's KV survives at committed positions
            src_pos = (n - 1)[:, None] + src_slot                  # [B, D]
            tcache_new = compact_tree_caches(
                tc, tcache_new, src_pos, n, d, tables, self.kv_block_size)

            n_commit = jnp.where(frozen, 0, a + 1)
            new_m = jnp.where(frozen, m, n)
            new_n = n + n_commit
            hist = jnp.sum(
                jnp.where(frozen[:, None], 0,
                          (a[:, None] > jnp.arange(d)[None, :])
                          .astype(jnp.int32)), axis=0)             # [D]
            # per-round accept counts: which sibling rank won at each
            # accepted depth (rank == -1 where the depth rejected)
            valid = (rank >= 0) & ~frozen[:, None]                 # [B, D]
            round_hist = jnp.sum(
                (rank[:, :, None] == jnp.arange(max_b)[None, None, :])
                & valid[:, :, None], axis=(0, 1)).astype(jnp.int32)
            rank = jnp.where(frozen[:, None], -1, rank)
            new_state = dataclasses.replace(
                state, gen=gen, n=new_n, m=new_m, tcache=tcache_new,
                dcache=dcache, rngs=next_keys,
                pf_pos=(state.pf_pos if not chunked else
                        jnp.where(prefilling, pf + cl, state.pf_pos)))
            return new_state, jnp.where(frozen, 0, a), hist, round_hist, \
                rank, 1

        return step

    def generate_spec(self, prompt: Array, max_new: int, mode: str = "pard",
                      seed: int = 0, tree_idx=None):
        """``tree_idx`` ([B] ints) pins each row to a bank template for the
        whole run (tree drafting only; default: template 0 — with a
        single-template bank, exactly the old static behaviour)."""
        assert self.dp is not None, "spec decoding requires a draft model"
        if self.tree is not None:
            assert mode == "pard", "tree templates require mode='pard'"
        else:
            assert tree_idx is None, "tree_idx requires a TemplateBank"
        b, p = prompt.shape
        k = self.k
        # Both prefills stop at prompt[:-1]: the verify window re-processes
        # x_{P-1} (an idempotent KV rewrite for attention — but SSM state
        # must NOT see it twice, so it is excluded here).
        assert p >= 2, "prompts must have at least 2 tokens"
        L = p + max_new + self.window_slack   # room for the final window
        state = self.init_state(prompt, L, seed=seed)
        if tree_idx is not None:
            idx = np.asarray(tree_idx, np.int32)
            assert idx.shape == (b,) and idx.min() >= 0 \
                and idx.max() < len(self.tree), idx
            state = dataclasses.replace(state, tree_idx=jnp.asarray(idx))

        prefill_t = self._fn("sp_prefill_t", lambda t, c: prefill_row(
            self.tp, self.tc, t, None, c, enc_out=self.enc_out), donate=(1,))
        prefill_d = self._fn("sp_prefill_d", lambda t, c: prefill_row(
            self.dp, self.dc, t, None, c, enc_out=self.draft_enc_out),
            donate=(1,))
        # donate the whole state: the steady state then updates gen + both
        # cache pools in place (no per-iteration multi-MB buffer copies)
        # greedy batches compile the sampled machinery out entirely (no
        # per-step threefry splits, no lax.cond fusion barriers)
        go = self.temperature == 0.0
        sfx = "_g" if go else ""
        if self.tree is not None:
            step = self._fn(f"tree_step_{self.tree.key}{sfx}",
                            self._build_tree_step(greedy_only=go),
                            donate=(0,))
        else:
            step = self._fn(f"spec_step_{mode}{sfx}",
                            self._build_spec_step(mode, greedy_only=go),
                            donate=(0,))

        state = dataclasses.replace(
            state, tcache=prefill_t(prompt[:, :-1], state.tcache),
            dcache=prefill_d(prompt[:, :-1], state.dcache))

        iters, draft_calls, target_calls = 0, 0, 0
        acc_hist = jnp.zeros((k,), jnp.int32)
        round_hist = None
        acc_total, live_iters = 0, 0
        target_n = p + max_new
        host_overhead_ms = []       # blocking-reads-done -> next dispatch
        t_reads_done = None
        while True:
            live = int(jnp.sum(~state.done))
            if t_reads_done is not None:
                host_overhead_ms.append(
                    (time.perf_counter() - t_reads_done) * 1e3)
            state, a, hist, rhist, _rank, n_draft = step(state)
            iters += 1
            live_iters += live
            draft_calls += n_draft
            target_calls += 1
            acc_hist = acc_hist + hist
            round_hist = rhist if round_hist is None else round_hist + rhist
            acc_total += int(jnp.sum(a))
            state = dataclasses.replace(state, done=state.n >= target_n)
            stop = bool(jnp.all(state.done)) or iters > max_new + 2
            t_reads_done = time.perf_counter()
            if stop:
                break

        n, gen = state.n, state.gen
        tokens = gen[:, :target_n]
        live_iters = max(live_iters, 1)
        stats = SpecStats(
            iterations=iters,
            tokens_generated=int(jnp.sum(jnp.minimum(n, target_n) - p)),
            draft_forwards=draft_calls,
            target_forwards=target_calls,
            accept_hist=jax.device_get(acc_hist),
            acceptance_rate=acc_total / (live_iters * k),
            mean_accepted=acc_total / live_iters + 1.0,
            round_hist=jax.device_get(round_hist),
            host_overhead_p50_ms=(float(np.percentile(host_overhead_ms, 50))
                                  if host_overhead_ms else 0.0),
            host_overhead_p95_ms=(float(np.percentile(host_overhead_ms, 95))
                                  if host_overhead_ms else 0.0),
        )
        return tokens, stats
