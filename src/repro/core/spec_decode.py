"""Speculative decoding: AR baseline, vanilla SD (AR draft), PARD.

All step functions use fixed shapes (jit-once):

  * the generation buffer ``gen [B, L]`` holds committed tokens; ``n [B]``
    counts them. Commits write a full (K+1)-slot window at offset n — slots
    beyond the accepted count hold garbage that is overwritten before it can
    ever be read (reads are always < n).
  * KV caches are contiguous; speculative rollback = the next call's
    ``cache_pos`` simply re-covers the rejected entries (validity is
    ``index < cache_pos + q_len``, so stale KV is invisible).
  * SSM/hybrid layers cannot roll back by position: the verify forward runs
    with ``collect_ssm=True`` and the engine gathers the per-token state at
    the last accepted index (DESIGN.md §3).

PARD draft (paper Eq. 7): ONE forward of
  [ new committed tokens (A <= K+1) | mask x (K-1) | pad ]   (2K slots)
produces all K proposals: slot A-1 (last real token) proposes token 1, the
K-1 mask slots propose the rest. Plain causal attention over this window
equals the paper's mask-token factorisation because exactly one chain is in
flight at inference time.

VSD draft: the same window advances the committed tokens, then K-1 extra
single-token AR calls — K draft forwards/iteration vs PARD's 1 (Eq. 3 vs 4).

Greedy (temperature 0) verification is exactly lossless vs AR decoding;
temperature > 0 uses Leviathan speculative sampling (accept with p/q,
resample from the clipped residual).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import forward, init_caches
from ..models.config import SSM, ModelConfig, scan_plan

Array = jax.Array


def _row_take(x: Array, idx: Array) -> Array:
    """x: [B, T, ...], idx: [B] -> [B, ...]."""
    return jax.vmap(lambda r, i: jax.lax.dynamic_index_in_dim(r, i, 0, False))(x, idx)


def _row_write(buf: Array, vec: Array, pos: Array) -> Array:
    """buf: [B, L]; vec: [B, W]; pos: [B] -> buf with vec written at pos."""
    return jax.vmap(lambda b, v, p: jax.lax.dynamic_update_slice(b, v, (p,)))(
        buf, vec, pos)


def gather_ssm_states(cfg: ModelConfig, collected, accept_idx: Array):
    """Select per-token SSM states at the last accepted index.

    ``collected`` is the new_caches pytree from a ``collect_ssm`` forward:
    SSM entries hold per-token states (conv: [B, T, W-1, C], ssm:
    [B, T, H, P, N]; scanned layers carry a leading repeats dim) while
    attention entries are normal caches. Returns the cache pytree with every
    SSM state set to the state after ``accept_idx[b]`` input tokens.
    """
    plan = scan_plan(cfg)

    def row_gather(leaf):       # [B, T, ...] -> [B, ...]
        return jax.vmap(lambda r, i: jax.lax.dynamic_index_in_dim(
            r, i, 0, False))(leaf, accept_idx)

    def pick(tree, scanned: bool):
        def gather_leaf(leaf):
            if scanned:         # [R, B, T, ...]
                return jax.vmap(row_gather)(leaf)
            return row_gather(leaf)
        return jax.tree.map(gather_leaf, tree)

    out = {"prefix": [], "scan": []}
    for i, spec in enumerate(plan.prefix):
        c = collected["prefix"][i]
        out["prefix"].append(pick(c, False) if spec.mixer == SSM else c)
    for j, spec in enumerate(plan.period):
        c = collected["scan"][j]
        out["scan"].append(pick(c, True) if spec.mixer == SSM else c)
    return out


def _has_ssm(cfg: ModelConfig) -> bool:
    plan = scan_plan(cfg)
    return any(s.mixer == SSM for s in plan.prefix + plan.period)


def speculative_accept(p_full, qprob, props, rng):
    """Leviathan speculative sampling (the T>0 acceptance rule).

    p_full: [B, K+1, V] target probabilities at each verify position
    qprob:  [B, K, V]   draft proposal distributions
    props:  [B, K]      proposed tokens
    Returns (a [B] number accepted, commit_tok [B] the correction/bonus
    token). The induced distribution of every committed token equals the
    target's own sampling distribution (tested in tests/test_spec_decode).
    """
    b, k = props.shape
    r_acc, r_res = jax.random.split(rng)
    p_at = jnp.take_along_axis(p_full[:, :k], props[..., None], axis=-1)[..., 0]
    q_at = jnp.take_along_axis(qprob, props[..., None], axis=-1)[..., 0]
    u = jax.random.uniform(r_acc, p_at.shape)
    ok = (u * q_at < p_at).astype(jnp.int32)
    accepted = jnp.cumprod(ok, axis=1)
    a = jnp.sum(accepted, axis=1)
    # residual at the first reject; when a == K the padded q row is 0 so the
    # residual reduces to the target dist (bonus sampling) automatically
    q_ext = jnp.concatenate([qprob, jnp.zeros_like(qprob[:, :1])], axis=1)
    p_a = _row_take(p_full, a)
    q_a = _row_take(q_ext, a)
    resid = jnp.maximum(p_a - q_a, 0.0)
    resid = resid / jnp.maximum(jnp.sum(resid, axis=-1, keepdims=True), 1e-9)
    commit_tok = jax.random.categorical(
        r_res, jnp.log(resid + 1e-30)).astype(jnp.int32)
    return a, accepted, commit_tok


# ---------------------------------------------------------------------------
# Decode state — the unified core shared with the serving engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DecodeState:
    """Everything one decode step reads and writes, as one pytree.

    Both ``SpecDecoder.generate_*`` (uniform batch, run-to-completion) and
    the continuous-batching serving engine (ragged slots, admission /
    release between steps) advance a ``DecodeState`` through the SAME jitted
    step functions (``SpecDecoder._build_ar_step`` /  ``_build_spec_step``).

      gen    [B, L]  committed tokens (prompt + generated)
      n      [B]     committed count (reads are always < n)
      m      [B]     draft progress: committed tokens already processed by
                     the draft (n - m = the new-token window)
      done   [B]     frozen rows — steps rewrite their gen/n/m unchanged
      tcache, dcache cache pytrees (contiguous rows or paged pools)
      tables [B, MBS] block tables for the paged KV layout, or None for
                     contiguous (DESIGN.md §5); shared by target and draft
                     since both cache the same absolute positions.
    """
    gen: Array
    n: Array
    m: Array
    done: Array
    tcache: Any
    dcache: Any = None
    tables: Optional[Array] = None


# every field is pytree data (derived from the dataclass so new fields can
# never silently fall out of the jitted steps)
jax.tree_util.register_dataclass(
    DecodeState, [f.name for f in dataclasses.fields(DecodeState)], [])


def prefill_row(params, cfg: ModelConfig, toks: Array, plen, caches, *,
                tables=None, block_size=0, enc_out=None):
    """Prefill ``toks`` [B, T] (right-padded past ``plen``) into ``caches``.

    Shared by SpecDecoder prefills (uniform batch, ``plen=None``: every
    token real, final SSM state already correct) and the engine's bucketed
    per-request admission (T >= plen). Attention KV written at padded
    positions >= plen is never valid (kv_len bookkeeping; in the paged
    layout it lands in the row's own future blocks or the garbage block).
    SSM state cannot be masked after the fact, so with padding present it is
    rolled back to the state after the last REAL token (DESIGN.md §3).
    """
    has = _has_ssm(cfg) and plen is not None
    _, cache, _ = forward(params, cfg, toks, caches=caches,
                          cache_pos=jnp.zeros((toks.shape[0],), jnp.int32),
                          block_tables=tables, kv_block_size=block_size,
                          collect_ssm=has, enc_out=enc_out, last_only=True)
    if has:
        idx = jnp.broadcast_to(jnp.asarray(plen, jnp.int32) - 1,
                               (toks.shape[0],))
        cache = gather_ssm_states(cfg, cache, idx)
    return cache


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SpecStats:
    iterations: int
    tokens_generated: int
    draft_forwards: int
    target_forwards: int
    accept_hist: Any          # [K] — how often draft position j was accepted
    acceptance_rate: float    # mean accepted drafts / K per iteration
    mean_accepted: float      # mean committed tokens per iteration (a+1)


class SpecDecoder:
    """Bundles target + draft and exposes AR / VSD / PARD generation.

    All public ``generate_*`` methods take ``prompt [B, P]`` (uniform length;
    the batched serving engine in serving/engine.py handles ragged requests)
    and return (tokens [B, P + max_new], SpecStats).
    """

    def __init__(self, target_params, target_cfg: ModelConfig,
                 draft_params=None, draft_cfg: ModelConfig = None, *,
                 k: int = 8, max_len: int = 2048, temperature: float = 0.0,
                 enc_out=None, draft_enc_out=None, kv_block_size: int = 0):
        self.tp, self.tc = target_params, target_cfg
        self.dp, self.dc = draft_params, draft_cfg
        self.k = k
        self.max_len = max_len
        self.temperature = temperature
        self.enc_out = enc_out
        self.draft_enc_out = draft_enc_out
        # 0 = contiguous caches; > 0 = paged pools, steps consume the block
        # tables carried in DecodeState.tables (the serving engine's layout)
        self.kv_block_size = kv_block_size
        if draft_cfg is not None:
            assert draft_cfg.vocab_size == target_cfg.vocab_size, \
                "speculative decoding requires a shared tokenizer/vocab"
        self._jit_cache: Dict[str, Any] = {}

    # -- jitted primitives ------------------------------------------------
    def _fn(self, name, builder, donate=()):
        if name not in self._jit_cache:
            self._jit_cache[name] = jax.jit(builder, donate_argnums=donate)
        return self._jit_cache[name]

    def _target_forward(self, tokens, caches, cache_pos, tables=None,
                        collect_ssm=False):
        return forward(self.tp, self.tc, tokens, caches=caches,
                       cache_pos=cache_pos, enc_out=self.enc_out,
                       collect_ssm=collect_ssm, block_tables=tables,
                       kv_block_size=self.kv_block_size)

    def _draft_forward(self, tokens, caches, cache_pos, tables=None,
                       collect_ssm=False):
        return forward(self.dp, self.dc, tokens, caches=caches,
                       cache_pos=cache_pos, enc_out=self.draft_enc_out,
                       collect_ssm=collect_ssm, block_tables=tables,
                       kv_block_size=self.kv_block_size)

    # ----------------------------------------------------------------- AR
    def _build_ar_step(self):
        """One greedy AR decode step over a DecodeState (the AR+ baseline
        and the engine's mode="ar" — one shared implementation)."""
        def step(state: DecodeState) -> DecodeState:
            gen, n, done = state.gen, state.n, state.done
            last = jnp.take_along_axis(gen, (n - 1)[:, None], axis=1)
            logits, tcache, _ = self._target_forward(
                last.astype(jnp.int32), state.tcache, n - 1, state.tables)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            gen2 = jax.vmap(
                lambda g, t, p: jax.lax.dynamic_update_slice(g, t[None], (p,))
            )(gen, nxt, n)
            gen = jnp.where(done[:, None], gen, gen2)
            n = jnp.where(done, n, n + 1)
            return dataclasses.replace(state, gen=gen, n=n, tcache=tcache)
        return step

    def init_state(self, prompt: Array, gen_len: int,
                   with_draft: bool = True) -> DecodeState:
        """Contiguous-layout DecodeState for a uniform-length batch (the
        engine builds its own paged state from serving.kv_pool)."""
        b, p = prompt.shape
        gen = jnp.zeros((b, gen_len), jnp.int32)
        gen = gen.at[:, :p].set(prompt)
        return DecodeState(
            gen=gen, n=jnp.full((b,), p, jnp.int32),
            m=jnp.full((b,), p - 1, jnp.int32), done=jnp.zeros((b,), bool),
            tcache=init_caches(self.tc, b, self.max_len),
            dcache=(init_caches(self.dc, b, self.max_len)
                    if with_draft and self.dc is not None else None))

    def generate_ar(self, prompt: Array, max_new: int):
        b, p = prompt.shape
        state = self.init_state(prompt, p + max_new + 1, with_draft=False)

        # AR prefill covers the WHOLE prompt: its last logits commit the
        # first new token, so exactly max_new forwards produce max_new
        # tokens (unlike spec prefills, which stop at prompt[:-1] and let
        # the first verify window re-read x_{P-1})
        def pre(toks, c):
            logits, c, _ = self._target_forward(
                toks, c, jnp.zeros((toks.shape[0],), jnp.int32))
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), c
        prefill = self._fn("ar_prefill", pre, donate=(1,))
        step = self._fn("ar_step", self._build_ar_step(), donate=(0,))

        first, tcache = prefill(prompt, state.tcache)
        state = dataclasses.replace(
            state, gen=state.gen.at[:, p].set(first),
            n=state.n + 1, tcache=tcache)
        for _ in range(max_new - 1):
            state = step(state)
        tokens = state.gen[:, :p + max_new]
        stats = SpecStats(max_new, max_new * b, 0, max_new, None, 0.0, 1.0)
        return tokens, stats

    # ------------------------------------------------------------- shared
    def _build_spec_step(self, mode: str):
        k = self.k
        tc, dc = self.tc, self.dc
        mask_id = dc.mask_token_id
        t_has_ssm = _has_ssm(tc)
        d_has_ssm = _has_ssm(dc)
        temp = self.temperature

        def draft_window(gen, n, m):
            """[B, 2K] window of new committed tokens + masks."""
            b = gen.shape[0]
            i = jnp.arange(2 * k)[None, :]
            idx = m[:, None] + i
            a = (n - m)[:, None]                      # committed, unprocessed
            tok = jnp.take_along_axis(gen, jnp.clip(idx, 0, gen.shape[1] - 1),
                                      axis=1)
            is_real = i < a
            is_mask = (i >= a) & (i < a + (k - 1))
            tok = jnp.where(is_real, tok, jnp.where(is_mask, mask_id, 0))
            return tok.astype(jnp.int32)

        def propose_pard(gen, n, m, dcache, tables, rng):
            tok = draft_window(gen, n, m)
            logits, dcache, _ = self._draft_forward(
                tok, dcache, m, tables, collect_ssm=d_has_ssm)
            if d_has_ssm:
                # state after the last real token (input index A-1)
                dcache = gather_ssm_states(dc, dcache, n - m - 1)
            a = n - m
            sl = (a - 1)[:, None] + jnp.arange(k)[None, :]
            lg = jax.vmap(lambda l, s: l[s])(logits, sl)   # [B, K, V]
            if temp == 0.0:
                props = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                qprob = None
            else:
                lg = lg.astype(jnp.float32) / temp
                props = jax.random.categorical(rng, lg).astype(jnp.int32)
                qprob = jax.nn.softmax(lg, axis=-1)
            return props, qprob, dcache, 1                  # 1 draft forward

        def propose_vsd(gen, n, m, dcache, tables, rng):
            # call 1: advance committed window, propose token 1
            tok = draft_window(gen, n, m)[:, :k + 1]        # reals only window
            logits, dcache, _ = self._draft_forward(
                tok, dcache, m, tables, collect_ssm=d_has_ssm)
            a = n - m
            if d_has_ssm:
                # roll SSM state back to "after the last real token"; the AR
                # proposal calls below advance a throwaway copy, the next
                # iteration restarts from this snapshot.
                dcache = gather_ssm_states(dc, dcache, a - 1)
            snapshot = dcache
            lg_list = [jax.vmap(lambda l, i: l[i])(logits, a - 1)]  # [B, V]
            props = []
            rngs = jax.random.split(rng, k)
            cur_pos = n
            for j in range(k - 1 + 1):
                lgj = lg_list[-1]
                if temp == 0.0:
                    pj = jnp.argmax(lgj, axis=-1).astype(jnp.int32)
                else:
                    pj = jax.random.categorical(
                        rngs[j], lgj.astype(jnp.float32) / temp).astype(jnp.int32)
                props.append(pj)
                if j == k - 1:
                    break
                lgn, dcache, _ = self._draft_forward(pj[:, None], dcache,
                                                     cur_pos, tables)
                cur_pos = cur_pos + 1
                lg_list.append(lgn[:, 0])
            props = jnp.stack(props, axis=1)                # [B, K]
            if temp == 0.0:
                qprob = None
            else:
                qprob = jax.nn.softmax(
                    jnp.stack(lg_list, axis=1).astype(jnp.float32) / temp, axis=-1)
            return props, qprob, snapshot, k                # K draft forwards

        propose = propose_pard if mode == "pard" else propose_vsd

        def step(state: DecodeState, rng):
            gen, n, m, done = state.gen, state.n, state.m, state.done
            tcache, dcache, tables = state.tcache, state.dcache, state.tables
            b = gen.shape[0]
            rng, r1, r2, r3 = jax.random.split(rng, 4)
            props, qprob, dcache, n_draft = propose(gen, n, m, dcache,
                                                    tables, r1)

            # verify window: [last committed, d_1..d_K]
            last = jnp.take_along_axis(gen, (n - 1)[:, None], axis=1)
            vin = jnp.concatenate([last.astype(jnp.int32), props], axis=1)
            logits, tcache_new, _ = self._target_forward(
                vin, tcache, n - 1, tables, collect_ssm=t_has_ssm)

            if temp == 0.0:
                tgt = jnp.argmax(logits[:, :k], axis=-1).astype(jnp.int32)
                match = (props == tgt).astype(jnp.int32)
                accepted = jnp.cumprod(match, axis=1)        # [B, K]
                a = jnp.sum(accepted, axis=1)                # [B]
                all_argmax = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                commit_tok = _row_take(all_argmax, a)        # correction/bonus
            else:
                p_full = jax.nn.softmax(
                    logits.astype(jnp.float32) / temp, axis=-1)  # [B, K+1, V]
                a, accepted, commit_tok = speculative_accept(
                    p_full, qprob, props, r2)

            # committed tokens this iteration: d_1..d_a, then commit_tok
            j = jnp.arange(k + 1)[None, :]
            props_ext = jnp.concatenate([props, props[:, -1:]], axis=1)
            vec = jnp.where(j < a[:, None], props_ext,
                            jnp.where(j == a[:, None], commit_tok[:, None], 0))
            # frozen rows: rewrite what's already there
            old = jax.vmap(lambda g, p: jax.lax.dynamic_slice(g, (p,), (k + 1,)))(
                gen, n)
            vec = jnp.where(done[:, None], old, vec)
            gen = _row_write(gen, vec.astype(gen.dtype), n)

            n_commit = jnp.where(done, 0, a + 1)
            new_m = jnp.where(done, m, n)
            new_n = n + n_commit

            if t_has_ssm:
                # state after input index a (= last committed token processed)
                tcache_new = gather_ssm_states(tc, tcache_new, a)
            # frozen rows keep old caches? their cache contents are untouched
            # at positions < n and never read beyond; safe to keep new buffers.
            acc_hist = jnp.sum(
                jnp.where(done[:, None], 0, accepted), axis=0)  # [K]
            new_state = dataclasses.replace(
                state, gen=gen, n=new_n, m=new_m, tcache=tcache_new,
                dcache=dcache)
            return new_state, jnp.where(done, 0, a), acc_hist, n_draft

        return step

    def generate_spec(self, prompt: Array, max_new: int, mode: str = "pard",
                      seed: int = 0):
        assert self.dp is not None, "spec decoding requires a draft model"
        b, p = prompt.shape
        k = self.k
        # Both prefills stop at prompt[:-1]: the verify window re-processes
        # x_{P-1} (an idempotent KV rewrite for attention — but SSM state
        # must NOT see it twice, so it is excluded here).
        assert p >= 2, "prompts must have at least 2 tokens"
        L = p + max_new + 2 * k + 2   # room for the final (K+1)-slot write
        state = self.init_state(prompt, L)

        prefill_t = self._fn("sp_prefill_t", lambda t, c: prefill_row(
            self.tp, self.tc, t, None, c, enc_out=self.enc_out), donate=(1,))
        prefill_d = self._fn("sp_prefill_d", lambda t, c: prefill_row(
            self.dp, self.dc, t, None, c, enc_out=self.draft_enc_out),
            donate=(1,))
        # donate the whole state: the steady state then updates gen + both
        # cache pools in place (no per-iteration multi-MB buffer copies)
        step = self._fn(f"spec_step_{mode}_{self.temperature}",
                        self._build_spec_step(mode), donate=(0,))

        state = dataclasses.replace(
            state, tcache=prefill_t(prompt[:, :-1], state.tcache),
            dcache=prefill_d(prompt[:, :-1], state.dcache))
        rng = jax.random.PRNGKey(seed)

        iters, draft_calls, target_calls = 0, 0, 0
        acc_hist = jnp.zeros((k,), jnp.int32)
        acc_total, live_iters = 0, 0
        target_n = p + max_new
        while True:
            live = int(jnp.sum(~state.done))
            rng, sub = jax.random.split(rng)
            state, a, hist, n_draft = step(state, sub)
            iters += 1
            live_iters += live
            draft_calls += n_draft
            target_calls += 1
            acc_hist = acc_hist + hist
            acc_total += int(jnp.sum(a))
            state = dataclasses.replace(state, done=state.n >= target_n)
            if bool(jnp.all(state.done)) or iters > max_new + 2:
                break

        n, gen = state.n, state.gen
        tokens = gen[:, :target_n]
        live_iters = max(live_iters, 1)
        stats = SpecStats(
            iterations=iters,
            tokens_generated=int(jnp.sum(jnp.minimum(n, target_n) - p)),
            draft_forwards=draft_calls,
            target_forwards=target_calls,
            accept_hist=jax.device_get(acc_hist),
            acceptance_rate=acc_total / (live_iters * k),
            mean_accepted=acc_total / live_iters + 1.0,
        )
        return tokens, stats
