"""Token acceptance for speculative decoding — ONE module for every verifier.

Both the flat chain (``SpecDecoder._build_spec_step``) and the packed
candidate tree (``_build_tree_step``) decide what to commit here, in four
rules that pair up as (greedy, sampled) x (chain, tree):

  * ``greedy_chain_accept``  — longest prefix matching the target argmax.
  * ``leviathan_accept``     — Leviathan speculative sampling: accept draft
    token x with min(1, p(x)/q(x)); on the first reject, commit a token from
    the clipped residual norm(max(p - q, 0)). Exact for temperature > 0.
  * ``greedy_tree_accept``   — longest root path whose node tokens match the
    target argmax at their parent slot (DESIGN.md §6).
  * ``sampled_tree_accept``  — multi-round (SpecInfer-style) recursive
    rejection sampling over sibling candidates: at each depth, try the
    surviving node's children in order; accept child token x with
    min(1, r(x)/q(x)) where r starts at the target distribution p and, after
    every rejected sibling, becomes the renormalised clipped residual
    norm(max(r - q, 0)). If all siblings reject, the correction token is
    sampled from the final residual; a fully accepted path samples the bonus
    token from p at its deepest node. Renormalising each round is what makes
    the induction exact: conditioned on a rejection, the remaining rounds
    are speculative sampling targeting the residual, so every committed
    token is distributed exactly as the target's own sampling distribution
    (tested in tests/test_sampled_tree.py, gated statistically in CI).

Sampling state is per ROW: every function takes ``keys [B, 2]`` (one PRNG
key per batch row) so a request's sampling trajectory depends only on its
own key and step count — never on batch composition or KV layout. That is
the seeded-determinism contract the engine relies on to mix greedy and
sampled requests in one batch (DecodeState.temp / DecodeState.rngs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

_EPS = 1e-9
_LOG_EPS = 1e-30


# ---------------------------------------------------------------------------
# Per-row PRNG plumbing
# ---------------------------------------------------------------------------

def make_row_keys(seed: int, ids) -> Array:
    """[B, 2] uint32 — one independent PRNG key per row, derived from a
    shared seed and a per-row id (the batch index in ``generate_*``, the
    request id in the serving engine)."""
    base = jax.random.PRNGKey(seed)
    ids = jnp.asarray(ids, jnp.uint32)
    return jax.vmap(lambda i: jax.random.fold_in(base, i))(ids)


def split_row_keys(keys: Array):
    """keys [B, 2] -> (next_keys, use_keys): each row's key advances one
    step; ``use_keys`` seeds this step's draws, ``next_keys`` is stored."""
    both = jax.vmap(lambda k: jax.random.split(k, 2))(keys)   # [B, 2, 2]
    return both[:, 0], both[:, 1]


def fold_row_keys(keys: Array, tag: int) -> Array:
    """Derive an independent per-row stream ``tag`` from ``keys``."""
    return jax.vmap(lambda k: jax.random.fold_in(k, tag))(keys)


def row_uniform(keys: Array) -> Array:
    return jax.vmap(lambda k: jax.random.uniform(k, ()))(keys)


def row_categorical(keys: Array, logits: Array) -> Array:
    """keys [B, 2], logits [B, V] -> [B] int32 (independent per row)."""
    return jax.vmap(lambda k, lg: jax.random.categorical(k, lg))(
        keys, logits).astype(jnp.int32)


def _row_take(x: Array, idx: Array) -> Array:
    """x: [B, T, ...], idx: [B] -> [B, ...]."""
    return jax.vmap(lambda r, i: jax.lax.dynamic_index_in_dim(r, i, 0, False))(x, idx)


def scale_logits(logits: Array, temp: Array) -> Array:
    """logits / temp with PER-ROW temperature, the one place the greedy-row
    guard lives: rows with temp == 0 divide by 1 instead (their scaled
    logits are never used — the greedy rules decide those rows — but NaNs
    must not be produced)."""
    t = jnp.where(temp > 0, temp, 1.0).astype(jnp.float32)
    t = t.reshape(t.shape + (1,) * (logits.ndim - 1))
    return logits.astype(jnp.float32) / t


def temp_softmax(logits: Array, temp: Array) -> Array:
    """softmax(logits / temp) with per-row temperature (see scale_logits)."""
    return jax.nn.softmax(scale_logits(logits, temp), axis=-1)


# ---------------------------------------------------------------------------
# Flat chain
# ---------------------------------------------------------------------------

def greedy_chain_accept(logits: Array, props: Array):
    """Greedy flat verification: longest draft prefix matching the target
    argmax. logits [B, K+1, V] at each verify slot, props [B, K].
    Returns (a [B], accepted [B, K], commit_tok [B])."""
    k = props.shape[1]
    tgt = jnp.argmax(logits[:, :k], axis=-1).astype(jnp.int32)
    accepted = jnp.cumprod((props == tgt).astype(jnp.int32), axis=1)
    a = jnp.sum(accepted, axis=1)
    all_argmax = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return a, accepted, _row_take(all_argmax, a)     # correction / bonus


def leviathan_accept(p_full: Array, qprob: Array, props: Array, keys: Array):
    """Leviathan speculative sampling (the flat T > 0 acceptance rule).

    p_full: [B, K+1, V] target probabilities at each verify position
    qprob:  [B, K, V]   draft proposal distributions
    props:  [B, K]      proposed tokens
    keys:   [B, 2]      per-row PRNG keys (this step's draw)
    Returns (a [B], accepted [B, K], commit_tok [B]) — the correction token
    comes from the clipped residual at the first reject; when a == K the
    padded q row is 0 so the residual reduces to the target distribution
    (bonus sampling) automatically. The induced distribution of every
    committed token equals the target's own sampling distribution (tested
    in tests/test_spec_decode.py).
    """
    b, k = props.shape
    k_acc = fold_row_keys(keys, 0)
    k_res = fold_row_keys(keys, 1)
    p_at = jnp.take_along_axis(p_full[:, :k], props[..., None], axis=-1)[..., 0]
    q_at = jnp.take_along_axis(qprob, props[..., None], axis=-1)[..., 0]
    u = jax.vmap(lambda kk: jax.random.uniform(kk, (k,)))(k_acc)
    ok = (u * q_at < p_at).astype(jnp.int32)
    accepted = jnp.cumprod(ok, axis=1)
    a = jnp.sum(accepted, axis=1)
    q_ext = jnp.concatenate([qprob, jnp.zeros_like(qprob[:, :1])], axis=1)
    resid = jnp.maximum(_row_take(p_full, a) - _row_take(q_ext, a), 0.0)
    resid = resid / jnp.maximum(jnp.sum(resid, axis=-1, keepdims=True), _EPS)
    commit_tok = row_categorical(k_res, jnp.log(resid + _LOG_EPS))
    return a, accepted, commit_tok


def speculative_accept(p_full: Array, qprob: Array, props: Array, rng):
    """Single-key convenience wrapper around ``leviathan_accept`` (rows draw
    from splits of one key; kept for callers without per-row state)."""
    keys = jax.random.split(rng, props.shape[0])
    return leviathan_accept(p_full, qprob, props, keys)


# ---------------------------------------------------------------------------
# Packed candidate tree
# ---------------------------------------------------------------------------

def tree_child_map(tree) -> np.ndarray:
    """[S, max_b] int32 — window slot of parent s's child at sibling rank c
    (0 where absent; slot 0 is the root and never a child). Host-side,
    static per template."""
    cm = np.zeros((tree.num_slots, max(tree.branching)), np.int32)
    for t in range(1, tree.num_slots):
        cm[tree.parent[t], tree.choice[t]] = t
    return cm


def greedy_tree_accept(tree, logits: Array, props: Array):
    """Greedy tree verification (DESIGN.md §6): a node survives iff its
    token equals the target argmax at its parent slot AND its parent
    survives; sibling tokens are distinct top-k ranks, so at most one node
    per depth survives.

    logits [B, S, V] at each window slot, props [B, N] node tokens.
    Returns (a [B], tok_depth [B, D], src_slot [B, D] — accepted node's
    window slot per depth, 0 where rejected —, commit_tok [B],
    rank [B, D] — accepted sibling rank per depth, -1 where rejected).
    """
    b = props.shape[0]
    d, s = tree.max_depth, tree.num_slots
    parent_idx = np.asarray(tree.parent[1:], np.int32)             # [N]
    node_depth_onehot = jnp.asarray(
        tree.depth[1:, None] == np.arange(1, d + 1)[None, :])      # [N, D]
    node_slot = jnp.arange(1, s, dtype=jnp.int32)                  # [N]
    choice = jnp.asarray(tree.choice)                              # [S]

    tgt = jnp.argmax(logits, axis=-1).astype(jnp.int32)            # [B, S]
    matched = props == tgt[:, parent_idx]                          # [B, N]
    ok = [jnp.ones((b,), bool)]
    for si in range(1, s):
        ok.append(matched[:, si - 1] & ok[tree.parent[si]])
    path_ok = jnp.stack(ok, axis=1)                                # [B, S]
    a = jnp.sum(path_ok[:, 1:], axis=1).astype(jnp.int32)          # [B]
    best_slot = jnp.max(
        jnp.where(path_ok, jnp.arange(s)[None, :], 0), axis=1)
    commit_tok = _row_take(tgt, best_slot)         # correction / bonus

    pick = path_ok[:, 1:, None] & node_depth_onehot[None]          # [B,N,D]
    tok_depth = jnp.sum(pick * props[:, :, None], axis=1)          # [B, D]
    src_slot = jnp.sum(pick * node_slot[None, :, None], axis=1)    # [B, D]
    rank = jnp.where(src_slot > 0, choice[src_slot], -1)
    return a, tok_depth.astype(jnp.int32), src_slot.astype(jnp.int32), \
        commit_tok, rank.astype(jnp.int32)


def sampled_tree_accept(tree, p_full: Array, q_depth: Array, props: Array,
                        keys: Array):
    """Multi-round recursive rejection sampling over the candidate tree.

    At each depth the surviving node's children are tried in sibling order;
    round c accepts child token x with probability min(1, r(x)/q_d(x)),
    where r is the target distribution at the surviving node, renormalised
    after every rejected sibling to norm(max(r - q_d, 0)). Children must be
    i.i.d. samples from q_d (the draft's depth-d proposal distribution) —
    that, plus the renormalisation, makes every committed token exactly
    target-distributed (see module docstring).

    tree:    TreeTemplate (static host metadata)
    p_full:  [B, S, V] target probabilities at each window slot (temp-scaled)
    q_depth: [B, D, V] draft proposal distribution per depth (temp-scaled)
    props:   [B, N]    node tokens (i.i.d. per node from its depth's q)
    keys:    [B, 2]    per-row PRNG keys (this step's acceptance draws;
             independent of the stream that sampled ``props``)
    Returns (a, tok_depth, src_slot, commit_tok, rank) shaped exactly like
    ``greedy_tree_accept`` so the step can select per row between them.
    """
    b = props.shape[0]
    d_max = tree.max_depth
    cm = jnp.asarray(tree_child_map(tree))                         # [S, mb]

    cur = jnp.zeros((b,), jnp.int32)          # surviving slot (root first)
    alive = jnp.ones((b,), bool)
    a = jnp.zeros((b,), jnp.int32)
    commit = jnp.zeros((b,), jnp.int32)
    toks, slots, ranks = [], [], []
    ctr = 0
    for d in range(1, d_max + 1):
        q_d = q_depth[:, d - 1]                                    # [B, V]
        r = _row_take(p_full, cur)                                 # [B, V]
        found = jnp.zeros((b,), bool)
        sel_slot = jnp.zeros((b,), jnp.int32)
        sel_tok = jnp.zeros((b,), jnp.int32)
        sel_rank = jnp.full((b,), -1, jnp.int32)
        for c in range(tree.branching[d - 1]):
            slot_c = cm[cur, c]                                    # [B]
            x = jnp.take_along_axis(
                props, jnp.maximum(slot_c - 1, 0)[:, None], axis=1)[:, 0]
            qx = jnp.take_along_axis(q_d, x[:, None], axis=1)[:, 0]
            rx = jnp.take_along_axis(r, x[:, None], axis=1)[:, 0]
            u = row_uniform(fold_row_keys(keys, ctr))
            ctr += 1
            acc = (u * qx < rx) & alive & ~found
            sel_slot = jnp.where(acc, slot_c, sel_slot)
            sel_tok = jnp.where(acc, x, sel_tok)
            sel_rank = jnp.where(acc, c, sel_rank)
            found = found | acc
            # renormalised clipped residual for the next round (rows that
            # accepted stop updating; their r is never read again)
            nr = jnp.maximum(r - q_d, 0.0)
            nr = nr / jnp.maximum(jnp.sum(nr, axis=-1, keepdims=True), _EPS)
            r = jnp.where(found[:, None], r, nr)
        # all siblings rejected: the correction token comes from the final
        # residual, and the row stops here
        corr = row_categorical(fold_row_keys(keys, ctr),
                                jnp.log(r + _LOG_EPS))
        ctr += 1
        die = alive & ~found
        commit = jnp.where(die, corr, commit)
        a = a + (alive & found)
        cur = jnp.where(found, sel_slot, cur)
        toks.append(jnp.where(alive & found, sel_tok, 0))
        slots.append(jnp.where(alive & found, sel_slot, 0))
        ranks.append(jnp.where(alive, sel_rank, -1))
        alive = alive & found
    # fully accepted path: bonus token from the target distribution at the
    # deepest accepted node
    bonus = row_categorical(fold_row_keys(keys, ctr),
                             jnp.log(_row_take(p_full, cur) + _LOG_EPS))
    commit = jnp.where(alive, bonus, commit)
    return a, jnp.stack(toks, axis=1), jnp.stack(slots, axis=1), commit, \
        jnp.stack(ranks, axis=1)


def sample_tree_props(tree, scaled_logits: Array, keys: Array) -> Array:
    """i.i.d. draft candidates for ``sampled_tree_accept``: node s at depth
    d draws from softmax(scaled_logits[:, d-1]) under its own per-(row,
    node) key. scaled_logits [B, D, V] (already temperature-divided);
    keys [B, 2]. Returns props [B, N] int32."""
    node_depth = np.asarray(tree.depth[1:], np.int32)

    def row(k, lg_row):                         # lg_row [D, V]
        out = []
        for i, nd in enumerate(node_depth):
            out.append(jax.random.categorical(
                jax.random.fold_in(k, i), lg_row[nd - 1]))
        return jnp.stack(out)

    return jax.vmap(row)(keys, scaled_logits).astype(jnp.int32)
