"""Token acceptance for speculative decoding — ONE module for every verifier.

Both the flat chain (``SpecDecoder._build_spec_step``) and the packed
candidate tree (``_build_tree_step``) decide what to commit here, in four
rules that pair up as (greedy, sampled) x (chain, tree):

  * ``greedy_chain_accept``  — longest prefix matching the target argmax.
  * ``leviathan_accept``     — Leviathan speculative sampling: accept draft
    token x with min(1, p(x)/q(x)); on the first reject, commit a token from
    the clipped residual norm(max(p - q, 0)). Exact for temperature > 0.
  * ``greedy_tree_accept``   — longest root path whose node tokens match the
    target argmax at their parent slot (DESIGN.md §6).
  * ``sampled_tree_accept``  — multi-round (SpecInfer-style) recursive
    rejection sampling over sibling candidates: at each depth, try the
    surviving node's children in order; accept child token x with
    min(1, r(x)/q(x)) where r starts at the target distribution p and, after
    every rejected sibling, becomes the renormalised clipped residual
    norm(max(r - q, 0)). If all siblings reject, the correction token is
    sampled from the final residual; a fully accepted path samples the bonus
    token from p at its deepest node. Renormalising each round is what makes
    the induction exact: conditioned on a rejection, the remaining rounds
    are speculative sampling targeting the residual, so every committed
    token is distributed exactly as the target's own sampling distribution
    (tested in tests/test_sampled_tree.py, gated statistically in CI).

Sampling state is per ROW: every function takes ``keys [B, 2]`` (one PRNG
key per batch row) so a request's sampling trajectory depends only on its
own key and step count — never on batch composition or KV layout. That is
the seeded-determinism contract the engine relies on to mix greedy and
sampled requests in one batch (DecodeState.temp / DecodeState.rngs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

_EPS = 1e-9
_LOG_EPS = 1e-30


# ---------------------------------------------------------------------------
# Per-row PRNG plumbing
# ---------------------------------------------------------------------------

def make_row_keys(seed: int, ids) -> Array:
    """[B, 2] uint32 — one independent PRNG key per row, derived from a
    shared seed and a per-row id (the batch index in ``generate_*``, the
    request id in the serving engine)."""
    base = jax.random.PRNGKey(seed)
    ids = jnp.asarray(ids, jnp.uint32)
    return jax.vmap(lambda i: jax.random.fold_in(base, i))(ids)


def split_row_keys(keys: Array):
    """keys [B, 2] -> (next_keys, use_keys): each row's key advances one
    step; ``use_keys`` seeds this step's draws, ``next_keys`` is stored."""
    both = jax.vmap(lambda k: jax.random.split(k, 2))(keys)   # [B, 2, 2]
    return both[:, 0], both[:, 1]


def fold_row_keys(keys: Array, tag: int) -> Array:
    """Derive an independent per-row stream ``tag`` from ``keys``."""
    return jax.vmap(lambda k: jax.random.fold_in(k, tag))(keys)


def row_uniform(keys: Array) -> Array:
    """One U(0, 1) draw per row: keys [B, 2] -> [B] f32."""
    return jax.vmap(lambda k: jax.random.uniform(k, ()))(keys)


def row_categorical(keys: Array, logits: Array) -> Array:
    """keys [B, 2], logits [B, V] -> [B] int32 (independent per row)."""
    return jax.vmap(lambda k, lg: jax.random.categorical(k, lg))(
        keys, logits).astype(jnp.int32)


def _row_take(x: Array, idx: Array) -> Array:
    """x: [B, T, ...], idx: [B] -> [B, ...]."""
    return jax.vmap(lambda r, i: jax.lax.dynamic_index_in_dim(r, i, 0, False))(x, idx)


def scale_logits(logits: Array, temp: Array) -> Array:
    """logits / temp with PER-ROW temperature, the one place the greedy-row
    guard lives: rows with temp == 0 divide by 1 instead (their scaled
    logits are never used — the greedy rules decide those rows — but NaNs
    must not be produced)."""
    t = jnp.where(temp > 0, temp, 1.0).astype(jnp.float32)
    t = t.reshape(t.shape + (1,) * (logits.ndim - 1))
    return logits.astype(jnp.float32) / t


def temp_softmax(logits: Array, temp: Array) -> Array:
    """softmax(logits / temp) with per-row temperature (see scale_logits)."""
    return jax.nn.softmax(scale_logits(logits, temp), axis=-1)


# ---------------------------------------------------------------------------
# Flat chain
# ---------------------------------------------------------------------------

def greedy_chain_accept(logits: Array, props: Array):
    """Greedy flat verification: longest draft prefix matching the target
    argmax. logits [B, K+1, V] at each verify slot, props [B, K].
    Returns (a [B], accepted [B, K], commit_tok [B])."""
    k = props.shape[1]
    tgt = jnp.argmax(logits[:, :k], axis=-1).astype(jnp.int32)
    accepted = jnp.cumprod((props == tgt).astype(jnp.int32), axis=1)
    a = jnp.sum(accepted, axis=1)
    all_argmax = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return a, accepted, _row_take(all_argmax, a)     # correction / bonus


def leviathan_accept(p_full: Array, qprob: Array, props: Array, keys: Array):
    """Leviathan speculative sampling (the flat T > 0 acceptance rule).

    p_full: [B, K+1, V] target probabilities at each verify position
    qprob:  [B, K, V]   draft proposal distributions
    props:  [B, K]      proposed tokens
    keys:   [B, 2]      per-row PRNG keys (this step's draw)
    Returns (a [B], accepted [B, K], commit_tok [B]) — the correction token
    comes from the clipped residual at the first reject; when a == K the
    padded q row is 0 so the residual reduces to the target distribution
    (bonus sampling) automatically. The induced distribution of every
    committed token equals the target's own sampling distribution (tested
    in tests/test_spec_decode.py).
    """
    b, k = props.shape
    k_acc = fold_row_keys(keys, 0)
    k_res = fold_row_keys(keys, 1)
    p_at = jnp.take_along_axis(p_full[:, :k], props[..., None], axis=-1)[..., 0]
    q_at = jnp.take_along_axis(qprob, props[..., None], axis=-1)[..., 0]
    u = jax.vmap(lambda kk: jax.random.uniform(kk, (k,)))(k_acc)
    ok = (u * q_at < p_at).astype(jnp.int32)
    accepted = jnp.cumprod(ok, axis=1)
    a = jnp.sum(accepted, axis=1)
    q_ext = jnp.concatenate([qprob, jnp.zeros_like(qprob[:, :1])], axis=1)
    resid = jnp.maximum(_row_take(p_full, a) - _row_take(q_ext, a), 0.0)
    resid = resid / jnp.maximum(jnp.sum(resid, axis=-1, keepdims=True), _EPS)
    commit_tok = row_categorical(k_res, jnp.log(resid + _LOG_EPS))
    return a, accepted, commit_tok


def speculative_accept(p_full: Array, qprob: Array, props: Array, rng):
    """Single-key convenience wrapper around ``leviathan_accept`` (rows draw
    from splits of one key; kept for callers without per-row state)."""
    keys = jax.random.split(rng, props.shape[0])
    return leviathan_accept(p_full, qprob, props, keys)


# ---------------------------------------------------------------------------
# Packed candidate tree
# ---------------------------------------------------------------------------

def tree_child_map(tree) -> np.ndarray:
    """[S, max_b] int32 — window slot of parent s's child at sibling rank c
    (0 where absent; slot 0 is the root and never a child). Host-side,
    static per template."""
    cm = np.zeros((tree.num_slots, max(tree.branching)), np.int32)
    for t in range(1, tree.num_slots):
        cm[tree.parent[t], tree.choice[t]] = t
    return cm


def _bcast_rows(arr, b):
    """Template metadata [S, ...] -> per-row [B, S, ...]."""
    a = jnp.asarray(arr)
    return jnp.broadcast_to(a[None], (b,) + a.shape)


def greedy_tree_accept_rows(logits: Array, props: Array, parent: Array,
                            depth: Array, choice: Array, anc: Array,
                            nslots: Array, d_max: int):
    """Greedy tree verification with a PER-ROW template (DESIGN.md §7): a
    node survives iff its token equals the target argmax at its parent slot
    AND its parent survives; sibling tokens are distinct top-k ranks, so at
    most one node per depth survives. Survival is evaluated through the
    packed ancestor bitmask — slot s survives iff every ancestor-or-self
    bit is also a "matched" bit — so rows with different tree shapes share
    one fully vectorised decision.

    logits [B, S, V] at each window slot; props [B, S-1] node tokens;
    parent / depth / choice [B, S] int32 and anc [B, S] uint32 are the
    row's template metadata (padded slots past ``nslots[b]`` carry zeros
    and can never be accepted); d_max is the static bank depth.
    Returns (a [B], tok_depth [B, D], src_slot [B, D] — accepted node's
    window slot per depth, 0 where rejected —, commit_tok [B],
    rank [B, D] — accepted sibling rank per depth, -1 where rejected).
    """
    s = anc.shape[1]
    slot_ids = jnp.arange(s, dtype=jnp.int32)
    tgt = jnp.argmax(logits, axis=-1).astype(jnp.int32)            # [B, S]
    # node tokens must match the target argmax at their PARENT slot
    par_tok = jnp.take_along_axis(tgt, jnp.maximum(parent[:, 1:], 0), axis=1)
    node_valid = slot_ids[None, 1:] < nslots[:, None]
    matched = (props == par_tok) & node_valid                      # [B, N]
    bits = jnp.sum(
        jnp.where(matched,
                  jnp.uint32(1) << slot_ids[1:].astype(jnp.uint32)[None],
                  jnp.uint32(0)), axis=1) | jnp.uint32(1)          # [B]
    path_ok = ((anc & ~bits[:, None]) == 0) \
        & (slot_ids[None] < nslots[:, None])                       # [B, S]
    a = jnp.sum(path_ok[:, 1:], axis=1).astype(jnp.int32)
    best_slot = jnp.max(jnp.where(path_ok, slot_ids[None], 0), axis=1)
    commit_tok = _row_take(tgt, best_slot)         # correction / bonus

    darange = jnp.arange(1, d_max + 1, dtype=jnp.int32)
    pick = path_ok[:, 1:, None] & (depth[:, 1:, None] == darange[None, None])
    tok_depth = jnp.sum(pick * props[:, :, None], axis=1)          # [B, D]
    src_slot = jnp.sum(pick * slot_ids[None, 1:, None], axis=1)    # [B, D]
    rank = jnp.where(src_slot > 0,
                     jnp.take_along_axis(choice, src_slot, axis=1), -1)
    return a, tok_depth.astype(jnp.int32), src_slot.astype(jnp.int32), \
        commit_tok, rank.astype(jnp.int32)


def greedy_tree_accept(tree, logits: Array, props: Array):
    """Single-template convenience wrapper around the per-row rule (every
    row shares ``tree``). Kept for callers without a template bank."""
    b = props.shape[0]
    nslots = jnp.full((b,), tree.num_slots, jnp.int32)
    return greedy_tree_accept_rows(
        logits, props, _bcast_rows(tree.parent, b),
        _bcast_rows(tree.depth, b), _bcast_rows(tree.choice, b),
        _bcast_rows(tree.anc, b), nslots, tree.max_depth)


def sampled_tree_accept_rows(p_full: Array, q_depth: Array, props: Array,
                             child_map: Array, keys: Array, d_max: int,
                             max_b: int):
    """Multi-round recursive rejection sampling with a PER-ROW template.

    At each depth the surviving node's children are tried in sibling order;
    round c accepts child token x with probability min(1, r(x)/q_d(x)),
    where r is the target distribution at the surviving node, renormalised
    after every rejected sibling to norm(max(r - q_d, 0)). Children must be
    i.i.d. samples from q_d (the draft's depth-d proposal distribution) —
    that, plus the renormalisation, makes every committed token exactly
    target-distributed (see module docstring). Rows whose template offers
    fewer than ``max_b`` siblings at a depth simply skip the extra rounds
    (no accept, no residual update — exactness is per offered round, so a
    masked round leaves the induction untouched); a row whose surviving
    node has no children at all commits a token from the unmodified target
    distribution, which coincides with the bonus draw.

    p_full:    [B, S, V]     target probabilities per window slot (scaled)
    q_depth:   [B, D, V]     draft proposal distribution per depth (scaled)
    props:     [B, S-1]      node tokens (i.i.d. per node from its depth's q)
    child_map: [B, S, max_b] window slot of cur's child at rank c (0=absent)
    keys:      [B, 2]        per-row PRNG keys (this step's draws)
    Returns (a, tok_depth, src_slot, commit_tok, rank) shaped exactly like
    ``greedy_tree_accept_rows`` so the step can select per row between them.
    """
    b = props.shape[0]
    cur = jnp.zeros((b,), jnp.int32)          # surviving slot (root first)
    alive = jnp.ones((b,), bool)
    a = jnp.zeros((b,), jnp.int32)
    commit = jnp.zeros((b,), jnp.int32)
    toks, slots, ranks = [], [], []
    ctr = 0
    for d in range(1, d_max + 1):
        q_d = q_depth[:, d - 1]                                    # [B, V]
        r = _row_take(p_full, cur)                                 # [B, V]
        cm_cur = _row_take(child_map, cur)                         # [B, mb]
        found = jnp.zeros((b,), bool)
        sel_slot = jnp.zeros((b,), jnp.int32)
        sel_tok = jnp.zeros((b,), jnp.int32)
        sel_rank = jnp.full((b,), -1, jnp.int32)
        for c in range(max_b):
            slot_c = cm_cur[:, c]                                  # [B]
            has = slot_c > 0           # row offers a rank-c sibling here
            x = jnp.take_along_axis(
                props, jnp.maximum(slot_c - 1, 0)[:, None], axis=1)[:, 0]
            qx = jnp.take_along_axis(q_d, x[:, None], axis=1)[:, 0]
            rx = jnp.take_along_axis(r, x[:, None], axis=1)[:, 0]
            u = row_uniform(fold_row_keys(keys, ctr))
            ctr += 1
            acc = (u * qx < rx) & alive & ~found & has
            sel_slot = jnp.where(acc, slot_c, sel_slot)
            sel_tok = jnp.where(acc, x, sel_tok)
            sel_rank = jnp.where(acc, c, sel_rank)
            found = found | acc
            # renormalised clipped residual for the next round (rows that
            # accepted — or were not offered this round — stop updating)
            nr = jnp.maximum(r - q_d, 0.0)
            nr = nr / jnp.maximum(jnp.sum(nr, axis=-1, keepdims=True), _EPS)
            r = jnp.where((found | ~has)[:, None], r, nr)
        # all siblings rejected: the correction token comes from the final
        # residual, and the row stops here
        corr = row_categorical(fold_row_keys(keys, ctr),
                                jnp.log(r + _LOG_EPS))
        ctr += 1
        die = alive & ~found
        commit = jnp.where(die, corr, commit)
        a = a + (alive & found)
        cur = jnp.where(found, sel_slot, cur)
        toks.append(jnp.where(alive & found, sel_tok, 0))
        slots.append(jnp.where(alive & found, sel_slot, 0))
        ranks.append(jnp.where(alive, sel_rank, -1))
        alive = alive & found
    # fully accepted path: bonus token from the target distribution at the
    # deepest accepted node
    bonus = row_categorical(fold_row_keys(keys, ctr),
                             jnp.log(_row_take(p_full, cur) + _LOG_EPS))
    commit = jnp.where(alive, bonus, commit)
    return a, jnp.stack(toks, axis=1), jnp.stack(slots, axis=1), commit, \
        jnp.stack(ranks, axis=1)


def sampled_tree_accept(tree, p_full: Array, q_depth: Array, props: Array,
                        keys: Array):
    """Single-template convenience wrapper around the per-row rule (every
    row shares ``tree``). Kept for callers without a template bank."""
    b = props.shape[0]
    cm = _bcast_rows(tree_child_map(tree), b)
    return sampled_tree_accept_rows(p_full, q_depth, props, cm, keys,
                                    tree.max_depth, max(tree.branching))


def sample_tree_props_rows(scaled_logits: Array, node_depth: Array,
                           keys: Array) -> Array:
    """i.i.d. draft candidates for ``sampled_tree_accept_rows``: node i
    draws from softmax(scaled_logits[:, node_depth[b, i] - 1]) under its
    own per-(row, node) key. scaled_logits [B, D, V] (already
    temperature-divided); node_depth [B, N] int32 (padded slots carry 0 and
    draw an unused depth-1 sample); keys [B, 2]. Returns props [B, N]."""
    out = []
    for i in range(node_depth.shape[1]):
        lg = _row_take(scaled_logits, jnp.maximum(node_depth[:, i] - 1, 0))
        out.append(row_categorical(fold_row_keys(keys, i), lg))
    return jnp.stack(out, axis=1)


def sample_tree_props(tree, scaled_logits: Array, keys: Array) -> Array:
    """Single-template wrapper around ``sample_tree_props_rows``."""
    b = scaled_logits.shape[0]
    return sample_tree_props_rows(
        scaled_logits, _bcast_rows(tree.depth[1:].astype(np.int32), b), keys)
