"""COnditional Drop token (COD) data processing — paper §3.2.2, Algorithm 1.

Training is decomposed into K subtasks (Fig. 4): subtask s predicts the s-th
next token from real context + (s-1) mask tokens. All subtasks pack into one
sequence; the attention pattern is *functionally determined* by two int32
per-token fields (no O(T^2) mask is ever materialised):

  segment[i] = s  (1 = real tokens / subtask 1; s>=2 = mask tokens of
                   subtask s; 0 = padding)
  base[i]    = n  (context length the token conditions on; for segment-1
                   tokens base == original position)

Allowed attention (see models.attention.pard_mask):
  q(s, n) -> k(1, n_k)  iff n_k <  n        real context x_0..x_{n-1}
  q(s, n) -> k(j, n)    iff 2 <= j < s      earlier masks of the same chain
  q(s, n) -> k(s, n)                        self

Conditional drop: subtask s retains the bases with the ``N_s`` smallest
per-base priorities, ``N_s = round(N * max(r^{s-1}, r_min))`` (Eq. 11).
Because thresholds shrink with s, retained sets are **nested** per base —
every retained query's preceding mask chain (bases equal, smaller s) is
guaranteed present, i.e. "the preceding KV cache for attention computation is
complete" (Alg. 1 line 7) holds by construction.

Token budget check (Eq. 10): sum_s N_s ≈ N (1-r^K)/(1-r) < N/(1-r).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

IGNORE = -100


@dataclasses.dataclass(frozen=True)
class CodConfig:
    k: int = 8              # K: tokens predicted per draft forward (K_train)
    r: float = 0.7          # retention decay factor
    r_min: float = 0.2      # minimum retention rate
    drop: bool = True       # False = full mask-token training (no COD)


def subtask_sizes(n: int, cod: CodConfig) -> np.ndarray:
    """N_s for s = 1..K (Eq. 9 / Eq. 11). Subtask s has at most n - s valid
    query bases (base ranges over 1..n-s so the label index base+s-1 <= n-1)."""
    out = []
    for s in range(1, cod.k + 1):
        if s == 1:
            out.append(n)                  # all real tokens (subtask 1)
            continue
        avail = max(n - s, 0)              # bases 1..n-s have a valid label
        if not cod.drop:
            out.append(avail)
        else:
            frac = max(cod.r ** (s - 1), cod.r_min)
            out.append(min(int(round(n * frac)), avail))
    return np.asarray(out, np.int64)


def pack_sample(tokens: np.ndarray, cod: CodConfig, mask_token_id: int,
                rng: np.random.Generator, out_len: Optional[int] = None
                ) -> Dict[str, np.ndarray]:
    """Process ONE sample (1-D int array of length N) per Algorithm 1.

    Returns fixed-length (``out_len``) arrays:
      input_ids, position_ids, labels (IGNORE where no loss), segment, base.
    Layout is segment-major: [subtask-1 tokens | subtask-2 masks | ...].
    Physical order is irrelevant to correctness — attention is defined purely
    on (segment, base).
    """
    tokens = np.asarray(tokens, np.int64)
    n = len(tokens)
    sizes = subtask_sizes(n, cod)

    # nested retention: priorities per base; subtask s keeps the N_s smallest
    pri = rng.permutation(np.arange(1, n))  # bases 1..n-1, random priority
    # pri[j] is the base with priority rank j

    segs, bases, ids, poss, labs = [], [], [], [], []

    # subtask 1: the original AR sequence
    segs.append(np.ones(n, np.int32))
    bases.append(np.arange(n, dtype=np.int32))
    ids.append(tokens.astype(np.int32))
    poss.append(np.arange(n, dtype=np.int32))
    lab1 = np.concatenate([tokens[1:], [IGNORE]]).astype(np.int32)
    labs.append(lab1)

    prev = pri                        # subtask-(s-1) retained, priority order
    for s in range(2, cod.k + 1):
        n_s = sizes[s - 1]
        # nested by construction: choose from the PREVIOUS subtask's
        # retained bases (restricted to bases whose subtask-s label exists),
        # in priority order — guarantees every mask's chain is complete
        cand = prev[prev <= n - s]
        if n_s <= 0 or len(cand) == 0:
            prev = cand
            continue
        prev = cand[:min(n_s, len(cand))]
        keep = np.sort(prev)
        n_s = len(keep)
        segs.append(np.full(n_s, s, np.int32))
        bases.append(keep.astype(np.int32))
        ids.append(np.full(n_s, mask_token_id, np.int32))
        # mask m_{s-2} of chain with base n sits at position n + s - 2
        poss.append((keep + s - 2).astype(np.int32))
        labs.append(tokens[keep + s - 1].astype(np.int32))

    seg = np.concatenate(segs)
    base = np.concatenate(bases)
    inp = np.concatenate(ids)
    pos = np.concatenate(poss)
    lab = np.concatenate(labs)

    t = len(seg)
    if out_len is None:
        out_len = t
    if t > out_len:
        raise ValueError(f"packed length {t} exceeds out_len {out_len}")
    pad = out_len - t

    def padded(a, fill):
        return np.concatenate([a, np.full(pad, fill, a.dtype)])

    return {
        "input_ids": padded(inp, 0),
        "position_ids": padded(pos, 0),
        "labels": padded(lab, IGNORE),
        "segment": padded(seg, 0),
        "base": padded(base, 0),
        "n_tokens": np.int32(t),
    }


def packed_len_bound(n: int, cod: CodConfig) -> int:
    """Static upper bound on the packed length for sequence length n."""
    return int(subtask_sizes(n, cod).sum())


def pack_batch(batch_tokens: np.ndarray, cod: CodConfig, mask_token_id: int,
               seed: int = 0) -> Dict[str, np.ndarray]:
    """batch_tokens: [B, N] -> batched packed arrays [B, T_packed]."""
    b, n = batch_tokens.shape
    out_len = packed_len_bound(n, cod)
    rng = np.random.default_rng(seed)
    rows = [pack_sample(batch_tokens[i], cod, mask_token_id, rng, out_len)
            for i in range(b)]
    return {k: np.stack([r[k] for r in rows]) for k in rows[0]}


# ---------------------------------------------------------------------------
# Invariant checks (used by hypothesis property tests)
# ---------------------------------------------------------------------------

def check_invariants(packed: Dict[str, np.ndarray], tokens: np.ndarray,
                     cod: CodConfig, mask_token_id: int) -> None:
    seg, base = packed["segment"], packed["base"]
    pos, lab, inp = packed["position_ids"], packed["labels"], packed["input_ids"]
    n = len(tokens)
    live = seg > 0
    # 1. position ids consistent: pos == base + seg - 2 for masks, == base for real
    m = seg >= 2
    assert np.all(pos[m] == base[m] + seg[m] - 2)
    r1 = seg == 1
    assert np.all(pos[r1] == base[r1])
    assert np.all(inp[m] == mask_token_id)
    # 2. labels: subtask s>=2 at base n predicts tokens[n + s - 1];
    #    segment-1 token at position i (base == i) predicts tokens[i + 1]
    valid_lab = live & (lab != IGNORE)
    idx = np.where(seg[valid_lab] == 1, base[valid_lab] + 1,
                   base[valid_lab] + seg[valid_lab] - 1)
    assert np.all(idx < n)
    assert np.all(lab[valid_lab] == tokens[idx])
    # 3. KV completeness: every mask (s, n) has its full chain (j, n), 2<=j<s
    present = set(zip(seg[live].tolist(), base[live].tolist()))
    for s, b_ in zip(seg[m].tolist(), base[m].tolist()):
        for j in range(2, s):
            assert (j, b_) in present, f"chain broken: ({s},{b_}) missing ({j},{b_})"
    # 4. drop accounting: per-subtask counts match Eq. 11 up to the nested-
    #    retention constraint (the retained set draws from the previous
    #    subtask's set, which can clip a few tail bases)
    sizes = subtask_sizes(n, cod)
    prev_cnt = None
    for s in range(1, cod.k + 1):
        cnt = int(np.sum(seg == s))
        assert cnt <= sizes[s - 1], (s, cnt, sizes[s - 1])
        if s >= 2:
            # can lose at most one tail base per subtask step vs the target
            assert cnt >= min(sizes[s - 1], (prev_cnt or n) - 1) - 1, \
                (s, cnt, sizes[s - 1])
        prev_cnt = cnt
