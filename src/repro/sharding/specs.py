"""Logical-axis sharding rules (MaxText-style) with divisibility fallbacks.

Params are plain nested dicts; each leaf's sharding is chosen by its *leaf
name* via ``PARAM_RULES``: an ordered list of candidate trailing-axis specs.
The first candidate whose named axes all divide the corresponding dims is
used; leading dims (e.g. the scan-stack repeats axis) are padded with None.

Training uses ``fsdp=True``: any dim left unsharded by the tensor rule is
additionally sharded over the data axis when divisible (ZeRO-3 — required
for the big assigned models to have any chance of fitting v5e HBM; see
EXPERIMENTS.md §Dry-run for the honest accounting).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# trailing-dims candidates per leaf name; names resolve via AXIS_MAP
PARAM_RULES: Dict[str, List[Tuple[Optional[str], ...]]] = {
    # embeddings
    "embedding": [("vocab", None)],
    "unembed": [("vocab", None)],
    # gqa attention
    "wq": [(None, "tp", None), ("tp", None, None)],
    "wk": [(None, "tp", None), ("tp", None, None)],
    "wv": [(None, "tp", None), ("tp", None, None)],
    "wo": [("tp", None, None), (None, None, "tp")],
    "bq": [(None, None)], "bk": [(None, None)], "bv": [(None, None)],
    "q_norm": [(None,)], "k_norm": [(None,)],
    # mla
    "w_dq": [(None, "tp")],
    "w_uq": [(None, "tp", None), ("tp", None, None)],
    "w_dkv": [(None, None)],
    "w_uk": [(None, "tp", None), ("tp", None, None)],
    "w_uv": [(None, "tp", None), ("tp", None, None)],
    "q_lora_norm": [(None,)], "kv_lora_norm": [(None,)],
    # mlp
    "wi": [(None, "tp")], "wg": [(None, "tp")],
    # moe — baseline is tensor-parallel WITHIN each expert (experts
    # replicated over model). Expert-parallel sharding (experts dim on
    # model, all-to-all dispatch) compiles pathologically slowly through
    # GSPMD for the grouped one-hot dispatch and is explored as a §Perf
    # experiment via ``expert_parallel_rules`` below, not as the default.
    "we_i": [(None, None, "tp"), ("tp", None, None)],
    "we_g": [(None, None, "tp"), ("tp", None, None)],
    "we_o": [(None, "tp", None), ("tp", None, None)],
    "router": [(None, None)],
    # ssm (mamba2)
    "in_proj": [(None, "tp"), ("tp", None)],
    "conv_w": [(None, None)], "conv_b": [(None,)],
    "A_log": [(None,)], "D": [(None,)], "dt_bias": [(None,)],
    "ssm_norm": [(None,)],
    "out_proj": [("tp", None)],
    # norms
    "scale": [(None,)], "bias": [(None,)],
    # gate scalar (vision cross-attn)
    "gate": [()],
}

# mlp down-projection "wo" is 2-D while attention "wo" is 3-D; disambiguate
# by rank below.
MLP_WO_RULES = [("tp", None)]

# Serving (inference) ruleset: REDUCTION-FREE tensor parallelism. Every
# candidate shards an OUTPUT dim of its projection only, and infeasible
# leaves replicate instead of falling back to a contraction dim — so GSPMD
# never splits a dot's contraction across devices and never inserts a
# partial-sum reduce. Each output element is then computed by exactly one
# device with full-operand accumulation order, which (together with the
# all-gather hints in models/) makes the forward BITWISE IDENTICAL across
# mesh shapes — the serving engine's token-identity guarantee (DESIGN.md
# §11). Training keeps PARAM_RULES: there the Megatron-style contraction
# sharding halves the activation traffic and losslessness is not a gate.
SERVING_PARAM_RULES: Dict[str, List[Tuple[Optional[str], ...]]] = {
    "embedding": [("vocab", None)],
    "unembed": [("vocab", None)],
    "wq": [(None, "tp", None)],
    "wk": [(None, "tp", None)],
    "wv": [(None, "tp", None)],
    "wo": [(None, None, "tp")],          # attention 3-D: shard d_model out
    "bq": [(None, None)], "bk": [(None, None)], "bv": [(None, None)],
    "q_norm": [(None,)], "k_norm": [(None,)],
    "w_dq": [(None, "tp")],
    "w_uq": [(None, "tp", None)],
    "w_dkv": [(None, None)],
    "w_uk": [(None, "tp", None)],
    "w_uv": [(None, "tp", None)],
    "q_lora_norm": [(None,)], "kv_lora_norm": [(None,)],
    "wi": [(None, "tp")], "wg": [(None, "tp")],
    "we_i": [(None, None, "tp")],
    "we_g": [(None, None, "tp")],
    "we_o": [(None, None, "tp")],
    "router": [(None, None)],
    "in_proj": [(None, "tp")],
    "conv_w": [(None, None)], "conv_b": [(None,)],
    "A_log": [(None,)], "D": [(None,)], "dt_bias": [(None,)],
    "ssm_norm": [(None,)],
    "out_proj": [(None, "tp")],
    "scale": [(None,)], "bias": [(None,)],
    "gate": [()],
}
SERVING_MLP_WO_RULES = [(None, "tp")]

# Throughput serving ruleset: Megatron-style ROW PARALLELISM on the
# down-projections. The up-projections (wq/wk/wv/wi/wg/we_i/we_g/in_proj)
# keep the exact ruleset's column-parallel output-dim sharding, but the
# contraction-side weights — attention ``wo`` [H, hd, d], mlp ``wo``
# [f, d], moe ``we_o`` [E, f, d], ssm ``out_proj`` [e, d] — shard their
# CONTRACTION dim over model. Between the column and row halves the
# activation stays model-sharded (``ops.rowparallel_einsum``), each device
# contracts its local shard, and GSPMD realizes the replicated output with
# exactly ONE psum (all-reduce) per attention block and one per MLP —
# replacing the exact ruleset's full-activation all-gather before every
# contraction. The (tied) embedding table replicates instead of sharding
# over vocab: the exact ruleset's vocab-parallel lookup costs a per-step
# all-reduce and its vocab-sharded logits a per-step all-gather — shared
# overhead that at repro scale (V = 4 d_model) rivals the per-layer
# traffic; the throughput ruleset trades that table's memory for zero
# embed/logits collectives (a production vocab would re-shard it). The
# price of the row-parallel psum is accumulation order: tokens match an
# exact-ruleset engine only to tolerance, not bitwise — the throughput
# ruleset's OWN numerics are pinned at ROWPARALLEL_CHUNKS granularity so
# they stay reproducible across mesh sizes (DESIGN.md §13). Every other
# leaf is IDENTICAL to SERVING_PARAM_RULES — property tested in
# tests/test_tp_ruleset.py.
THROUGHPUT_PARAM_RULES: Dict[str, List[Tuple[Optional[str], ...]]] = {
    **SERVING_PARAM_RULES,
    "embedding": [(None, None)],         # replicated (tied lookup + logits)
    "unembed": [(None, None)],
    "wo": [("tp", None, None)],          # attention 3-D: shard heads (contraction)
    "we_o": [(None, "tp", None)],        # moe: shard d_ff (contraction)
    "out_proj": [("tp", None)],          # ssm: shard d_inner (contraction)
}
THROUGHPUT_MLP_WO_RULES = [("tp", None)]

# Canonical chunk count of the throughput ruleset's row-parallel psum: the
# down-projection contraction is ALWAYS split into this many f32-rounded
# bf16 partials (tp4 = one per device via GSPMD; tp1 emulates the combine
# in ops.rowparallel_einsum), so the ruleset's numerics are a property of
# the ruleset, not of the mesh it happens to run on. A contraction dim
# that this count does not divide replicates instead — on BOTH the weight
# side (here) and the activation side (ops.rowparallel_einsum), so the
# two fallbacks can never disagree.
ROWPARALLEL_CHUNKS = 4

# Leaves where the two serving rulesets intentionally differ: the
# contraction-side weights, plus the replicated embedding pair. Everything
# else must agree — tested.
CONTRACTION_LEAVES = ("wo", "we_o", "out_proj")
RULESET_DIVERGENT_LEAVES = CONTRACTION_LEAVES + ("embedding", "unembed")

AXIS_MAP = {"vocab": "model", "tp": "model"}


def _feasible(shape, cand, mesh_shape) -> bool:
    for dim, ax in zip(shape[-len(cand):] if cand else [], cand):
        if ax is None:
            continue
        sz = mesh_shape[AXIS_MAP[ax]]
        if dim % sz:
            return False
    return True


def _spec_for_leaf(path: str, shape, mesh: Mesh, fsdp: bool,
                   fsdp_axes=("data",), rule_set=None, mlp_wo=None,
                   throughput: bool = False) -> P:
    name = path.rsplit("/", 1)[-1]
    rules = (PARAM_RULES if rule_set is None else rule_set).get(name)
    if name == "wo":
        # attention wo ([H, hd, d]) lives under mixer/cross; everything
        # else named wo is an mlp down-projection ([f, d]). Rank cannot
        # disambiguate: the scan stack's leading repeats dim makes a
        # stacked mlp wo rank-3 — matching it against the attention rule
        # used to shard the STACK dim (surfacing as a hoisted per-step
        # weight reshard all-to-all in the tp audit)
        parent = path.rsplit("/", 2)[-2] if "/" in path else ""
        if parent not in ("mixer", "cross"):
            rules = MLP_WO_RULES if mlp_wo is None else mlp_wo
    if rules is None:
        rules = [tuple(None for _ in shape)]
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    # throughput contraction split is pinned at ROWPARALLEL_CHUNKS
    # granularity: a sharded dim the canonical chunk count does not divide
    # must replicate even if the (smaller) mesh would — keeps the weight
    # fallback aligned with ops.rowparallel_einsum's activation fallback
    chunked = throughput and name in CONTRACTION_LEAVES

    chosen = None
    for cand in rules:
        if len(cand) <= len(shape) and _feasible(shape, cand, mesh_shape):
            if chunked:
                dims = shape[len(shape) - len(cand):]
                if any(a == "tp" and d % ROWPARALLEL_CHUNKS
                       for a, d in zip(cand, dims)):
                    continue
            chosen = cand
            break
    if chosen is None:
        chosen = tuple(None for _ in shape)
    # pad leading dims (scan repeats axis etc.)
    full = [None] * (len(shape) - len(chosen)) + \
        [AXIS_MAP[a] if a else None for a in chosen]

    if fsdp and len(shape) >= 2:
        # ZeRO-3: shard the largest still-unsharded dim over the fsdp axes
        fsdp_size = int(np.prod([mesh_shape[a] for a in fsdp_axes]))
        free = [i for i, a in enumerate(full) if a is None]
        free = [i for i in free if shape[i] % fsdp_size == 0]
        if free:
            i = max(free, key=lambda j: shape[j])
            full[i] = fsdp_axes[0] if len(fsdp_axes) == 1 else tuple(fsdp_axes)
    return P(*full)


def _walk(tree, prefix=""):
    if isinstance(tree, dict):
        for k in tree:
            yield from _walk(tree[k], f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _walk(v, f"{prefix}/#{i}")
    elif tree is not None:
        yield prefix, tree


def _map_with_path(tree, fn, prefix=""):
    if isinstance(tree, dict):
        return {k: _map_with_path(v, fn, f"{prefix}/{k}") for k, v in tree.items()}
    if isinstance(tree, list):
        return [_map_with_path(v, fn, f"{prefix}/#{i}") for i, v in enumerate(tree)]
    if isinstance(tree, tuple):
        return tuple(_map_with_path(v, fn, f"{prefix}/#{i}") for i, v in enumerate(tree))
    if tree is None:
        return None
    return fn(prefix, tree)


def param_specs(params, mesh: Mesh, *, fsdp: bool = False,
                fsdp_axes: Sequence[str] = ("data",),
                expert_parallel: bool = False, serving: bool = False,
                ruleset: str = "exact"):
    """PartitionSpec tree matching ``params`` (arrays or ShapeDtypeStructs).

    ``expert_parallel=True`` flips the MoE rule to shard the experts dim
    over the model axis (the §Perf experiment). ``serving=True`` selects a
    serving ruleset chosen by ``ruleset``: ``"exact"`` (default) is the
    reduction-free ``SERVING_PARAM_RULES`` (output-dim tensor parallelism
    only — the bitwise-identity ruleset; DESIGN.md §11); ``"throughput"``
    is the Megatron-style ``THROUGHPUT_PARAM_RULES`` (row-parallel
    down-projections, one psum per block; DESIGN.md §13)."""
    if ruleset not in ("exact", "throughput"):
        raise ValueError(f"unknown serving ruleset {ruleset!r}")
    if serving and ruleset == "throughput":
        rules, mlp_wo = THROUGHPUT_PARAM_RULES, THROUGHPUT_MLP_WO_RULES
    elif serving:
        rules, mlp_wo = SERVING_PARAM_RULES, SERVING_MLP_WO_RULES
    else:
        rules, mlp_wo = PARAM_RULES, MLP_WO_RULES
    if expert_parallel:
        rules = dict(rules)
        rules["we_i"] = [("tp", None, None), (None, None, "tp")]
        rules["we_g"] = [("tp", None, None), (None, None, "tp")]
        rules["we_o"] = [("tp", None, None), (None, "tp", None)]
    return _map_with_path(
        params, lambda p, leaf: _spec_for_leaf(
            p, leaf.shape, mesh, fsdp, tuple(fsdp_axes), rules, mlp_wo,
            throughput=serving and ruleset == "throughput"))


def _batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_spec(mesh: Mesh, batch: int, ndim: int) -> P:
    """Batch-leading data sharding; falls back to replication if the batch
    doesn't divide the data axes (e.g. batch=1 long-context)."""
    axes = _batch_axes(mesh)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    size = int(np.prod([mesh_shape[a] for a in axes]))
    if batch % size == 0:
        lead = axes if len(axes) > 1 else axes[0]
        return P(lead, *([None] * (ndim - 1)))
    # try data-only
    if "data" in mesh.axis_names and batch % mesh_shape["data"] == 0:
        return P("data", *([None] * (ndim - 1)))
    return P(*([None] * ndim))


def cache_specs(caches, cfg, mesh: Mesh, batch: int,
                seq_model_shard: bool = False):
    """KV cache / SSM state sharding for serving.

    Batch shards over (pod, data) when divisible; otherwise (batch=1
    long-context) the cache *sequence* dim shards over the data axes and the
    attention computes a distributed softmax (GSPMD inserts the combine).
    KV heads / MLA latent / SSM heads shard over model when divisible.
    """
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = _batch_axes(mesh)
    dsize = int(np.prod([mesh_shape[a] for a in axes]))
    batch_ok = batch % dsize == 0
    lead = (axes if len(axes) > 1 else axes[0]) if batch_ok else None
    seq_ax = None if batch_ok else (axes if len(axes) > 1 else axes[0])
    if seq_model_shard:
        # §Perf variant: KV sequence over the model axis (batch keeps its
        # data sharding); kv-head replication is replaced by a distributed
        # softmax over sequence shards
        seq_ax = ("model",) if batch_ok else tuple(axes) + ("model",)

    def leaf_spec(path, leaf):
        shape = leaf.shape
        nd = len(shape)
        name = path.rsplit("/", 1)[-1]
        scanned = "/scan/" in path or path.startswith("/scan")
        off = 1 if scanned else 0        # leading repeats dim -> None
        spec = [None] * nd
        if name in ("k", "v"):           # [.., B, S, Hkv, hd]
            spec[off] = lead
            spec[off + 1] = seq_ax
            if shape[off + 2] % mesh_shape.get("model", 1) == 0:
                spec[off + 2] = "model"
        elif name in ("k_scale", "v_scale"):   # [.., B, S, Hkv] (quant)
            spec[off] = lead
            spec[off + 1] = seq_ax
            if shape[off + 2] % mesh_shape.get("model", 1) == 0:
                spec[off + 2] = "model"
        elif name == "ckv":              # [.., B, S, width]
            spec[off] = lead
            spec[off + 1] = seq_ax
        elif name == "ckv_scale":        # [.., B, S] (quant MLA)
            spec[off] = lead
            spec[off + 1] = seq_ax
        elif name == "conv":             # [.., B, W-1, C]
            spec[off] = lead
            if shape[off + 2] % mesh_shape.get("model", 1) == 0:
                spec[off + 2] = "model"
        elif name == "ssm":              # [.., B, H, P, N]
            spec[off] = lead
            if shape[off + 1] % mesh_shape.get("model", 1) == 0:
                spec[off + 1] = "model"
        return P(*spec)

    return _map_with_path(caches, leaf_spec)


def paged_cache_specs(caches, mesh: Mesh):
    """PartitionSpec tree for the PAGED pool layout (serving/kv_pool.py).

    Attention leaves are shared block pools with no batch dim —
    ``[.., NB, bs, Hkv, hd]`` (scanned layers carry a leading repeats dim)
    — so the only shardable axis is the KV-head one, split over "model"
    when divisible (aligned with the head-sharded k/v projections of
    ``SERVING_PARAM_RULES``: the paged scatter and the per-head attention
    stay device-local). Quant ``*_scale`` siblings shard identically on
    their head dim; MLA ``ckv``/``ckv_scale`` pools and the block-size
    axis replicate; SSM leaves stay ``[B, ...]`` and replicate (they are
    O(1) per row). The block TABLES are host np arrays pushed replicated —
    every device must resolve every block index (DESIGN.md §11).
    """
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = mesh_shape.get("model", 1)

    def leaf_spec(path, leaf):
        shape = leaf.shape
        name = path.rsplit("/", 1)[-1]
        scanned = "/scan/" in path or path.startswith("/scan")
        off = 1 if scanned else 0        # leading repeats dim -> None
        spec = [None] * len(shape)
        if name in ("k", "v") and shape[off + 2] % model == 0:
            spec[off + 2] = "model"      # [.., NB, bs, Hkv, hd]
        elif name in ("k_scale", "v_scale") and shape[off + 2] % model == 0:
            spec[off + 2] = "model"      # [.., NB, bs, Hkv]
        # ckv / ckv_scale / conv / ssm: replicated
        return P(*spec)

    return _map_with_path(caches, leaf_spec)


def replicated_specs(tree):
    """A PartitionSpec tree replicating every leaf of ``tree`` — the
    serving draft model's sharding (small enough to live whole on every
    device; replication keeps its K sequential forwards collective-free)."""
    return _map_with_path(tree, lambda p, leaf: P())


def to_named(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
