"""Synthetic data pipeline.

Offline reproduction of the paper's instruct corpora (Magpie, Evol-Code,
OpenR1-Math...) is impossible; what the PARD *mechanisms* need from data is
(a) learnable sequential structure so target and draft models correlate, and
(b) a deterministic, seedable stream so every experiment is reproducible.

``MarkovCorpus`` generates sequences from a sparse per-token Markov chain with
Zipf-distributed marginals — a standard stand-in for language statistics. A
``prompt/continuation`` split makes it usable for both training and
generation benchmarks. The streaming interface (`batches`) mirrors a real
sharded data loader: infinite iterator, per-host sharding hook, fixed shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class MarkovCorpus:
    vocab_size: int
    branching: int = 4          # out-degree of the transition graph
    zipf_a: float = 1.3
    seed: int = 0
    # transition temperature: lower -> more predictable text
    determinism: float = 0.7

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v, b = self.vocab_size, self.branching
        self._succ = rng.integers(0, v, size=(v, b))
        # transition distribution = softmax(z * determinism): higher
        # determinism -> peakier transitions (more predictable "text", the
        # high-acceptance regime of the paper's code/math benchmarks)
        z = rng.normal(size=(v, b))
        ez = np.exp((z - z.max(axis=1, keepdims=True)) * self.determinism)
        self._probs = ez / ez.sum(axis=1, keepdims=True)
        # Zipf marginal for sequence starts
        ranks = np.arange(1, v + 1, dtype=np.float64)
        z = ranks ** (-self.zipf_a)
        self._start = z / z.sum()

    def sample(self, rng: np.random.Generator, batch: int, seq_len: int
               ) -> np.ndarray:
        out = np.empty((batch, seq_len), np.int32)
        cur = rng.choice(self.vocab_size, size=batch, p=self._start)
        out[:, 0] = cur
        for t in range(1, seq_len):
            u = rng.random(batch)
            cdf = np.cumsum(self._probs[cur], axis=1)
            choice = (u[:, None] > cdf).sum(axis=1)
            cur = self._succ[cur, choice]
            out[:, t] = cur
        return out

    def batches(self, batch: int, seq_len: int, *, seed: int = 0,
                shard: int = 0, num_shards: int = 1) -> Iterator[np.ndarray]:
        """Infinite deterministic stream; distinct shards get disjoint
        sub-streams (multi-host data parallelism hook)."""
        rng = np.random.default_rng((seed, shard, num_shards))
        while True:
            yield self.sample(rng, batch, seq_len)

    def prompts(self, rng: np.random.Generator, batch: int, prompt_len: int
                ) -> np.ndarray:
        return self.sample(rng, batch, prompt_len)
