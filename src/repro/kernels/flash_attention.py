"""Causal GQA flash attention — Pallas TPU kernel.

Grid: (batch, q_head, num_q_blocks, num_kv_blocks), kv innermost. The
output block is revisited across the kv dimension; running max / sum /
accumulator live in VMEM scratch (the standard TPU flash-attention
structure). Supports sliding-window masking and gemma2-style attention
logit softcapping.

TPU adaptation notes (vs the CUDA flash-attention the paper's frameworks
use): block shapes are MXU/VPU aligned — q blocks of 128 rows, kv blocks of
128-512, head_dim padded to a multiple of 128 by ops.py; masks are computed
from block-relative iotas (no [T,T] mask tensor touches HBM); fully-masked
(q,kv) block pairs are skipped with pl.when on block indices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *, scale, causal,
            window, softcap, block_q, block_k, seq_len):
    qi, ki = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q_start = qi * block_q
    k_start = ki * block_k

    # block-level skip: causal (kv entirely after q) or window (kv entirely
    # before the window of the newest query in the block)
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window:
        run = jnp.logical_and(
            run, k_start + block_k - 1 > q_start - window) if causal else run

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)     # [bq, d]
        k = k_ref[0, :, 0, :].astype(jnp.float32)     # [bk, d]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = k_pos < seq_len                         # padded kv tail
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_s[...] = acc_s[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _final():
        denom = l_s[...]
        denom = jnp.where(denom == 0.0, 1.0, denom)   # fully-masked rows -> 0
        o_ref[0, :, 0, :] = (acc_s[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    scale=None, block_q=128, block_k=128, interpret=False):
    """q: [B, T, Hq, D]; k, v: [B, S, Hkv, D]. T and S must be multiples of
    the block sizes and D should be 128-aligned (ops.py pads)."""
    b, t, hq, d = q.shape
    s_len = k.shape[1]
    hkv = k.shape[2]
    g = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    grid = (b, hq, pl.cdiv(t, block_q), pl.cdiv(s_len, block_k))

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, seq_len=s_len)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d), lambda bi, h, qi, ki: (bi, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda bi, h, qi, ki, g=g: (bi, ki, h // g, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda bi, h, qi, ki, g=g: (bi, ki, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda bi, h, qi, ki: (bi, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t, hq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
