"""Mamba2 SSD chunked scan — Pallas TPU kernel.

Grid: (batch, head, num_chunks), chunks innermost; the running state
S [P, N] lives in VMEM scratch and is carried across chunk iterations
(sequential dependence is exactly the flash-attention revisiting pattern,
with the state playing the accumulator role).

Per chunk (length L):
  cum_t   = cumsum(dt_t * A)                         (log-decay prefix)
  y_intra = ((C B^T) ∘ exp(cum_i - cum_j) ∘ causal) @ (dt x)
  y_state = (C @ S_in) * exp(cum)
  S_out   = S_in * exp(cum_L) + sum_j exp(cum_L - cum_j) dt_j x_j B_j^T

The intra-chunk term is two MXU matmuls of shape [L,N]x[N,L] and [L,L]x[L,P]
— chunk length L is chosen 128/256 so both hit the systolic array at full
tile occupancy; dt/A gating is VPU elementwise work on [L] vectors.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, s0_ref, y_ref, sf_ref, s_s,
            *, chunk):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        s_s[...] = s0_ref[0, 0, :, :].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)         # [L, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)          # [L]
    A = a_ref[0]                                       # scalar (per head)
    B = b_ref[0, :, :].astype(jnp.float32)            # [L, N]
    C = c_ref[0, :, :].astype(jnp.float32)            # [L, N]

    dtA = dt * A                                       # [L]
    cum = jnp.cumsum(dtA)                              # [L]
    seq = x.shape[0]
    i = jax.lax.broadcasted_iota(jnp.int32, (seq, seq), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (seq, seq), 1)
    w = jnp.where(i >= j, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
    cb = jnp.dot(C, B.T, preferred_element_type=jnp.float32)   # [L, L]
    gate = w * cb
    xdt = x * dt[:, None]                              # [L, P]
    y_intra = jnp.dot(gate, xdt, preferred_element_type=jnp.float32)

    S = s_s[...]                                       # [P, N]
    y_state = jnp.dot(C, S.T, preferred_element_type=jnp.float32) \
        * jnp.exp(cum)[:, None]                        # [L, P]... (C@S^T)[l,p]
    y_ref[0, :, 0, :] = (y_intra + y_state).astype(y_ref.dtype)

    decay_end = jnp.exp(cum[-1] - cum)                 # [L]
    S_new = S * jnp.exp(cum[-1]) + jnp.dot(
        (xdt * decay_end[:, None]).T, B,
        preferred_element_type=jnp.float32)            # [P, N]
    s_s[...] = S_new

    @pl.when(ci == nc - 1)
    def _final():
        sf_ref[0, 0, :, :] = S_new.astype(sf_ref.dtype)


def ssd_chunked_kernel(x, dt, A, B, C, init_state=None, *, chunk=128,
                       interpret=False):
    """x: [b, t, h, p]; dt: [b, t, h] (post-softplus); A: [h] (negative);
    B, C: [b, t, n]; init_state: [b, h, p, n] or None.
    Returns (y [b,t,h,p], final_state [b,h,p,n]). t must be a multiple of
    ``chunk`` (ops.py pads)."""
    b, t, h, p = x.shape
    n = B.shape[-1]
    assert t % chunk == 0, "pad t to a chunk multiple in ops.py"
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)
    nc = t // chunk

    kern = functools.partial(_kernel, chunk=chunk)
    # B/C are shared across heads: index maps ignore the head coordinate
    y, sf = pl.pallas_call(
        kern,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hh, ci: (bi, ci, hh, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hh, ci: (bi, ci, hh)),
            pl.BlockSpec((1,), lambda bi, hh, ci: (hh,)),
            pl.BlockSpec((1, chunk, n), lambda bi, hh, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hh, ci: (bi, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hh, ci: (bi, hh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hh, ci: (bi, ci, hh, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hh, ci: (bi, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), B, C, init_state.astype(jnp.float32))
    return y, sf
