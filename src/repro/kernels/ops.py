"""Public jit'd wrappers around the Pallas kernels.

Handle padding to TPU-aligned block shapes, GQA head grouping, dtype
plumbing, and interpret-mode dispatch (CPU backend -> interpret=True so the
kernels validate on this container; on TPU they compile natively).
"""
from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp

from . import decode_attention as _dec
from . import flash_attention as _fa
from . import pard_attention as _pard
from . import ssd as _ssd
from . import tree_attention as _tree


def _interpret(flag):
    if flag is not None:
        return flag
    return jax.default_backend() == "cpu"


# --------------------------------------------------------------------------
# Activation sharding hints (sharded serving, DESIGN.md §11)
#
# The serving executor traces its fused steps under ``activation_mesh`` so
# the forward can pin GSPMD's layout choices at the two places they would
# otherwise break bitwise cross-mesh identity: a model-sharded activation
# feeding a contraction (attention heads into wo, mlp hidden into the
# down-projection, vocab-sharded logits into softmax/argmax) lets the
# partitioner pick partial-sum reduction, whose accumulation order differs
# from the single-device dot. ``gather_activation`` forces the all-gather
# FIRST, so every contraction runs full-operand on every device and the
# tokens match across mesh shapes exactly. With no mesh set (training, the
# uniform generate_* paths, tier-1 tests) both helpers are identity.
# --------------------------------------------------------------------------

_ACTIVATION_MESH = None


@contextlib.contextmanager
def activation_mesh(mesh):
    """Trace-time context: the mesh ``gather_activation`` replicates onto
    (None = the hints are no-ops). Set around jit TRACING — the hints bake
    into the compiled computation, so the context only needs to wrap the
    call sites that may trigger a (re)trace."""
    global _ACTIVATION_MESH
    prev, _ACTIVATION_MESH = _ACTIVATION_MESH, mesh
    try:
        yield
    finally:
        _ACTIVATION_MESH = prev


def gather_activation(x):
    """Constrain ``x`` to be fully replicated (all-gather any model-sharded
    dim) before a contraction / normalization consumes it. Identity when no
    activation mesh is set."""
    if _ACTIVATION_MESH is None or x is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(_ACTIVATION_MESH,
                                      jax.sharding.PartitionSpec()))


def _pad_axis(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if not pad:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    scale=None, block_q=128, block_k=128, interpret=None):
    """Drop-in for ref.flash_attention_ref. Pads T/S/D to block multiples."""
    interpret = _interpret(interpret)
    b, t, hq, d = q.shape
    block_q = min(block_q, max(8, 1 << (t - 1).bit_length()))
    block_k = min(block_k, max(8, 1 << (k.shape[1] - 1).bit_length()))
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    q, _ = _pad_axis(q, 1, block_q)
    k, s_orig = _pad_axis(k, 1, block_k)
    v, _ = _pad_axis(v, 1, block_k)
    # padded kv tail is masked via seq_len; padded q rows are dropped below
    out = _fa.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, scale=scale, block_q=block_q,
                              block_k=block_k, interpret=interpret)
    return out[:, :t]


@functools.partial(jax.jit, static_argnames=(
    "window", "softcap", "scale", "block_k", "interpret"))
def decode_attention(q, k, v, kv_len, q_pos, *, k_scale=None, v_scale=None,
                     window=0, softcap=0.0, scale=None, block_k=256,
                     interpret=None):
    interpret = _interpret(interpret)
    b, tq, hq, d = q.shape
    block_k = min(block_k, max(8, 1 << (k.shape[1] - 1).bit_length()))
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    k, _ = _pad_axis(k, 1, block_k)
    v, _ = _pad_axis(v, 1, block_k)
    if k_scale is not None:
        k_scale, _ = _pad_axis(k_scale, 1, block_k)
        v_scale, _ = _pad_axis(v_scale, 1, block_k)
    return _dec.decode_attention(q, k, v, kv_len, q_pos, k_scale=k_scale,
                                 v_scale=v_scale, window=window,
                                 softcap=softcap, scale=scale,
                                 block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "window", "softcap", "scale", "interpret"))
def decode_attention_paged(q, k_pages, v_pages, block_tables, kv_len, q_pos,
                           *, k_scale=None, v_scale=None, window=0,
                           softcap=0.0, scale=None, interpret=None):
    """Paged-pool variant: k/v are [NB, block, Hkv, D] pools indirected by
    ``block_tables`` [B, MBS]. The pool's block size IS the kernel's kv
    block, so no padding is needed — the grid sweeps the table entries.
    k_scale/v_scale: optional [NB, block, Hkv] dequant scales for
    quantized pools."""
    interpret = _interpret(interpret)
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    return _dec.decode_attention_paged(q, k_pages, v_pages, block_tables,
                                       kv_len, q_pos, k_scale=k_scale,
                                       v_scale=v_scale, window=window,
                                       softcap=softcap, scale=scale,
                                       interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "window", "softcap", "scale", "block_k", "interpret"))
def tree_attention(q, k, v, kv_len, q_pos, win_start, anc, *, win_len=None,
                   k_scale=None, v_scale=None, window=0, softcap=0.0,
                   scale=None, block_k=256, interpret=None):
    """Tree-verification attention against a contiguous cache. ``anc`` is
    the [B, Tq] uint32 packed ancestor bitmask (bit j = window slot j
    visible); ``win_start`` the cache index of window slot 0; ``win_len``
    the optional [B] per-row count of meaningful window slots (per-request
    tree templates — None means all Tq slots); k_scale/v_scale: optional
    [B, S, Hkv] dequant scales for quantized k/v."""
    interpret = _interpret(interpret)
    d = q.shape[-1]
    block_k = min(block_k, max(8, 1 << (k.shape[1] - 1).bit_length()))
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    k, _ = _pad_axis(k, 1, block_k)
    v, _ = _pad_axis(v, 1, block_k)
    if k_scale is not None:
        k_scale, _ = _pad_axis(k_scale, 1, block_k)
        v_scale, _ = _pad_axis(v_scale, 1, block_k)
    return _tree.tree_attention(q, k, v, kv_len, q_pos, win_start, anc,
                                win_len=win_len, k_scale=k_scale,
                                v_scale=v_scale, window=window,
                                softcap=softcap, scale=scale,
                                block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "window", "softcap", "scale", "interpret"))
def tree_attention_paged(q, k_pages, v_pages, block_tables, kv_len, q_pos,
                         win_start, anc, *, win_len=None, k_scale=None,
                         v_scale=None, window=0, softcap=0.0, scale=None,
                         interpret=None):
    """Paged-pool tree verification: k/v are [NB, block, Hkv, D] pools
    indirected by ``block_tables`` [B, MBS]; the pool's block size IS the
    kernel's kv block (no padding), exactly like decode_attention_paged.
    ``win_len``: optional [B] per-row meaningful window slots;
    k_scale/v_scale: optional [NB, block, Hkv] dequant scales for
    quantized pools."""
    interpret = _interpret(interpret)
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    return _tree.tree_attention_paged(q, k_pages, v_pages, block_tables,
                                      kv_len, q_pos, win_start, anc,
                                      win_len=win_len, k_scale=k_scale,
                                      v_scale=v_scale, window=window,
                                      softcap=softcap, scale=scale,
                                      interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "scale", "softcap", "block_q", "block_k", "interpret"))
def pard_attention(q, k, v, segment, base, *, scale=None, softcap=0.0,
                   block_q=128, block_k=128, interpret=None):
    """GQA is handled by repeating KV heads (draft models are small; the
    repeat is cheap relative to the mask-aware attention itself)."""
    interpret = _interpret(interpret)
    b, t, hq, d = q.shape
    hkv = k.shape[2]
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    block_q = min(block_q, max(8, 1 << (t - 1).bit_length()))
    block_k = min(block_k, block_q)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    q, _ = _pad_axis(q, 1, block_q)
    k, _ = _pad_axis(k, 1, block_k)
    v, _ = _pad_axis(v, 1, block_k)
    seg, _ = _pad_axis(segment.astype(jnp.int32), 1, block_q)  # pad seg=0
    bas, _ = _pad_axis(base.astype(jnp.int32), 1, block_q)
    out = _pard.pard_attention(q, k, v, seg, bas, scale=scale,
                               softcap=softcap, block_q=block_q,
                               block_k=block_k, interpret=interpret)
    return out[:, :t]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked(x, dt, A, B, C, init_state=None, *, chunk=128,
                interpret=None):
    interpret = _interpret(interpret)
    b, t, h, p = x.shape
    chunk = min(chunk, max(8, 1 << (t - 1).bit_length()))
    x, t_orig = _pad_axis(x, 1, chunk)
    dt, _ = _pad_axis(dt, 1, chunk)      # padded dt=0 -> exp(0)=1, x=0: no-op
    B, _ = _pad_axis(B, 1, chunk)
    C, _ = _pad_axis(C, 1, chunk)
    y, sf = _ssd.ssd_chunked_kernel(x, dt, A, B, C, init_state, chunk=chunk,
                                    interpret=interpret)
    return y[:, :t_orig], sf
