"""Public jit'd wrappers around the Pallas kernels.

Handle padding to TPU-aligned block shapes, GQA head grouping, dtype
plumbing, and interpret-mode dispatch (CPU backend -> interpret=True so the
kernels validate on this container; on TPU they compile natively).
"""
from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp

from . import decode_attention as _dec
from . import flash_attention as _fa
from . import pard_attention as _pard
from . import ssd as _ssd
from . import tree_attention as _tree


def _interpret(flag):
    if flag is not None:
        return flag
    return jax.default_backend() == "cpu"


# --------------------------------------------------------------------------
# Activation sharding hints (sharded serving, DESIGN.md §11 / §13)
#
# The serving executor traces its fused steps under ``activation_mesh`` so
# the forward can pin GSPMD's layout choices at the places they would
# otherwise drift. Two rulesets share the same seams:
#
# "exact" (default): a model-sharded activation feeding a contraction
# (attention heads into wo, mlp hidden into the down-projection,
# vocab-sharded logits into softmax/argmax) lets the partitioner pick
# partial-sum reduction, whose accumulation order differs from the
# single-device dot. ``partial_activation`` behaves as ``gather_activation``
# — force the all-gather FIRST, so every contraction runs full-operand on
# every device and the tokens match across mesh shapes bitwise.
#
# "throughput": ``partial_activation`` instead KEEPS the activation
# model-sharded on its contraction axis between the column-parallel
# up-projection and the row-parallel down-projection
# (THROUGHPUT_PARAM_RULES). Each device contracts its local shard; the
# post-contraction ``gather_activation`` constrains the partial product to
# replicated, which GSPMD realizes as exactly ONE psum (all-reduce) per
# attention block / MLP instead of per-contraction full-activation
# all-gathers. Tokens then match tp1 only to tolerance (accumulation
# order), never bitwise.
#
# With no mesh set (training, the uniform generate_* paths, tier-1 tests)
# both helpers are identity.
# --------------------------------------------------------------------------

_ACTIVATION_MESH = None
_ACTIVATION_RULESET = "exact"


@contextlib.contextmanager
def activation_mesh(mesh, ruleset="exact"):
    """Trace-time context: the mesh the activation hints constrain onto
    (None = the hints are no-ops) and the serving ruleset steering
    ``partial_activation``. Set around jit TRACING — the hints bake into
    the compiled computation, so the context only needs to wrap the call
    sites that may trigger a (re)trace."""
    global _ACTIVATION_MESH, _ACTIVATION_RULESET
    prev = (_ACTIVATION_MESH, _ACTIVATION_RULESET)
    _ACTIVATION_MESH, _ACTIVATION_RULESET = mesh, ruleset
    try:
        yield
    finally:
        _ACTIVATION_MESH, _ACTIVATION_RULESET = prev


def gather_activation(x):
    """Constrain ``x`` to be fully replicated before a contraction /
    normalization / sampling consumes it. Identity when no activation mesh
    is set. Under the throughput ruleset this is the POST-contraction seam:
    constraining the locally-contracted partial product to replicated is
    what makes GSPMD emit the block's single psum."""
    if _ACTIVATION_MESH is None or x is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(_ACTIVATION_MESH,
                                      jax.sharding.PartitionSpec()))


def partial_activation(x, axis=-1):
    """PRE-combine seam between the column- and row-parallel halves.

    Exact ruleset: alias of ``gather_activation`` (full-operand
    contraction, bitwise identity). Throughput ruleset: keep ``x``
    model-sharded on ``axis`` — ``rowparallel_einsum`` applies it to the
    canonical chunk axis of its partial products so the f32 combine over
    that axis lowers to the block's single psum; falls back to the gather
    when the axis does not divide the model mesh (mirroring the replicate
    fallback in THROUGHPUT_PARAM_RULES). Identity when no mesh is set."""
    if _ACTIVATION_MESH is None or x is None:
        return x
    if _ACTIVATION_RULESET != "throughput":
        return gather_activation(x)
    mesh_shape = dict(zip(_ACTIVATION_MESH.axis_names,
                          _ACTIVATION_MESH.devices.shape))
    model = mesh_shape.get("model", 1)
    if model <= 1 or x.shape[axis] % model:
        return gather_activation(x)
    spec = [None] * x.ndim
    spec[axis if axis >= 0 else x.ndim + axis] = "model"
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(_ACTIVATION_MESH,
                                      jax.sharding.PartitionSpec(*spec)))


def rowparallel_einsum(eq, x, w, *, x_axis, w_axis):
    """Down-projection contraction at a serving-ruleset seam.

    Exact ruleset (and training / no-mesh, where both hints are identity):
    gather ``x`` to replicated and contract whole — the reduction-free
    bitwise path, graph-identical to the pre-ruleset code.

    Throughput ruleset: Megatron row parallelism with numerics pinned at
    canonical-chunk granularity. The contraction dim (``x_axis`` of ``x``
    / ``w_axis`` of ``w``) is reshaped into a ``ROWPARALLEL_CHUNKS`` (=4)
    chunk axis; ONE einsum contracts per chunk (XLA dots accumulate in f32
    and round to the compute dtype once per chunk → bf16 partials), the
    chunk axis is constrained model-sharded, and the partials combine by a
    single f32-upcast sum rounded once. Mesh-size independence falls out
    structurally:

    - model axis = 4: one bf16 chunk-partial per device; the f32 sum over
      the sharded chunk axis lowers to the block's single psum.
    - model axis = 2: two chunk-partials per device; local f32 partial
      sums + a 2-way f32 psum.
    - model axis = 1 (the reference the benchmark gates compare against):
      the same graph with the sum evaluated locally.

    An f32 sum of four bf16-valued terms is exact in f32 arithmetic
    (8-bit mantissas; associativity cannot matter below a ~2^16 exponent
    spread), so every mesh size rounds the SAME real number to bf16 once —
    bitwise-identical greedy tokens across tp1/tp2/tp4, verified by the
    serve_sharded match-rate gate and tests/test_tp_ruleset.py. (XLA CPU's
    bf16 all-reduce computes exactly this f32-upcast-sum-round-once —
    discovered empirically; its HLO shows the reduction ``promoted`` to
    f32 — so the earlier bf16-psum formulation agreed bitwise too, but
    only as a backend property, not by construction.)

    Contraction dim not divisible by 4: replicate fallback (gather + whole
    contraction), mirroring THROUGHPUT_PARAM_RULES' weight-side fallback,
    at every mesh size.
    """
    if _ACTIVATION_MESH is None or _ACTIVATION_RULESET != "throughput":
        return jnp.einsum(eq, gather_activation(x), w)
    from ..sharding.specs import ROWPARALLEL_CHUNKS
    nc = ROWPARALLEL_CHUNKS
    if x.shape[x_axis] % nc or w.shape[w_axis] % nc:
        return jnp.einsum(eq, gather_activation(x), w)
    ins, out = eq.split("->")
    xs, ws = ins.split(",")
    assert "Z" not in eq, eq  # chunk-axis label must be free
    xs2 = xs[:x_axis] + "Z" + xs[x_axis:]
    ws2 = ws[:w_axis] + "Z" + ws[w_axis:]

    def split(a, axis):
        axis = axis % a.ndim
        sh = a.shape
        return a.reshape(sh[:axis] + (nc, sh[axis] // nc) + sh[axis + 1:])

    partials = jnp.einsum(f"{xs2},{ws2}->Z{out}", split(x, x_axis),
                          split(w, w_axis))
    partials = partial_activation(partials, axis=0)
    return jnp.sum(partials.astype(jnp.float32), axis=0).astype(x.dtype)


def _pad_axis(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if not pad:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    scale=None, block_q=128, block_k=128, interpret=None):
    """Drop-in for ref.flash_attention_ref. Pads T/S/D to block multiples."""
    interpret = _interpret(interpret)
    b, t, hq, d = q.shape
    block_q = min(block_q, max(8, 1 << (t - 1).bit_length()))
    block_k = min(block_k, max(8, 1 << (k.shape[1] - 1).bit_length()))
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    q, _ = _pad_axis(q, 1, block_q)
    k, s_orig = _pad_axis(k, 1, block_k)
    v, _ = _pad_axis(v, 1, block_k)
    # padded kv tail is masked via seq_len; padded q rows are dropped below
    out = _fa.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, scale=scale, block_q=block_q,
                              block_k=block_k, interpret=interpret)
    return out[:, :t]


@functools.partial(jax.jit, static_argnames=(
    "window", "softcap", "scale", "block_k", "interpret"))
def decode_attention(q, k, v, kv_len, q_pos, *, k_scale=None, v_scale=None,
                     window=0, softcap=0.0, scale=None, block_k=256,
                     interpret=None):
    interpret = _interpret(interpret)
    b, tq, hq, d = q.shape
    block_k = min(block_k, max(8, 1 << (k.shape[1] - 1).bit_length()))
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    k, _ = _pad_axis(k, 1, block_k)
    v, _ = _pad_axis(v, 1, block_k)
    if k_scale is not None:
        k_scale, _ = _pad_axis(k_scale, 1, block_k)
        v_scale, _ = _pad_axis(v_scale, 1, block_k)
    return _dec.decode_attention(q, k, v, kv_len, q_pos, k_scale=k_scale,
                                 v_scale=v_scale, window=window,
                                 softcap=softcap, scale=scale,
                                 block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "window", "softcap", "scale", "interpret"))
def decode_attention_paged(q, k_pages, v_pages, block_tables, kv_len, q_pos,
                           *, k_scale=None, v_scale=None, window=0,
                           softcap=0.0, scale=None, interpret=None):
    """Paged-pool variant: k/v are [NB, block, Hkv, D] pools indirected by
    ``block_tables`` [B, MBS]. The pool's block size IS the kernel's kv
    block, so no padding is needed — the grid sweeps the table entries.
    k_scale/v_scale: optional [NB, block, Hkv] dequant scales for
    quantized pools."""
    interpret = _interpret(interpret)
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    return _dec.decode_attention_paged(q, k_pages, v_pages, block_tables,
                                       kv_len, q_pos, k_scale=k_scale,
                                       v_scale=v_scale, window=window,
                                       softcap=softcap, scale=scale,
                                       interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "window", "softcap", "scale", "block_k", "interpret"))
def tree_attention(q, k, v, kv_len, q_pos, win_start, anc, *, win_len=None,
                   k_scale=None, v_scale=None, window=0, softcap=0.0,
                   scale=None, block_k=256, interpret=None):
    """Tree-verification attention against a contiguous cache. ``anc`` is
    the [B, Tq] uint32 packed ancestor bitmask (bit j = window slot j
    visible); ``win_start`` the cache index of window slot 0; ``win_len``
    the optional [B] per-row count of meaningful window slots (per-request
    tree templates — None means all Tq slots); k_scale/v_scale: optional
    [B, S, Hkv] dequant scales for quantized k/v."""
    interpret = _interpret(interpret)
    d = q.shape[-1]
    block_k = min(block_k, max(8, 1 << (k.shape[1] - 1).bit_length()))
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    k, _ = _pad_axis(k, 1, block_k)
    v, _ = _pad_axis(v, 1, block_k)
    if k_scale is not None:
        k_scale, _ = _pad_axis(k_scale, 1, block_k)
        v_scale, _ = _pad_axis(v_scale, 1, block_k)
    return _tree.tree_attention(q, k, v, kv_len, q_pos, win_start, anc,
                                win_len=win_len, k_scale=k_scale,
                                v_scale=v_scale, window=window,
                                softcap=softcap, scale=scale,
                                block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "window", "softcap", "scale", "interpret"))
def tree_attention_paged(q, k_pages, v_pages, block_tables, kv_len, q_pos,
                         win_start, anc, *, win_len=None, k_scale=None,
                         v_scale=None, window=0, softcap=0.0, scale=None,
                         interpret=None):
    """Paged-pool tree verification: k/v are [NB, block, Hkv, D] pools
    indirected by ``block_tables`` [B, MBS]; the pool's block size IS the
    kernel's kv block (no padding), exactly like decode_attention_paged.
    ``win_len``: optional [B] per-row meaningful window slots;
    k_scale/v_scale: optional [NB, block, Hkv] dequant scales for
    quantized pools."""
    interpret = _interpret(interpret)
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    return _tree.tree_attention_paged(q, k_pages, v_pages, block_tables,
                                      kv_len, q_pos, win_start, anc,
                                      win_len=win_len, k_scale=k_scale,
                                      v_scale=v_scale, window=window,
                                      softcap=softcap, scale=scale,
                                      interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "scale", "softcap", "block_q", "block_k", "interpret"))
def pard_attention(q, k, v, segment, base, *, scale=None, softcap=0.0,
                   block_q=128, block_k=128, interpret=None):
    """GQA is handled by repeating KV heads (draft models are small; the
    repeat is cheap relative to the mask-aware attention itself)."""
    interpret = _interpret(interpret)
    b, t, hq, d = q.shape
    hkv = k.shape[2]
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    block_q = min(block_q, max(8, 1 << (t - 1).bit_length()))
    block_k = min(block_k, block_q)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    q, _ = _pad_axis(q, 1, block_q)
    k, _ = _pad_axis(k, 1, block_k)
    v, _ = _pad_axis(v, 1, block_k)
    seg, _ = _pad_axis(segment.astype(jnp.int32), 1, block_q)  # pad seg=0
    bas, _ = _pad_axis(base.astype(jnp.int32), 1, block_q)
    out = _pard.pard_attention(q, k, v, seg, bas, scale=scale,
                               softcap=softcap, block_q=block_q,
                               block_k=block_k, interpret=interpret)
    return out[:, :t]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked(x, dt, A, B, C, init_state=None, *, chunk=128,
                interpret=None):
    interpret = _interpret(interpret)
    b, t, h, p = x.shape
    chunk = min(chunk, max(8, 1 << (t - 1).bit_length()))
    x, t_orig = _pad_axis(x, 1, chunk)
    dt, _ = _pad_axis(dt, 1, chunk)      # padded dt=0 -> exp(0)=1, x=0: no-op
    B, _ = _pad_axis(B, 1, chunk)
    C, _ = _pad_axis(C, 1, chunk)
    y, sf = _ssd.ssd_chunked_kernel(x, dt, A, B, C, init_state, chunk=chunk,
                                    interpret=interpret)
    return y[:, :t_orig], sf
