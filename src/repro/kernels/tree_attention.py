"""Tree-verification attention — Pallas TPU kernel.

Speculative *tree* verification (DESIGN.md §6): one target forward scores a
packed candidate tree of draft tokens instead of a single chain. The query
block holds the verify window ``[root | tree nodes]`` (root = re-processed
last committed token); its KV is written at consecutive cache slots
``win_start .. win_start + Tq - 1`` even though nodes on different branches
share logical (RoPE) positions. Plain causal masking is therefore wrong
inside the window — node i may only attend its ancestors — so the kernel
carries a packed per-query ancestor bitmask alongside the causal rule:

  * cache slot  < win_start             -> committed context: always allowed
    (optionally sliding-window limited against the query's logical position);
  * cache slot == win_start + j (j<Tq)  -> allowed iff bit j of ``anc[row]``
    is set (bit 0 = root; a node's mask is its parent's mask | its own bit)
    AND j < the row's ``win_len`` (per-request tree templates pad the batch
    window to the widest template; slots past a row's own template are
    meaningless and invisible);
  * everything is bounded by ``kv_index < min(kv_len, win_start + win_len)``
    — the per-row effective length, so a narrow-template row's KV sweep
    skips the padded window blocks entirely (swept bytes track the row's
    OWN tree, not the bank's widest).

Window sizes are <= 32 slots, so one uint32 bitmask per query row packs the
whole tree. Ancestors sit at most ``max_depth`` logical positions behind the
query, far inside any realistic sliding window, so the window test applies
to context keys only.

Like kernels/decode_attention.py, ONE kernel body serves both cache layouts:
contiguous ``[B, S, Hkv, D]`` rows, and the block-paged pool where the
scalar-prefetched block table resolves the pool indirection in the BlockSpec
index_map before the DMA. Blocks past ``kv_len`` are skipped, so swept bytes
track the actual cache fill.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, kvlen_ref, winstart_ref, winlen_ref, anc_ref, q_ref,
            k_ref, v_ref, *rest, scale, window, softcap, block_k, tq, g,
            quant=False):
    if quant:
        # quantized KV stream (DESIGN.md §10): per-(slot, head) float32
        # scales ride in two extra refs right after k/v
        ks_ref, vs_ref, o_ref, m_s, l_s, acc_s, qp_s, anc_s = rest
    else:
        ks_ref = vs_ref = None
        o_ref, m_s, l_s, acc_s, qp_s, anc_s = rest
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)
        # the per-row mask operands are k-block-invariant: expand the
        # [tq] position / ancestor-bitmask vectors to query-row shape ONCE
        # per (batch, head) program instead of on every k-block visit
        qp_s[...] = jnp.repeat(qpos_ref[0, :], g)[:, None]
        anc_s[...] = jnp.repeat(anc_ref[0, :], g)[:, None]

    kv_len = kvlen_ref[0]                              # scalar for this row
    ws = winstart_ref[0]
    wl = winlen_ref[0]                                 # row's own window
    eff_len = jnp.minimum(kv_len, ws + wl)             # per-row sweep bound
    k_start = ki * block_k

    @pl.when(k_start < eff_len)
    def _compute():
        q = q_ref[0, :, :, :].astype(jnp.float32)      # [tq, g, d]
        d = q.shape[-1]
        q2 = q.reshape(tq * g, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # [bk, d]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        if quant:
            # dequant fused into the sweep: the block expands against its
            # scales right after the DMA, still inside VMEM
            k = k * ks_ref[0, :, 0][:, None]
            v = v * vs_ref[0, :, 0][:, None]
        s = jnp.dot(q2, k.T, preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap

        # rows are (window slot i, group member): the mask depends only on
        # i — read the expansions hoisted into scratch at ki == 0
        qp_rows = qp_s[...]                            # [tq*g, 1] int32
        anc_rows = anc_s[...]                          # [tq*g, 1] uint32
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (tq * g, block_k), 1)
        ctx = k_pos < ws                               # committed context
        if window:
            ctx &= k_pos > qp_rows - window
        j = k_pos - ws                                 # window slot index
        in_win = (j >= 0) & (j < wl) & (j < tq)
        bit = (anc_rows >> jnp.clip(j, 0, tq - 1).astype(jnp.uint32)
               ) & jnp.uint32(1)
        mask = (k_pos < eff_len) & (ctx | (in_win & (bit == 1)))
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_s[...] = acc_s[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _final():
        denom = jnp.where(l_s[...] == 0.0, 1.0, l_s[...])
        o_ref[0, :, 0, :] = (acc_s[...] / denom).reshape(
            tq, g * acc_s.shape[-1]).astype(o_ref.dtype)


def tree_attention(q, k, v, kv_len, q_pos, win_start, anc, *, win_len=None,
                   k_scale=None, v_scale=None, window=0, softcap=0.0,
                   scale=None, block_k=256, interpret=False):
    """q: [B, Tq, Hq, D] — the packed verify window; k, v: [B, S, Hkv, D];
    kv_len: [B]; q_pos: [B, Tq] logical positions (root pos + depth);
    win_start: [B] cache index of window slot 0; anc: [B, Tq] uint32
    ancestor bitmasks (bit j = window slot j visible); win_len: [B] int32
    count of meaningful window slots per row (None = Tq for every row —
    single-template batches); k_scale/v_scale: optional [B, S, Hkv] float32
    dequant scales for quantized k/v (int8 / fp8)."""
    b, tq, hq, d = q.shape
    s_len, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    quant = k_scale is not None
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    if win_len is None:
        win_len = jnp.full((b,), tq, jnp.int32)

    qg = q.reshape(b, tq, hkv, g, d)
    grid = (b, hkv, pl.cdiv(s_len, block_k))

    kern = functools.partial(_kernel, scale=scale, window=window,
                             softcap=softcap, block_k=block_k, tq=tq, g=g,
                             quant=quant)

    in_specs = [
        pl.BlockSpec((1, tq), lambda bi, h, ki: (bi, 0)),       # q_pos
        pl.BlockSpec((1,), lambda bi, h, ki: (bi,)),            # kv_len
        pl.BlockSpec((1,), lambda bi, h, ki: (bi,)),            # win_start
        pl.BlockSpec((1,), lambda bi, h, ki: (bi,)),            # win_len
        pl.BlockSpec((1, tq), lambda bi, h, ki: (bi, 0)),       # anc
        pl.BlockSpec((1, tq, 1, g, d),
                     lambda bi, h, ki: (bi, 0, h, 0, 0)),       # q
        pl.BlockSpec((1, block_k, 1, d),
                     lambda bi, h, ki: (bi, ki, h, 0)),         # k
        pl.BlockSpec((1, block_k, 1, d),
                     lambda bi, h, ki: (bi, ki, h, 0)),         # v
    ]
    args = [q_pos.astype(jnp.int32), kv_len.astype(jnp.int32),
            win_start.astype(jnp.int32), win_len.astype(jnp.int32),
            anc.astype(jnp.uint32), qg, k, v]
    if quant:
        for _ in range(2):                                      # k/v scales
            in_specs.append(pl.BlockSpec((1, block_k, 1),
                                         lambda bi, h, ki: (bi, ki, h)))
        args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, tq, 1, g * d),
                               lambda bi, h, ki: (bi, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, tq, hkv, g * d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq * g, 1), jnp.float32),
            pltpu.VMEM((tq * g, 1), jnp.float32),
            pltpu.VMEM((tq * g, d), jnp.float32),
            pltpu.VMEM((tq * g, 1), jnp.int32),     # hoisted q positions
            pltpu.VMEM((tq * g, 1), jnp.uint32),    # hoisted ancestor masks
        ],
        interpret=interpret,
    )(*args)
    return out.reshape(b, tq, hq, d)


def _paged_kernel(bt_ref, qpos_ref, kvlen_ref, winstart_ref, winlen_ref,
                  anc_ref, q_ref, k_ref, v_ref, *rest, **kw):
    # bt_ref (the scalar-prefetched block table) is consumed only by the
    # BlockSpec index_maps; the compute body is the contiguous kernel's.
    _kernel(qpos_ref, kvlen_ref, winstart_ref, winlen_ref, anc_ref, q_ref,
            k_ref, v_ref, *rest, **kw)


def tree_attention_paged(q, k_pages, v_pages, block_tables, kv_len, q_pos,
                         win_start, anc, *, win_len=None, k_scale=None,
                         v_scale=None, window=0, softcap=0.0, scale=None,
                         interpret=False):
    """Paged-pool tree-verification attention.

    q: [B, Tq, Hq, D]; k_pages, v_pages: [NB, block, Hkv, D] shared pools;
    block_tables: [B, MBS] int32 (block 0 = reserved garbage block);
    kv_len: [B]; q_pos: [B, Tq] logical positions; win_start: [B];
    anc: [B, Tq] uint32 ancestor bitmasks; win_len: [B] int32 meaningful
    window slots per row (None = Tq); k_scale/v_scale: optional
    [NB, block, Hkv] float32 per-slot dequant scales when the pools are
    quantized (int8 / fp8) — they ride the same table indirection.
    """
    b, tq, hq, d = q.shape
    block, hkv = k_pages.shape[1], k_pages.shape[2]
    mbs = block_tables.shape[1]
    g = hq // hkv
    quant = k_scale is not None
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    if win_len is None:
        win_len = jnp.full((b,), tq, jnp.int32)

    qg = q.reshape(b, tq, hkv, g, d)
    kern = functools.partial(_paged_kernel, scale=scale, window=window,
                             softcap=softcap, block_k=block, tq=tq, g=g,
                             quant=quant)

    in_specs = [
        pl.BlockSpec((1, tq), lambda bi, h, ki, bt: (bi, 0)),   # q_pos
        pl.BlockSpec((1,), lambda bi, h, ki, bt: (bi,)),        # kv_len
        pl.BlockSpec((1,), lambda bi, h, ki, bt: (bi,)),        # win_start
        pl.BlockSpec((1,), lambda bi, h, ki, bt: (bi,)),        # win_len
        pl.BlockSpec((1, tq), lambda bi, h, ki, bt: (bi, 0)),   # anc
        pl.BlockSpec((1, tq, 1, g, d),
                     lambda bi, h, ki, bt: (bi, 0, h, 0, 0)),   # q
        pl.BlockSpec((1, block, 1, d),
                     lambda bi, h, ki, bt: (bt[bi, ki], 0, h, 0)),  # k
        pl.BlockSpec((1, block, 1, d),
                     lambda bi, h, ki, bt: (bt[bi, ki], 0, h, 0)),  # v
    ]
    args = [block_tables.astype(jnp.int32), q_pos.astype(jnp.int32),
            kv_len.astype(jnp.int32), win_start.astype(jnp.int32),
            win_len.astype(jnp.int32), anc.astype(jnp.uint32), qg, k_pages,
            v_pages]
    if quant:
        for _ in range(2):                                      # k/v scales
            in_specs.append(pl.BlockSpec(
                (1, block, 1), lambda bi, h, ki, bt: (bt[bi, ki], 0, h)))
        args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv, mbs),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, tq, 1, g * d),
                               lambda bi, h, ki, bt: (bi, 0, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((tq * g, 1), jnp.float32),
            pltpu.VMEM((tq * g, 1), jnp.float32),
            pltpu.VMEM((tq * g, d), jnp.float32),
            pltpu.VMEM((tq * g, 1), jnp.int32),     # hoisted q positions
            pltpu.VMEM((tq * g, 1), jnp.uint32),    # hoisted ancestor masks
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, tq, hkv, g * d), q.dtype),
        interpret=interpret,
    )(*args)
    return out.reshape(b, tq, hq, d)
