"""Speculative-verify / decode attention — Pallas TPU kernel.

The PARD serving hot path: a small query block (1 AR token or the K+1
verification window) attends to a long KV cache. This is the kernel the
paper's Table 6 bandwidth argument lives in: per iteration the draft+target
weights stream once, and the KV cache stream dominates — so the kernel's job
is to keep the cache read perfectly sequential and do the online softmax in
VMEM.

Grid: (batch, kv_head, num_kv_blocks). ALL queries for one kv head — the
(K+1) positions x G grouped q heads — are flattened into one [Tq*G, D] tile
that stays resident in VMEM across the whole cache sweep (Tq*G <= a few
hundred rows), while K/V blocks stream through. Per-row validity comes from
(kv_len, q_pos) scalars, prefetched to SMEM-like VMEM blocks.

Blocks past kv_len are skipped entirely (pl.when on the block index), so the
swept bytes scale with the *actual* cache fill, not the allocated max_len.

Two cache layouts share ONE kernel body:

  * contiguous — k/v are [B, S, Hkv, D]; grid step ki streams block ki of
    row b's buffer;
  * paged — k/v are a pool of fixed-size blocks [NB, block, Hkv, D] plus a
    per-row block table [B, MBS]. The table is scalar-prefetched
    (PrefetchScalarGridSpec) so the BlockSpec index_map can resolve the
    indirection *before* the DMA: grid step ki streams pool block
    table[b, ki], which holds row b's absolute positions
    [ki*block, (ki+1)*block). Unallocated entries point at the reserved
    garbage block 0 and are skipped by the kv_len guard anyway.

The kernel's masking logic is identical in both cases because a sequence
block index ki maps to the same absolute position range either way.

Validity is PER ROW — (kv_len, q_pos) scalars — so one launch serves the
serving engine's fused mixed batches (DESIGN.md §8): decoding rows sweep
their long cache while prefilling rows' chunks (q_pos = cursor + i,
kv_len = cursor + chunk) skip every block past their short fill, keeping
swept bytes proportional to each row's actual context.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, kvlen_ref, q_ref, k_ref, v_ref, *rest, scale, window,
            softcap, block_k, tq, g, quant=False):
    if quant:
        # quantized KV stream (DESIGN.md §10): per-(slot, head) float32
        # scales ride in two extra refs right after k/v
        ks_ref, vs_ref, o_ref, m_s, l_s, acc_s = rest
    else:
        ks_ref = vs_ref = None
        o_ref, m_s, l_s, acc_s = rest
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    kv_len = kvlen_ref[0]                              # scalar for this row
    k_start = ki * block_k

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0, :, :, :].astype(jnp.float32)      # [tq, g, d]
        d = q.shape[-1]
        q2 = q.reshape(tq * g, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # [bk, d]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        if quant:
            # dequant fused into the sweep: the block expands against its
            # scales right after the DMA, still inside VMEM
            k = k * ks_ref[0, :, 0][:, None]
            v = v * vs_ref[0, :, 0][:, None]
        s = jnp.dot(q2, k.T, preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap

        # rows are (q position i, group member): validity depends only on i
        qp = qpos_ref[0, :]                            # [tq]
        qp_rows = jnp.repeat(qp, g)[:, None]           # [tq*g, 1] — static
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (tq * g, block_k), 1)
        mask = (k_pos < kv_len) & (k_pos <= qp_rows)
        if window:
            mask &= k_pos > qp_rows - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_s[...] = acc_s[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _final():
        denom = jnp.where(l_s[...] == 0.0, 1.0, l_s[...])
        o_ref[0, :, 0, :] = (acc_s[...] / denom).reshape(
            tq, g * acc_s.shape[-1]).astype(o_ref.dtype)


def decode_attention(q, k, v, kv_len, q_pos, *, k_scale=None, v_scale=None,
                     window=0, softcap=0.0, scale=None, block_k=256,
                     interpret=False):
    """q: [B, Tq, Hq, D] (Tq small); k, v: [B, S, Hkv, D];
    kv_len: [B] int32 valid cache entries; q_pos: [B, Tq] absolute.
    k_scale/v_scale: optional [B, S, Hkv] float32 dequant scales for
    quantized k/v (int8 / fp8); dequant is fused into the stream."""
    b, tq, hq, d = q.shape
    s_len, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    quant = k_scale is not None
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    # group q heads by their kv head: [B, Tq, Hkv, G, D]
    qg = q.reshape(b, tq, hkv, g, d)
    grid = (b, hkv, pl.cdiv(s_len, block_k))

    kern = functools.partial(_kernel, scale=scale, window=window,
                             softcap=softcap, block_k=block_k, tq=tq, g=g,
                             quant=quant)

    in_specs = [
        pl.BlockSpec((1, tq), lambda bi, h, ki: (bi, 0)),       # q_pos
        pl.BlockSpec((1,), lambda bi, h, ki: (bi,)),            # kv_len
        pl.BlockSpec((1, tq, 1, g, d),
                     lambda bi, h, ki: (bi, 0, h, 0, 0)),       # q
        pl.BlockSpec((1, block_k, 1, d),
                     lambda bi, h, ki: (bi, ki, h, 0)),         # k
        pl.BlockSpec((1, block_k, 1, d),
                     lambda bi, h, ki: (bi, ki, h, 0)),         # v
    ]
    args = [q_pos.astype(jnp.int32), kv_len.astype(jnp.int32), qg, k, v]
    if quant:
        for _ in range(2):                                      # k/v scales
            in_specs.append(pl.BlockSpec((1, block_k, 1),
                                         lambda bi, h, ki: (bi, ki, h)))
        args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, tq, 1, g * d),
                               lambda bi, h, ki: (bi, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, tq, hkv, g * d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq * g, 1), jnp.float32),
            pltpu.VMEM((tq * g, 1), jnp.float32),
            pltpu.VMEM((tq * g, d), jnp.float32),
        ],
        interpret=interpret,
    )(*args)
    return out.reshape(b, tq, hq, d)


def _paged_kernel(bt_ref, qpos_ref, kvlen_ref, q_ref, k_ref, v_ref, *rest,
                  **kw):
    # bt_ref (the scalar-prefetched block table) is consumed only by the
    # BlockSpec index_maps; the compute body is the contiguous kernel's.
    _kernel(qpos_ref, kvlen_ref, q_ref, k_ref, v_ref, *rest, **kw)


def decode_attention_paged(q, k_pages, v_pages, block_tables, kv_len, q_pos,
                           *, k_scale=None, v_scale=None, window=0,
                           softcap=0.0, scale=None, interpret=False):
    """Paged-pool decode/verify attention.

    q: [B, Tq, Hq, D]; k_pages, v_pages: [NB, block, Hkv, D] shared pools;
    block_tables: [B, MBS] int32 (block 0 = reserved garbage block);
    kv_len: [B] int32 valid entries; q_pos: [B, Tq] absolute positions.
    k_scale/v_scale: optional [NB, block, Hkv] float32 per-slot dequant
    scales when the pools are quantized (int8 / fp8); the scale blocks
    ride the same table indirection as their pages.
    """
    b, tq, hq, d = q.shape
    block, hkv = k_pages.shape[1], k_pages.shape[2]
    mbs = block_tables.shape[1]
    g = hq // hkv
    quant = k_scale is not None
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    qg = q.reshape(b, tq, hkv, g, d)
    kern = functools.partial(_paged_kernel, scale=scale, window=window,
                             softcap=softcap, block_k=block, tq=tq, g=g,
                             quant=quant)

    in_specs = [
        pl.BlockSpec((1, tq), lambda bi, h, ki, bt: (bi, 0)),   # q_pos
        pl.BlockSpec((1,), lambda bi, h, ki, bt: (bi,)),        # kv_len
        pl.BlockSpec((1, tq, 1, g, d),
                     lambda bi, h, ki, bt: (bi, 0, h, 0, 0)),   # q
        pl.BlockSpec((1, block, 1, d),
                     lambda bi, h, ki, bt: (bt[bi, ki], 0, h, 0)),  # k
        pl.BlockSpec((1, block, 1, d),
                     lambda bi, h, ki, bt: (bt[bi, ki], 0, h, 0)),  # v
    ]
    args = [block_tables.astype(jnp.int32), q_pos.astype(jnp.int32),
            kv_len.astype(jnp.int32), qg, k_pages, v_pages]
    if quant:
        for _ in range(2):                                      # k/v scales
            in_specs.append(pl.BlockSpec(
                (1, block, 1), lambda bi, h, ki, bt: (bt[bi, ki], 0, h)))
        args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv, mbs),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, tq, 1, g * d),
                               lambda bi, h, ki, bt: (bi, 0, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((tq * g, 1), jnp.float32),
            pltpu.VMEM((tq * g, 1), jnp.float32),
            pltpu.VMEM((tq * g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, tq, hkv, g * d), q.dtype),
        interpret=interpret,
    )(*args)
    return out.reshape(b, tq, hq, d)
