"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic source of truth: each kernel's tests sweep shapes and
dtypes and assert allclose against these functions. They intentionally share
the model's reference attention core (models.attention.attend — itself pure
jnp) so the kernels are validated against exactly what the model computes.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..models.attention import (PardMaskInfo, TreeAttnInfo, attend,
                                dequantize_kv, gather_pages)
from ..models.ssm import ssd_scan_ref


def _maybe_dequant(k, v, k_scale, v_scale):
    """fp32 semantics for quantized KV: expand against the scales up front
    so the oracle computes on exactly the values the kernel dequantizes."""
    if k_scale is None:
        return k, v
    return dequantize_kv(k, k_scale), dequantize_kv(v, v_scale)


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0,
                        scale=None):
    """q: [B,T,Hq,D]; k,v: [B,S,Hkv,D] (GQA: Hq % Hkv == 0)."""
    b, t = q.shape[:2]
    s = k.shape[1]
    q_pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    kv_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    return attend(q, k, v, q_pos, kv_pos, s, causal=causal, window=window,
                  attn_softcap=softcap, scale=scale)


def decode_attention_ref(q, k, v, kv_len, q_pos, *, k_scale=None,
                         v_scale=None, window=0, softcap=0.0, scale=None):
    """Speculative-verify attention: small q against a long KV cache.

    q: [B,Tq,Hq,D]; k,v: [B,S,Hkv,D]; kv_len: [B]; q_pos: [B,Tq] absolute.
    k_scale/v_scale: optional [B,S,Hkv] dequant scales for quantized k/v.
    """
    k, v = _maybe_dequant(k, v, k_scale, v_scale)
    b = q.shape[0]
    s = k.shape[1]
    kv_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    return attend(q, k, v, q_pos, kv_pos, kv_len, causal=True, window=window,
                  attn_softcap=softcap, scale=scale)


def decode_attention_paged_ref(q, k_pages, v_pages, block_tables, kv_len,
                               q_pos, *, k_scale=None, v_scale=None,
                               window=0, softcap=0.0, scale=None):
    """Paged-pool oracle: gather each row's blocks into a contiguous view
    (models.attention.gather_pages) and defer to the contiguous reference.

    q: [B,Tq,Hq,D]; k_pages, v_pages: [NB, block, Hkv, D];
    block_tables: [B, MBS]; kv_len: [B]; q_pos: [B,Tq] absolute.
    k_scale/v_scale: optional [NB, block, Hkv] per-slot dequant scales.
    """
    k = gather_pages(k_pages, block_tables)
    v = gather_pages(v_pages, block_tables)
    if k_scale is not None:
        k_scale = gather_pages(k_scale, block_tables)
        v_scale = gather_pages(v_scale, block_tables)
    return decode_attention_ref(q, k, v, kv_len, q_pos, k_scale=k_scale,
                                v_scale=v_scale, window=window,
                                softcap=softcap, scale=scale)


def tree_attention_ref(q, k, v, kv_len, q_pos, win_start, anc, *,
                       win_len=None, k_scale=None, v_scale=None, window=0,
                       softcap=0.0, scale=None):
    """Tree-verification attention: the packed candidate tree window against
    a long cache (DESIGN.md §6). Masking comes from models.attention's
    TreeAttnInfo (packed ancestor bitmask inside the window, plain context
    visibility before it) so the kernel validates against exactly what the
    model computes.

    q: [B,Tq,Hq,D]; k,v: [B,S,Hkv,D]; kv_len: [B]; q_pos: [B,Tq] logical
    positions; win_start: [B] cache index of window slot 0; anc: [B,Tq]
    uint32 ancestor bitmasks; win_len: optional [B] per-row count of
    meaningful window slots (per-request tree templates, DESIGN.md §7);
    k_scale/v_scale: optional [B,S,Hkv] dequant scales for quantized k/v.
    """
    k, v = _maybe_dequant(k, v, k_scale, v_scale)
    b = q.shape[0]
    s = k.shape[1]
    kv_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    info = TreeAttnInfo(jnp.asarray(win_start), jnp.asarray(anc),
                        None if win_len is None else jnp.asarray(win_len))
    if win_len is not None:
        # the kernels clamp each row's sweep to win_start + win_len; the
        # oracle realises the same bound through kv_len so padded window
        # slots are invisible on both paths
        kv_len = jnp.minimum(jnp.asarray(kv_len),
                             jnp.asarray(win_start) + jnp.asarray(win_len))
    return attend(q, k, v, q_pos, kv_pos, kv_len, causal=True, window=window,
                  attn_softcap=softcap, scale=scale, tree_info=info)


def tree_attention_paged_ref(q, k_pages, v_pages, block_tables, kv_len,
                             q_pos, win_start, anc, *, win_len=None,
                             k_scale=None, v_scale=None, window=0,
                             softcap=0.0, scale=None):
    """Paged-pool tree-verification oracle: gather each row's blocks into a
    contiguous view and defer to the contiguous reference."""
    k = gather_pages(k_pages, block_tables)
    v = gather_pages(v_pages, block_tables)
    if k_scale is not None:
        k_scale = gather_pages(k_scale, block_tables)
        v_scale = gather_pages(v_scale, block_tables)
    return tree_attention_ref(q, k, v, kv_len, q_pos, win_start, anc,
                              win_len=win_len, k_scale=k_scale,
                              v_scale=v_scale, window=window,
                              softcap=softcap, scale=scale)


def pard_attention_ref(q, k, v, segment, base, *, scale=None, softcap=0.0):
    """PARD-COD training attention; mask from (segment, base) metadata.

    q,k,v: [B,T,H*,D]; segment, base: [B,T] int32 (segment 0 = padding).
    """
    b, t = q.shape[:2]
    pos = jnp.zeros((b, t), jnp.int32)
    info = PardMaskInfo(jnp.asarray(segment), jnp.asarray(base))
    return attend(q, k, v, pos, pos, t, causal=False, attn_softcap=softcap,
                  scale=scale, mask_info=info)


def ssd_ref(x, dt, A, B, C, init_state=None):
    """Token-by-token SSD oracle. x: [b,t,h,p]; dt: [b,t,h] (post-softplus);
    A: [h] (negative); B,C: [b,t,n]. Returns (y, final_state)."""
    return ssd_scan_ref(x, dt, A, B, C, init_state=init_state)
