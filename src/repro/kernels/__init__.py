from . import ops, ref
