"""PARD-COD training attention — Pallas TPU kernel.

The paper's Fig. 4/5 attention pattern for packed mask-token training. GPU
implementations materialise a sparse/compacted attention mask; on TPU we
compute the mask *functionally inside the kernel* from two int32 metadata
vectors per token — (segment, base) — so the packed COD batch runs as one
dense-blocked flash attention and no O(T^2) mask ever exists in HBM.

Allowed q(s_q, b_q) -> k(s_k, b_k):
    s_k == 1           and b_k <  b_q     (real context)
    1 < s_k < s_q      and b_k == b_q     (earlier masks of the same chain)
    s_k == s_q         and b_k == b_q     (self)
plus segment > 0 on both sides (0 = padding).

Grid: (batch, head, num_q_blocks, num_kv_blocks); metadata streams as
[block]-sized int32 tiles beside the K/V tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qseg_ref, qbase_ref, kseg_ref, kbase_ref, q_ref, k_ref, v_ref,
            o_ref, m_s, l_s, acc_s, *, scale, softcap):
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, :, 0, :].astype(jnp.float32)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    qs = qseg_ref[0, :][:, None]
    qb = qbase_ref[0, :][:, None]
    ks = kseg_ref[0, :][None, :]
    kb = kbase_ref[0, :][None, :]
    real_ctx = (ks == 1) & (kb < qb)
    chain = (ks > 1) & (ks < qs) & (kb == qb)
    self_tok = (ks == qs) & (kb == qb)
    mask = (qs > 0) & (ks > 0) & (real_ctx | chain | self_tok)

    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_s[...] = acc_s[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _final():
        denom = jnp.where(l_s[...] == 0.0, 1.0, l_s[...])
        o_ref[0, :, 0, :] = (acc_s[...] / denom).astype(o_ref.dtype)


def pard_attention(q, k, v, segment, base, *, scale=None, softcap=0.0,
                   block_q=128, block_k=128, interpret=False):
    """q,k,v: [B, T, H, D]; segment, base: [B, T] int32 (segment 0 = pad).
    Self-attention over the packed COD layout (Hq == Hkv here; the draft
    models PARD adapts are small GQA/MHA models — ops.py pre-repeats KV if
    grouped)."""
    b, t, h, d = q.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    grid = (b, h, pl.cdiv(t, block_q), pl.cdiv(t, block_k))

    kern = functools.partial(_kernel, scale=scale, softcap=softcap)
    seg = segment.astype(jnp.int32)
    bas = base.astype(jnp.int32)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q), lambda bi, hh, qi, ki: (bi, qi)),
            pl.BlockSpec((1, block_q), lambda bi, hh, qi, ki: (bi, qi)),
            pl.BlockSpec((1, block_k), lambda bi, hh, qi, ki: (bi, ki)),
            pl.BlockSpec((1, block_k), lambda bi, hh, qi, ki: (bi, ki)),
            pl.BlockSpec((1, block_q, 1, d), lambda bi, hh, qi, ki: (bi, qi, hh, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda bi, hh, qi, ki: (bi, ki, hh, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda bi, hh, qi, ki: (bi, ki, hh, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda bi, hh, qi, ki: (bi, qi, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(seg, bas, seg, bas, q, k, v)
