"""deepseek-v2-lite-16b — MLA + MoE. [arXiv:2405.04434]
27L d_model=2048 16H (kv_lora=512) moe_d_ff=1408 vocab=102400,
64 routed experts top-6 + 2 shared, first layer dense (d_ff=10944).
NOTE: assignment bracket said "160 routed"; the public model (and the
column spec "64e top-6") has 64 routed experts — we use 64 (DESIGN.md §4)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    num_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    attn_kind="mla",
    kv_lora_rank=512,
    q_lora_rank=0,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    moe_num_experts=64,
    moe_top_k=6,
    moe_num_shared=2,
    moe_d_ff=1408,
    first_dense_layers=1,
    first_dense_d_ff=10944,
    tie_embeddings=False,
    max_seq_len=163840,
    source="arXiv:2405.04434",
)
