"""minicpm3-4b — dense decoder with MLA. [hf:openbmb/MiniCPM3-4B]
62L d_model=2560 40H d_ff=6400 vocab=73448, kv_lora=256, q_lora=768."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    arch_type="dense",
    num_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attn_kind="mla",
    kv_lora_rank=256,
    q_lora_rank=768,
    qk_nope_head_dim=64,
    qk_rope_head_dim=32,
    v_head_dim=64,
    tie_embeddings=True,
    max_seq_len=32768,
    source="hf:openbmb/MiniCPM3-4B",
)
