"""command-r-35b — dense GQA decoder, parallel attn/ffn block, no bias.
[hf:CohereForAI/c4ai-command-r-v01] 40L d_model=8192 64H (kv=8)
d_ff=22528 vocab=256000."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    arch_type="dense",
    num_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    head_dim=128,
    use_layernorm=True,
    parallel_block=True,
    rope_theta=8_000_000.0,
    tie_embeddings=True,
    max_seq_len=131072,
    source="hf:CohereForAI/c4ai-command-r-v01",
)
