"""The paper's own draft/target families (Table 1/2): LLaMA3, Qwen2.5,
DeepSeek-R1-Distill-Qwen. These are the models PARD itself was evaluated on;
we carry them as first-class configs so the reproduction benchmarks and the
dry-run can exercise the paper's exact draft/target pairs."""
from ..models.config import ModelConfig

llama31_8b = ModelConfig(
    name="llama3.1-8b", arch_type="dense", num_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=128256, head_dim=128,
    rope_theta=500000.0, tie_embeddings=False, max_seq_len=131072,
    source="arXiv:2407.21783")

llama32_1b = ModelConfig(
    name="llama3.2-1b", arch_type="dense", num_layers=16, d_model=2048,
    n_heads=32, n_kv_heads=8, d_ff=8192, vocab_size=128256, head_dim=64,
    rope_theta=500000.0, tie_embeddings=True, max_seq_len=131072,
    source="hf:meta-llama/Llama-3.2-1B")

qwen25_7b = ModelConfig(
    name="qwen2.5-7b", arch_type="dense", num_layers=28, d_model=3584,
    n_heads=28, n_kv_heads=4, d_ff=18944, vocab_size=152064, head_dim=128,
    rope_theta=1000000.0, qkv_bias=True, tie_embeddings=False,
    max_seq_len=131072, source="arXiv:2412.15115")

qwen25_05b = ModelConfig(
    name="qwen2.5-0.5b", arch_type="dense", num_layers=24, d_model=896,
    n_heads=14, n_kv_heads=2, d_ff=4864, vocab_size=151936, head_dim=64,
    rope_theta=1000000.0, qkv_bias=True, tie_embeddings=True,
    max_seq_len=32768, source="arXiv:2412.15115")

dsq_7b = ModelConfig(
    name="dsq-7b", arch_type="dense", num_layers=28, d_model=3584,
    n_heads=28, n_kv_heads=4, d_ff=18944, vocab_size=152064, head_dim=128,
    rope_theta=1000000.0, qkv_bias=True, tie_embeddings=False,
    max_seq_len=131072, source="arXiv:2501.12948 (distill-qwen-7b)")

dsq_15b = ModelConfig(
    name="dsq-1.5b", arch_type="dense", num_layers=28, d_model=1536,
    n_heads=12, n_kv_heads=2, d_ff=8960, vocab_size=151936, head_dim=128,
    rope_theta=1000000.0, qkv_bias=True, tie_embeddings=False,
    max_seq_len=131072, source="arXiv:2501.12948 (distill-qwen-1.5b)")

CONFIGS = {c.name: c for c in
           [llama31_8b, llama32_1b, qwen25_7b, qwen25_05b, dsq_7b, dsq_15b]}
