"""deepseek-67b — llama-architecture dense GQA decoder. [arXiv:2401.02954]
95L d_model=8192 64H (kv=8) d_ff=22016 vocab=102400."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    arch_type="dense",
    num_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    head_dim=128,
    tie_embeddings=False,
    max_seq_len=4096,
    source="arXiv:2401.02954",
)
