"""Tiny same-tokenizer model pairs for CPU tests, examples and benchmarks.
The draft/target pair shares vocab (a speculative-decoding requirement)."""
from ..models.config import ModelConfig

tiny_target = ModelConfig(
    name="tiny-target", arch_type="dense", num_layers=4, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=512, head_dim=32,
    tie_embeddings=True, max_seq_len=1024, source="test")

tiny_draft = ModelConfig(
    name="tiny-draft", arch_type="dense", num_layers=2, d_model=64,
    n_heads=2, n_kv_heads=1, d_ff=128, vocab_size=512, head_dim=32,
    tie_embeddings=True, max_seq_len=1024, source="test")

tiny_mid = ModelConfig(
    name="tiny-mid", arch_type="dense", num_layers=3, d_model=96,
    n_heads=2, n_kv_heads=2, d_ff=192, vocab_size=512, head_dim=48,
    tie_embeddings=True, max_seq_len=1024, source="test")

tiny_ssm = ModelConfig(
    name="tiny-ssm", arch_type="ssm", num_layers=2, d_model=64,
    n_heads=2, n_kv_heads=2, d_ff=0, vocab_size=512, ssm_state=16,
    ssm_headdim=32, ssm_expand=2, ssm_chunk=8, tie_embeddings=True,
    max_seq_len=1024, source="test")

CONFIGS = {c.name: c for c in [tiny_target, tiny_draft, tiny_mid, tiny_ssm]}

# benchmark-scale family: big enough that decode compute dominates the
# per-call dispatch overhead on CPU, so speculative speedups are measurable
# (the tiny-* family above is for fast unit tests only)
bench_target = ModelConfig(
    name="bench-target", arch_type="dense", num_layers=6, d_model=256,
    n_heads=8, n_kv_heads=4, d_ff=768, vocab_size=512, head_dim=32,
    tie_embeddings=True, max_seq_len=2048, source="bench")

bench_mid = ModelConfig(
    name="bench-mid", arch_type="dense", num_layers=4, d_model=192,
    n_heads=4, n_kv_heads=4, d_ff=512, vocab_size=512, head_dim=48,
    tie_embeddings=True, max_seq_len=2048, source="bench")

bench_draft = ModelConfig(
    name="bench-draft", arch_type="dense", num_layers=2, d_model=96,
    n_heads=2, n_kv_heads=2, d_ff=192, vocab_size=512, head_dim=48,
    tie_embeddings=True, max_seq_len=2048, source="bench")

for _c in (bench_target, bench_mid, bench_draft):
    CONFIGS[_c.name] = _c
