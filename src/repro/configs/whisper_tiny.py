"""whisper-tiny — encoder-decoder audio transformer backbone.
[arXiv:2212.04356] 4L(enc)+4L(dec), d_model=384, 6H (kv=6), d_ff=1536,
vocab=51865. Conv/mel frontend is a STUB: input_specs provides precomputed
frame embeddings [B, 1500, 384]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    arch_type="audio",
    num_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    head_dim=64,
    use_layernorm=True,
    mlp_act="gelu",
    mlp_gated=False,
    use_rope=False,
    abs_pos=True,
    qkv_bias=True,
    is_encoder_decoder=True,
    encoder_layers=4,
    encoder_seq=1500,
    tie_embeddings=True,
    max_seq_len=448,
    source="arXiv:2212.04356",
)
