"""granite-moe-3b-a800m — MoE decoder, 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base family] 32L d_model=1536 24H
(kv=8) expert d_ff=512 vocab=49155.
NOTE: assignment bracket said "32 experts"; the column spec says 40e —
we use 40 (DESIGN.md §4)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    arch_type="moe",
    num_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    head_dim=64,
    moe_num_experts=40,
    moe_top_k=8,
    moe_d_ff=512,
    tie_embeddings=True,
    max_seq_len=4096,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
