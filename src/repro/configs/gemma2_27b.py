"""gemma2-27b — dense GQA with local/global alternation + logit softcaps.
[arXiv:2408.00118] 46L d_model=4608 32H (kv=16) d_ff=36864 vocab=256000,
sliding_window=4096 on local layers, attn softcap 50, final softcap 30,
sandwich norms, sqrt(d) embedding scale, query scale (d/h)^-0.5."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    arch_type="dense",
    num_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab_size=256000,
    head_dim=128,
    local_global_period=2,
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    attn_scale=(4608 / 32) ** -0.5,
    post_block_norms=True,
    embed_scale=True,
    mlp_act="gelu",
    tie_embeddings=True,
    max_seq_len=8192,
    source="arXiv:2408.00118",
)
