"""jamba-1.5-large-398b — hybrid Mamba2+attention (1:7 interleave) + MoE.
[arXiv:2403.19887] 72L d_model=8192 64H (kv=8) d_ff=24576 vocab=65536,
MoE 16 experts top-2 on every other layer, ssm_state=128."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    num_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    attn_every=8,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    moe_num_experts=16,
    moe_top_k=2,
    moe_d_ff=24576,
    moe_period=2,
    use_rope=False,
    tie_embeddings=False,
    max_seq_len=262144,
    source="arXiv:2403.19887",
)
