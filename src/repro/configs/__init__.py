"""Architecture registry.

One module per assigned architecture (exact public config, source cited in
``source``) plus the paper's own draft/target pairs and tiny CPU-test models.
``get_config(name)`` accepts the dashed public id (e.g. ``gemma2-27b``) or a
``-smoke`` suffix for the reduced same-family variant.
"""
from __future__ import annotations

from ..models.config import ModelConfig

from . import (command_r_35b, deepseek_67b, deepseek_v2_lite_16b, gemma2_27b,
               granite_moe_3b_a800m, jamba_1_5_large_398b,
               llama_3_2_vision_11b, mamba2_130m, minicpm3_4b, whisper_tiny,
               paper_models, tiny)

_MODULES = [whisper_tiny, command_r_35b, gemma2_27b, deepseek_v2_lite_16b,
            jamba_1_5_large_398b, minicpm3_4b, llama_3_2_vision_11b,
            deepseek_67b, mamba2_130m, granite_moe_3b_a800m]

CONFIGS = {}
for _m in _MODULES:
    CONFIGS[_m.CONFIG.name] = _m.CONFIG
CONFIGS.update(paper_models.CONFIGS)
CONFIGS.update(tiny.CONFIGS)

ASSIGNED = [m.CONFIG.name for m in _MODULES]


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return CONFIGS[name[:-len("-smoke")]].reduced()
    return CONFIGS[name]


def list_configs():
    return sorted(CONFIGS)
