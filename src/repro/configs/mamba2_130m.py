"""mamba2-130m — attention-free SSM (SSD, state-space duality).
[arXiv:2405.21060] 24L d_model=768 vocab=50280 ssm_state=128, expand=2,
headdim=64 (24 ssd heads), no MLP blocks."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    arch_type="ssm",
    num_layers=24,
    d_model=768,
    n_heads=12,          # unused (attention-free); kept for head_dim math
    n_kv_heads=12,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    tie_embeddings=True,
    max_seq_len=1048576,
    source="arXiv:2405.21060",
)
