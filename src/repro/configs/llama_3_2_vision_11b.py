"""llama-3.2-vision-11b — dense GQA decoder with gated cross-attention
image layers every 5th layer. [hf:meta-llama/Llama-3.2-11B-Vision]
40L d_model=4096 32H (kv=8) d_ff=14336 vocab=128256. The ViT vision
encoder + projector is a STUB: input_specs provides patch embeddings
[B, 1601, 4096]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    arch_type="vlm",
    num_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500000.0,
    cross_attn_period=5,
    cross_kv_len=1601,
    tie_embeddings=False,
    max_seq_len=131072,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
