"""Checkpointing: save/restore arbitrary param pytrees without orbax.

Format: one ``.npz`` with flattened path-keyed arrays + a tiny JSON manifest
describing the treedef, so restores are structure-checked. Works for params,
optimizer state, and engine state alike.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict:
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(f"{prefix}/{k}", node[k])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(f"{prefix}/#{i}", v)
        elif node is None:
            flat[prefix + "/@none"] = np.zeros((0,))
        else:
            flat[prefix] = np.asarray(jax.device_get(node))

    walk("", tree)
    return flat


def save(path: str, tree: Any, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    man = {"keys": sorted(flat), "metadata": metadata or {}}
    with open(_manifest_path(path), "w") as f:
        json.dump(man, f)


def _manifest_path(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".manifest.json"


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype checked)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")

    def build(prefix, node):
        if isinstance(node, dict):
            return {k: build(f"{prefix}/{k}", node[k]) for k in node}
        if isinstance(node, (list, tuple)):
            t = type(node)
            return t(build(f"{prefix}/#{i}", v) for i, v in enumerate(node))
        if node is None:
            return None
        arr = npz[prefix]
        ref = np.asarray(node)
        if arr.shape != ref.shape:
            raise ValueError(f"{prefix}: shape {arr.shape} != {ref.shape}")
        return jnp.asarray(arr, dtype=ref.dtype)

    return build("", like)


def load_metadata(path: str) -> dict:
    with open(_manifest_path(path)) as f:
        return json.load(f)["metadata"]
