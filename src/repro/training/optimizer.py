"""AdamW + schedules + global-norm clipping, in pure JAX.

Deliberately optax-shaped (init/update returning (updates, state)) so the
train loop composes the same way a production stack would.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        def z():
            return jax.tree.map(
                lambda p: jnp.zeros_like(p, jnp.float32), params)
        return AdamWState(jnp.zeros((), jnp.int32), z(), z())

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState, dict]:
        # global-norm clip
        leaves = jax.tree.leaves(grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in leaves))
        scale = jnp.where(gnorm > self.clip_norm,
                          self.clip_norm / (gnorm + 1e-9), 1.0)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

        step = state.step + 1
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            u = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step, mu, nu), {"grad_norm": gnorm,
                                                      "lr": lr}


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    def f(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor_frac + (1 - floor_frac) * 0.5 *
                      (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return f
