"""Training loops: AR pretraining and PARD adaptation (paper §3.2).

``Trainer`` owns the jitted step. On a mesh, pass ``shardings`` (a params
PartitionSpec tree from repro.sharding.specs) and the step is pjit-compiled
with batch data-parallel over ("pod","data"); on CPU it is a plain jit.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.adaptation import ar_loss, pard_adaptation_loss
from ..core.cod import CodConfig, pack_batch
from ..models.config import ModelConfig
from .optimizer import AdamW, AdamWState


@dataclasses.dataclass
class Trainer:
    cfg: ModelConfig
    opt: AdamW
    loss_kind: str = "ar"            # "ar" | "pard"
    cod: Optional[CodConfig] = None
    remat: bool = False
    dtype: Any = jnp.float32         # CPU tests train in fp32
    mesh: Any = None
    param_sharding: Any = None
    data_sharding: Any = None

    def __post_init__(self):
        if self.loss_kind == "ar":
            def loss_fn(params, batch):
                return ar_loss(params, self.cfg, batch["tokens"],
                               dtype=self.dtype, aux_weight=0.01)
        else:
            cod = self.cod or CodConfig()

            def loss_fn(params, batch):
                return pard_adaptation_loss(params, self.cfg, batch,
                                            k_max=cod.k, dtype=self.dtype)

        def step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            params, opt_state, om = self.opt.update(grads, opt_state, params)
            return params, opt_state, {**metrics, "loss": loss, **om}

        if self.mesh is not None and self.param_sharding is not None:
            self._step = jax.jit(
                step,
                in_shardings=(self.param_sharding, None, self.data_sharding),
                out_shardings=(self.param_sharding, None, None))
        else:
            self._step = jax.jit(step)

    def init_state(self, params) -> AdamWState:
        return self.opt.init(params)

    def make_batch(self, tokens: np.ndarray, seed: int = 0) -> Dict[str, Any]:
        if self.loss_kind == "ar":
            return {"tokens": jnp.asarray(tokens)}
        cod = self.cod or CodConfig()
        packed = pack_batch(tokens, cod, self.cfg.mask_token_id, seed=seed)
        packed.pop("n_tokens", None)
        return {k: jnp.asarray(v) for k, v in packed.items()}

    def fit(self, params, stream: Iterator[np.ndarray], steps: int, *,
            log_every: int = 50, log_fn=print):
        state = self.init_state(params)
        history = []
        t0 = time.perf_counter()
        tokens_seen = 0
        for i in range(steps):
            raw = next(stream)
            batch = self.make_batch(raw, seed=i)
            params, state, metrics = self._step(params, state, batch)
            if self.loss_kind == "pard":
                tokens_seen += int(np.sum(np.asarray(
                    jax.device_get(batch["segment"])) > 0))
            else:
                tokens_seen += raw.size
            if (i + 1) % log_every == 0 or i == steps - 1:
                m = {k: float(jax.device_get(v)) for k, v in metrics.items()}
                m.update(step=i + 1, tokens=tokens_seen,
                         wall=round(time.perf_counter() - t0, 2))
                history.append(m)
                if log_fn:
                    log_fn({k: (round(v, 4) if isinstance(v, float) else v)
                            for k, v in m.items()})
        return params, state, history
