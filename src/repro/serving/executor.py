"""Device-side half of the serving stack (DESIGN.md §8).

The ``Executor`` owns everything that lives on the accelerator: the cache
pools (paged or contiguous), the single ``DecodeState`` pytree, and the
jitted step functions — built from the SAME ``SpecDecoder`` step builders
the uniform-batch ``generate_*`` paths use, but with ``chunked=True`` so
every step advances decoding rows AND consumes prompt chunks for
prefilling rows in one fused forward (no standalone prefill forwards, no
admission stall).

The host-side ``serving.scheduler.Scheduler`` decides WHO runs (queues,
admission, block allocation, template selection, latency accounting); the
executor only moves the device state: row admission writes the prompt into
``gen`` and arms the prefill cursor, retirement freezes the row, and
``sync_tables`` pushes the allocator's host block tables whenever they
change so released rows' stale writes route to the garbage block
(kv_pool I4). ``serving.engine.Engine`` wires the two together and keeps
the public API.

Stepping is split into a non-blocking ``dispatch`` and a blocking
``harvest`` (DESIGN.md §9) so the engine can run a two-deep pipeline:
``dispatch`` enqueues ONE fused XLA computation — staged mutations
(retirement mask, per-row template re-selection, commit-limit freeze)
folded in AHEAD of the inner step — and returns a ``StepHandle`` of device
futures immediately; ``harvest`` materializes every per-step output
``(a, rank, rhist, live, n, gen)`` in a single batched ``jax.device_get``
instead of one transfer per array. The handle's arrays are ordinary jit
OUTPUTS, distinct buffers from the ones inside the returned ``DecodeState``
— donating the state into the next dispatch therefore never invalidates a
still-unharvested handle (the donation invariant §9 relies on).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import acceptance
from ..core.spec_decode import DecodeState, SpecDecoder
from ..models import init_caches
from ..models.attention import resolve_kv_dtype
from ..models.config import SSM, ModelConfig, scan_plan
from . import kv_pool

# "no staged commit limit" sentinel: n never reaches int32 max, so the
# device-side freeze ``done |= n >= limits`` is a no-op for these rows
NO_LIMIT = np.int32(np.iinfo(np.int32).max)


@dataclasses.dataclass
class StepHandle:
    """One in-flight step: device futures plus host metadata snapshotted at
    dispatch time. ``a``/``rank``/``rhist`` are None for mode="ar";
    ``tree_sel`` is the host copy of the per-slot template indices the step
    was dispatched with (stats/controller attribution must use THIS, not
    the scheduler's mirrors, which may be re-staged before harvest)."""
    a: Optional[Any]
    rank: Optional[Any]
    rhist: Optional[Any]
    live: Any                 # [B] bool — rows the step commits tokens for
    n: Any                    # [B] post-step committed counts
    gen: Any                  # [B, L] post-step token buffer
    n_draft: int
    tree_sel: Optional[np.ndarray] = None
    # scheduler-stamped: rid per slot at dispatch time (-1 = empty). A slot
    # re-admitted while this step was in flight fails the rid match at
    # process time, so the stale step's n/gen are never attributed to the
    # new request (the one-step-stale commit horizon, DESIGN.md §9)
    rids: Optional[np.ndarray] = None
    # scheduler-stamped: which engine replica dispatched this step, so
    # process() harvests and attributes it on the right replica (§12)
    replica: int = 0


@dataclasses.dataclass
class StepResult:
    """Host-materialized ``StepHandle`` (one batched transfer)."""
    a: Optional[np.ndarray]
    rank: Optional[np.ndarray]
    rhist: Optional[np.ndarray]
    live: np.ndarray
    n: np.ndarray
    gen: np.ndarray


def _zero_ssm_rows(cfg: ModelConfig, cache, slot: int):
    """Reset one batch row's SSM/conv states to the init state (zeros).

    Chunked prefill reuses slots in place — there is no per-request prefill
    forward whose fresh one-row state gets scattered in — so a recycled
    slot's recurrent state must be cleared before its first chunk
    (attention KV needs nothing: validity is ``kv_index < kv_len``)."""
    plan = scan_plan(cfg)

    def zero(entry, scanned):
        def one(leaf):
            if scanned:                      # [R, B, ...]
                return leaf.at[:, slot].set(0)
            return leaf.at[slot].set(0)      # [B, ...]
        return jax.tree.map(one, entry)

    return {
        "prefix": [zero(e, False) if s.mixer == SSM else e
                   for s, e in zip(plan.prefix, cache["prefix"])],
        "scan": [zero(e, True) if s.mixer == SSM else e
                 for s, e in zip(plan.period, cache["scan"])],
    }


def _copy_block(cfg: ModelConfig, cache, src: int, dst: int):
    """Copy one pool block's KV ``src -> dst`` across all attention leaves
    (copy-on-write: the caller just remapped a shared block)."""
    plan = scan_plan(cfg)

    def cp(entry, scanned):
        def one(leaf):
            if scanned:                      # [R, NB, bs, ...]
                return leaf.at[:, dst].set(leaf[:, src])
            return leaf.at[dst].set(leaf[src])
        return jax.tree.map(one, entry)

    return {
        "prefix": [cp(e, False) if s.mixer in kv_pool.ATTN_MIXERS else e
                   for s, e in zip(plan.prefix, cache["prefix"])],
        "scan": [cp(e, True) if s.mixer in kv_pool.ATTN_MIXERS else e
                 for s, e in zip(plan.period, cache["scan"])],
    }


class Executor:
    """Owns the DecodeState + cache pools and runs the fused jitted steps."""

    def __init__(self, dec: SpecDecoder, target_cfg: ModelConfig,
                 draft_cfg: Optional[ModelConfig], mode: str, max_batch: int,
                 max_len: int, paged: bool, kv_block_size: int,
                 num_blocks: Optional[int], seed: int,
                 kv_dtype: str = "bf16", mesh=None, replica: int = 0,
                 tp_ruleset: str = "exact"):
        self.dec = dec
        self.mode = mode
        self.tc, self.dc = target_cfg, draft_cfg
        self.max_batch, self.max_len = max_batch, max_len
        self.paged = paged
        self.kv_dtype = kv_dtype
        # which serving ruleset the fused steps trace under ("exact" /
        # "throughput" — DESIGN.md §13); salts the jit keys because the two
        # rulesets bake different sharding constraints into the same step
        self.tp_ruleset = tp_ruleset
        # data-parallel serving (DESIGN.md §12): which engine replica this
        # executor backs. Each replica owns its own _step_fns dict, but the
        # id also salts the jit-cache key so a shared cache could never
        # cross-serve two replicas' differently-placed states.
        self.replica = replica
        # sharded serving (DESIGN.md §11): the target KV pools shard their
        # head dim over the mesh's "model" axis, everything else in the
        # DecodeState replicates, and the fused steps pin in/out shardings
        # so donation reuses the sharded buffers tick over tick
        self.mesh = mesh
        self._rng_base = jax.random.PRNGKey(seed)
        self._step_fns = {}
        self._tables_version = -1
        # draft forwards per step are a STATIC property of the mode (pard /
        # tree: one mask-window forward; vsd: k AR forwards; ar: none) — a
        # host constant, never read back from the jit output, so dispatch
        # stays non-blocking
        self._n_draft = 0 if mode == "ar" else (dec.k if mode == "vsd" else 1)

        cache_dtype = resolve_kv_dtype(kv_dtype)
        if paged:
            tcache = kv_pool.init_paged_caches(target_cfg, max_batch,
                                               num_blocks, kv_block_size,
                                               dtype=cache_dtype, mesh=mesh)
            dcache = (kv_pool.init_paged_caches(draft_cfg, max_batch,
                                                num_blocks, kv_block_size,
                                                dtype=cache_dtype)
                      if draft_cfg is not None else None)
            tables = jnp.zeros((max_batch, kv_pool.blocks_for(
                max_len, kv_block_size)), jnp.int32)
            self.kv_per_block = (
                kv_pool.kv_bytes_per_block(target_cfg, tcache, num_blocks)
                + (kv_pool.kv_bytes_per_block(draft_cfg, dcache, num_blocks)
                   if dcache is not None else 0))
        else:
            tcache = init_caches(target_cfg, max_batch, max_len,
                                 dtype=cache_dtype)
            dcache = (init_caches(draft_cfg, max_batch, max_len,
                                  dtype=cache_dtype)
                      if draft_cfg is not None else None)
            tables = None
            self.kv_per_block = 0
        self.kv_capacity = (
            kv_pool.kv_capacity_bytes(target_cfg, tcache)
            + (kv_pool.kv_capacity_bytes(draft_cfg, dcache)
               if dcache is not None else 0))

        self.state = DecodeState(
            gen=jnp.zeros((max_batch, max_len), jnp.int32),
            n=jnp.ones((max_batch,), jnp.int32) * 2,   # dummy-safe
            m=jnp.ones((max_batch,), jnp.int32),
            done=jnp.ones((max_batch,), bool),         # empty slots = done
            tcache=tcache, dcache=dcache, tables=tables,
            temp=jnp.zeros((max_batch,), jnp.float32),
            rngs=acceptance.make_row_keys(seed, np.arange(max_batch)),
            tree_idx=(jnp.zeros((max_batch,), jnp.int32)
                      if dec.tree is not None else None),
            pf_pos=jnp.zeros((max_batch,), jnp.int32),
            pf_len=jnp.zeros((max_batch,), jnp.int32))
        if mesh is not None:
            self._state_sh = self._state_shardings()
            self.state = jax.device_put(self.state, self._state_sh)
        else:
            self._state_sh = None

    # ----------------------------------------------------------- sharding
    def _state_shardings(self):
        """NamedSharding pytree matching the DecodeState: target KV pools
        shard KV heads over "model" (paged_cache_specs / cache_specs), the
        DRAFT pools and every other leaf — tokens, counters, PRNG keys,
        block tables — replicate. The draft replicates because it is small
        and its latency-critical window must not pay any cross-device
        traffic; block tables replicate because every device resolves the
        same block indirection (DESIGN.md §11)."""
        from ..sharding import specs as _specs
        mesh = self.mesh
        repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
        if self.paged:
            t_specs = _specs.paged_cache_specs(self.state.tcache, mesh)
        else:
            t_specs = _specs.cache_specs(self.state.tcache, self.tc, mesh,
                                         self.max_batch)
        base = jax.tree.map(lambda _: repl, self.state)
        return dataclasses.replace(base,
                                   tcache=_specs.to_named(t_specs, mesh))

    # ------------------------------------------------------------- tables
    def sync_tables(self, alloc: Optional[kv_pool.BlockAllocator]) -> None:
        """Push the host block tables to the device state when stale. Runs
        before any forward that could consume them, so released rows' stale
        writes always route to the garbage block (kv_pool I4)."""
        if alloc is not None and self._tables_version != alloc.version:
            tables = jnp.asarray(alloc.tables)
            if self.mesh is not None:
                # every device resolves the same block indirection: the
                # table is replicated host-side state (DESIGN.md §11)
                tables = jax.device_put(tables, jax.sharding.NamedSharding(
                    self.mesh, jax.sharding.PartitionSpec()))
            self.state = dataclasses.replace(self.state, tables=tables)
            self._tables_version = alloc.version

    # ---------------------------------------------------------- row admin
    def admit_row(self, slot: int, prompt: np.ndarray, temperature: float,
                  rid: int, tree_idx: int, pf_start: int,
                  seed: Optional[int] = None) -> None:
        """Arm ``slot`` for a new request: prompt into ``gen``, counters to
        the committed state, prefill cursor at ``pf_start`` (``> 0`` when a
        cached prefix already covers the leading blocks). NO device forward
        happens here — the fused steps prefill chunk by chunk. ``seed``
        (SamplingParams.seed) pins the row's PRNG stream to the request
        itself; None derives it from the engine seed and rid (the
        historical behaviour)."""
        p = len(prompt)
        st = self.state
        gen_row = np.zeros((self.max_len,), np.int32)
        gen_row[:p] = prompt
        row_key = (jax.random.fold_in(self._rng_base, rid) if seed is None
                   else jax.random.PRNGKey(int(seed)))
        self.state = dataclasses.replace(
            st,
            gen=st.gen.at[slot].set(jnp.asarray(gen_row)),
            n=st.n.at[slot].set(p),
            m=st.m.at[slot].set(p - 1),
            done=st.done.at[slot].set(False),
            temp=st.temp.at[slot].set(float(temperature)),
            rngs=st.rngs.at[slot].set(row_key),
            tree_idx=(st.tree_idx if st.tree_idx is None else
                      st.tree_idx.at[slot].set(int(tree_idx))),
            pf_pos=st.pf_pos.at[slot].set(int(pf_start)),
            pf_len=st.pf_len.at[slot].set(p - 1),
            tcache=_zero_ssm_rows(self.tc, st.tcache, slot),
            dcache=(None if st.dcache is None else
                    _zero_ssm_rows(self.dc, st.dcache, slot)))

    def retire_row(self, slot: int) -> None:
        # temp resets with the slot: a retired sampled request must not
        # keep forcing later all-greedy batches onto the sampled lax.cond
        # branch (jnp.any(temp > 0))
        self.state = dataclasses.replace(
            self.state, done=self.state.done.at[slot].set(True),
            temp=self.state.temp.at[slot].set(0.0))

    def set_tree_idx(self, slot: int, tree_idx: int) -> None:
        self.state = dataclasses.replace(
            self.state,
            tree_idx=self.state.tree_idx.at[slot].set(int(tree_idx)))

    def copy_block(self, src: int, dst: int) -> None:
        """Device half of allocator.copy_on_write: duplicate one block's KV
        in every pool (target + draft share block indices)."""
        st = self.state
        self.state = dataclasses.replace(
            st, tcache=_copy_block(self.tc, st.tcache, src, dst),
            dcache=(None if st.dcache is None else
                    _copy_block(self.dc, st.dcache, src, dst)))

    # -------------------------------------------------------------- steps
    def _build(self, variant: str, greedy_only: bool = False):
        if self.mode == "ar":
            # two compiled variants: the 1-wide pure-decode window (the
            # AR+ hot path — pad slots would cost real attention compute
            # every step) and the prefill_chunk-wide mixed window, selected
            # per tick by whether any row is actually prefilling
            builder = self.dec._build_ar_step(chunked=variant == "mixed")

            def step(state):
                return builder(state), None, None, None, None, 0
            return step
        # spec/tree windows already fit the chunk (same shapes either way:
        # the chunk substitution is a few jnp.where selects), so one
        # compiled step serves both pure-decode and mixed ticks
        if self.dec.tree is not None:
            return self.dec._build_tree_step(chunked=True,
                                             greedy_only=greedy_only)
        return self.dec._build_spec_step(
            "pard" if self.mode == "pard" else "vsd", chunked=True,
            greedy_only=greedy_only)

    def _build_fused(self, variant: str, apply_tree: bool,
                     greedy_only: bool = False):
        """One XLA dispatch per tick: staged host decisions (retirement,
        template re-selection, commit-limit freeze) fold into the SAME
        computation as the inner step, replacing the eager per-slot
        ``.at[].set`` dispatches the synchronous loop issued between steps.

        The wrapper also computes the LIVE mask (rows the step commits
        tokens for) on the post-mutation, pre-step state and returns it
        with the step outputs — the pipelined scheduler cannot derive it
        from host mirrors, which run one step ahead of unharvested
        results — and re-returns ``n``/``gen`` as explicit outputs so a
        harvest needs no read of the (soon-to-be-donated) state."""
        inner = self._build(variant, greedy_only)

        def fused(state, retire, tree_sel, limits):
            # staged retirement + the device-side limit freeze: a row whose
            # committed count reached its limit is frozen even if the host
            # has not harvested that result yet (the pipelined loop's
            # one-step-stale horizon must not let it overrun its blocks)
            done = state.done | retire | (state.n >= limits)
            # temp resets with retirement (see retire_row)
            temp = jnp.where(retire, 0.0, state.temp)
            tree_idx = state.tree_idx
            if apply_tree and tree_idx is not None:
                tree_idx = tree_sel
            st = dataclasses.replace(state, done=done, temp=temp,
                                     tree_idx=tree_idx)
            live = ~(st.done | (st.pf_pos < st.pf_len))
            new_state, a, _hist, rhist, rank, _nd = inner(st)
            return new_state, a, rank, rhist, live, new_state.n, new_state.gen
        return fused

    def dispatch(self, retire: Optional[np.ndarray] = None,
                 tree_sel: Optional[np.ndarray] = None,
                 limits: Optional[np.ndarray] = None,
                 any_prefilling: bool = True,
                 any_sampled: bool = True) -> StepHandle:
        """Enqueue one fused prefill+decode step and return immediately
        with a handle of device futures (the jitted call is asynchronous;
        nothing here blocks). ``retire`` [B] bool / ``tree_sel`` [B] int /
        ``limits`` [B] int are the scheduler's staged mutations (None =
        no-op); ``any_prefilling``: host hint selecting the AR window
        variant; ``any_sampled=False``: host hint (no OCCUPIED slot has
        temperature > 0) selecting the greedy-specialized spec/tree step —
        token-identical, with the sampled machinery compiled out. Greedy
        rows never consume their PRNG streams and a sampled row's key is
        freshly (seed, rid)-derived at admission, so alternating between
        the two compiled variants across steps is safe."""
        variant = "mixed" if (any_prefilling and self.mode == "ar") \
            else "decode"
        greedy_only = not any_sampled and self.mode != "ar"
        key = (variant, tree_sel is not None, greedy_only, self.kv_dtype,
               self.replica, self.tp_ruleset)
        if key not in self._step_fns:
            fused = self._build_fused(variant, apply_tree=tree_sel is not None,
                                      greedy_only=greedy_only)
            if self.mesh is None:
                self._step_fns[key] = jax.jit(fused, donate_argnums=(0,))
            else:
                # pin shardings on BOTH sides of the fused step: the donated
                # state's buffers keep their layout tick over tick (no
                # resharding churn), and the step stays one device
                # computation per dispatch. Scalars/handle outputs
                # replicate; None outputs (mode="ar") take a None entry.
                repl = jax.sharding.NamedSharding(
                    self.mesh, jax.sharding.PartitionSpec())
                aux = repl if self.mode != "ar" else None
                self._step_fns[key] = jax.jit(
                    fused, donate_argnums=(0,),
                    in_shardings=(self._state_sh, repl, repl, repl),
                    out_shardings=(self._state_sh, aux, aux, aux,
                                   repl, repl, repl))
        b = self.max_batch
        retire_d = (jnp.zeros((b,), bool) if retire is None
                    else jnp.asarray(retire, bool))
        limits_d = (jnp.full((b,), NO_LIMIT, jnp.int32) if limits is None
                    else jnp.asarray(limits, jnp.int32))
        tree_d = (jnp.zeros((b,), jnp.int32) if tree_sel is None
                  else jnp.asarray(tree_sel, jnp.int32))
        if self.mesh is not None:
            # trace under the activation mesh + ruleset so the forward's
            # partial/gather_activation hints bake in (§11/§13)
            from ..kernels import ops as _ops
            with _ops.activation_mesh(self.mesh, self.tp_ruleset):
                self.state, a, rank, rhist, live, n, gen = \
                    self._step_fns[key](self.state, retire_d, tree_d, limits_d)
        else:
            self.state, a, rank, rhist, live, n, gen = \
                self._step_fns[key](self.state, retire_d, tree_d, limits_d)
        return StepHandle(a=a, rank=rank, rhist=rhist, live=live, n=n,
                          gen=gen, n_draft=self._n_draft,
                          tree_sel=None if tree_sel is None
                          else np.asarray(tree_sel))

    def harvest(self, handle: StepHandle) -> StepResult:
        """Materialize one in-flight step's outputs in a SINGLE batched
        host transfer (blocks until that step completes on device). Safe
        to call after later dispatches: the handle's arrays are distinct
        jit-output buffers, untouched by the state donation."""
        if handle.a is None:                          # mode="ar"
            live, n, gen = jax.device_get(
                (handle.live, handle.n, handle.gen))
            return StepResult(None, None, None, np.asarray(live),
                              np.asarray(n), np.asarray(gen))
        a, rank, rhist, live, n, gen = jax.device_get(
            (handle.a, handle.rank, handle.rhist, handle.live, handle.n,
             handle.gen))
        return StepResult(np.asarray(a), np.asarray(rank),
                          np.asarray(rhist), np.asarray(live),
                          np.asarray(n), np.asarray(gen))

    def step(self, any_prefilling: bool = True):
        """One SYNCHRONOUS fused step (dispatch + immediate harvest, no
        staged mutations): the depth-1 special case, kept for tests and
        callers outside the engine's pipeline. Returns host copies of the
        per-row accepted depths / sibling ranks (None for mode="ar") and
        the draft-forward count."""
        handle = self.dispatch(any_prefilling=any_prefilling)
        res = self.harvest(handle)
        if res.a is None:
            return None, None, None, 0
        return res.a, res.rank, res.rhist, handle.n_draft

    def step_hlo(self, *, tree: bool = False, any_sampled: bool = False) -> str:
        """Compiled (post-GSPMD) HLO text of the decode-variant fused step.

        AOT lower + compile against the live DecodeState — nothing
        executes and nothing is donated, so this is safe to call on a
        serving executor between ticks. tools/comm_audit.py walks the
        returned text to count per-step collectives and their byte
        volumes, the measurable gate for the throughput ruleset
        (DESIGN.md §13; CPU-emulated collective wall-clock is not
        trustworthy, op/byte accounting is)."""
        greedy_only = not any_sampled and self.mode != "ar"
        fused = self._build_fused("decode", apply_tree=tree,
                                  greedy_only=greedy_only)
        b = self.max_batch
        args = (self.state, jnp.zeros((b,), bool),
                jnp.zeros((b,), jnp.int32),
                jnp.full((b,), NO_LIMIT, jnp.int32))
        if self.mesh is None:
            return jax.jit(fused).lower(*args).compile().as_text()
        repl = jax.sharding.NamedSharding(
            self.mesh, jax.sharding.PartitionSpec())
        aux = repl if self.mode != "ar" else None
        jitted = jax.jit(
            fused,
            in_shardings=(self._state_sh, repl, repl, repl),
            out_shardings=(self._state_sh, aux, aux, aux, repl, repl, repl))
        from ..kernels import ops as _ops
        with _ops.activation_mesh(self.mesh, self.tp_ruleset):
            return jitted.lower(*args).compile().as_text()

    # --------------------------------------------------------------- host
    def read_n(self) -> np.ndarray:
        return np.asarray(jax.device_get(self.state.n))

    def read_gen(self) -> np.ndarray:
        return np.asarray(jax.device_get(self.state.gen))
