"""Device-side half of the serving stack (DESIGN.md §8).

The ``Executor`` owns everything that lives on the accelerator: the cache
pools (paged or contiguous), the single ``DecodeState`` pytree, and the
jitted step functions — built from the SAME ``SpecDecoder`` step builders
the uniform-batch ``generate_*`` paths use, but with ``chunked=True`` so
every step advances decoding rows AND consumes prompt chunks for
prefilling rows in one fused forward (no standalone prefill forwards, no
admission stall).

The host-side ``serving.scheduler.Scheduler`` decides WHO runs (queues,
admission, block allocation, template selection, latency accounting); the
executor only moves the device state: row admission writes the prompt into
``gen`` and arms the prefill cursor, retirement freezes the row, and
``sync_tables`` pushes the allocator's host block tables whenever they
change so released rows' stale writes route to the garbage block
(kv_pool I4). ``serving.engine.Engine`` wires the two together and keeps
the public API.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import acceptance
from ..core.spec_decode import DecodeState, SpecDecoder
from ..models import init_caches
from ..models.config import SSM, ModelConfig, scan_plan
from . import kv_pool


def _zero_ssm_rows(cfg: ModelConfig, cache, slot: int):
    """Reset one batch row's SSM/conv states to the init state (zeros).

    Chunked prefill reuses slots in place — there is no per-request prefill
    forward whose fresh one-row state gets scattered in — so a recycled
    slot's recurrent state must be cleared before its first chunk
    (attention KV needs nothing: validity is ``kv_index < kv_len``)."""
    plan = scan_plan(cfg)

    def zero(entry, scanned):
        def one(leaf):
            if scanned:                      # [R, B, ...]
                return leaf.at[:, slot].set(0)
            return leaf.at[slot].set(0)      # [B, ...]
        return jax.tree.map(one, entry)

    return {
        "prefix": [zero(e, False) if s.mixer == SSM else e
                   for s, e in zip(plan.prefix, cache["prefix"])],
        "scan": [zero(e, True) if s.mixer == SSM else e
                 for s, e in zip(plan.period, cache["scan"])],
    }


def _copy_block(cfg: ModelConfig, cache, src: int, dst: int):
    """Copy one pool block's KV ``src -> dst`` across all attention leaves
    (copy-on-write: the caller just remapped a shared block)."""
    plan = scan_plan(cfg)

    def cp(entry, scanned):
        def one(leaf):
            if scanned:                      # [R, NB, bs, ...]
                return leaf.at[:, dst].set(leaf[:, src])
            return leaf.at[dst].set(leaf[src])
        return jax.tree.map(one, entry)

    return {
        "prefix": [cp(e, False) if s.mixer in kv_pool.ATTN_MIXERS else e
                   for s, e in zip(plan.prefix, cache["prefix"])],
        "scan": [cp(e, True) if s.mixer in kv_pool.ATTN_MIXERS else e
                 for s, e in zip(plan.period, cache["scan"])],
    }


class Executor:
    """Owns the DecodeState + cache pools and runs the fused jitted steps."""

    def __init__(self, dec: SpecDecoder, target_cfg: ModelConfig,
                 draft_cfg: Optional[ModelConfig], mode: str, max_batch: int,
                 max_len: int, paged: bool, kv_block_size: int,
                 num_blocks: Optional[int], seed: int):
        self.dec = dec
        self.mode = mode
        self.tc, self.dc = target_cfg, draft_cfg
        self.max_batch, self.max_len = max_batch, max_len
        self.paged = paged
        self._rng_base = jax.random.PRNGKey(seed)
        self._step_fns = {}
        self._tables_version = -1

        if paged:
            tcache = kv_pool.init_paged_caches(target_cfg, max_batch,
                                               num_blocks, kv_block_size)
            dcache = (kv_pool.init_paged_caches(draft_cfg, max_batch,
                                                num_blocks, kv_block_size)
                      if draft_cfg is not None else None)
            tables = jnp.zeros((max_batch, kv_pool.blocks_for(
                max_len, kv_block_size)), jnp.int32)
            self.kv_per_block = (
                kv_pool.kv_bytes_per_block(target_cfg, tcache, num_blocks)
                + (kv_pool.kv_bytes_per_block(draft_cfg, dcache, num_blocks)
                   if dcache is not None else 0))
        else:
            tcache = init_caches(target_cfg, max_batch, max_len)
            dcache = (init_caches(draft_cfg, max_batch, max_len)
                      if draft_cfg is not None else None)
            tables = None
            self.kv_per_block = 0
        self.kv_capacity = (
            kv_pool.kv_capacity_bytes(target_cfg, tcache)
            + (kv_pool.kv_capacity_bytes(draft_cfg, dcache)
               if dcache is not None else 0))

        self.state = DecodeState(
            gen=jnp.zeros((max_batch, max_len), jnp.int32),
            n=jnp.ones((max_batch,), jnp.int32) * 2,   # dummy-safe
            m=jnp.ones((max_batch,), jnp.int32),
            done=jnp.ones((max_batch,), bool),         # empty slots = done
            tcache=tcache, dcache=dcache, tables=tables,
            temp=jnp.zeros((max_batch,), jnp.float32),
            rngs=acceptance.make_row_keys(seed, np.arange(max_batch)),
            tree_idx=(jnp.zeros((max_batch,), jnp.int32)
                      if dec.tree is not None else None),
            pf_pos=jnp.zeros((max_batch,), jnp.int32),
            pf_len=jnp.zeros((max_batch,), jnp.int32))

    # ------------------------------------------------------------- tables
    def sync_tables(self, alloc: Optional[kv_pool.BlockAllocator]) -> None:
        """Push the host block tables to the device state when stale. Runs
        before any forward that could consume them, so released rows' stale
        writes always route to the garbage block (kv_pool I4)."""
        if alloc is not None and self._tables_version != alloc.version:
            self.state = dataclasses.replace(
                self.state, tables=jnp.asarray(alloc.tables))
            self._tables_version = alloc.version

    # ---------------------------------------------------------- row admin
    def admit_row(self, slot: int, prompt: np.ndarray, temperature: float,
                  rid: int, tree_idx: int, pf_start: int) -> None:
        """Arm ``slot`` for a new request: prompt into ``gen``, counters to
        the committed state, prefill cursor at ``pf_start`` (``> 0`` when a
        cached prefix already covers the leading blocks). NO device forward
        happens here — the fused steps prefill chunk by chunk."""
        p = len(prompt)
        st = self.state
        gen_row = np.zeros((self.max_len,), np.int32)
        gen_row[:p] = prompt
        self.state = dataclasses.replace(
            st,
            gen=st.gen.at[slot].set(jnp.asarray(gen_row)),
            n=st.n.at[slot].set(p),
            m=st.m.at[slot].set(p - 1),
            done=st.done.at[slot].set(False),
            temp=st.temp.at[slot].set(float(temperature)),
            rngs=st.rngs.at[slot].set(
                jax.random.fold_in(self._rng_base, rid)),
            tree_idx=(st.tree_idx if st.tree_idx is None else
                      st.tree_idx.at[slot].set(int(tree_idx))),
            pf_pos=st.pf_pos.at[slot].set(int(pf_start)),
            pf_len=st.pf_len.at[slot].set(p - 1),
            tcache=_zero_ssm_rows(self.tc, st.tcache, slot),
            dcache=(None if st.dcache is None else
                    _zero_ssm_rows(self.dc, st.dcache, slot)))

    def retire_row(self, slot: int) -> None:
        # temp resets with the slot: a retired sampled request must not
        # keep forcing later all-greedy batches onto the sampled lax.cond
        # branch (jnp.any(temp > 0))
        self.state = dataclasses.replace(
            self.state, done=self.state.done.at[slot].set(True),
            temp=self.state.temp.at[slot].set(0.0))

    def set_tree_idx(self, slot: int, tree_idx: int) -> None:
        self.state = dataclasses.replace(
            self.state,
            tree_idx=self.state.tree_idx.at[slot].set(int(tree_idx)))

    def copy_block(self, src: int, dst: int) -> None:
        """Device half of allocator.copy_on_write: duplicate one block's KV
        in every pool (target + draft share block indices)."""
        st = self.state
        self.state = dataclasses.replace(
            st, tcache=_copy_block(self.tc, st.tcache, src, dst),
            dcache=(None if st.dcache is None else
                    _copy_block(self.dc, st.dcache, src, dst)))

    # -------------------------------------------------------------- steps
    def _build(self, variant: str):
        if self.mode == "ar":
            # two compiled variants: the 1-wide pure-decode window (the
            # AR+ hot path — pad slots would cost real attention compute
            # every step) and the prefill_chunk-wide mixed window, selected
            # per tick by whether any row is actually prefilling
            builder = self.dec._build_ar_step(chunked=variant == "mixed")

            def step(state):
                return builder(state), None, None, None, None, 0
            return step
        # spec/tree windows already fit the chunk (same shapes either way:
        # the chunk substitution is a few jnp.where selects), so one
        # compiled step serves both pure-decode and mixed ticks
        if self.dec.tree is not None:
            return self.dec._build_tree_step(chunked=True)
        return self.dec._build_spec_step(
            "pard" if self.mode == "pard" else "vsd", chunked=True)

    def step(self, any_prefilling: bool = True):
        """One fused prefill+decode step. Returns host copies of the
        per-row accepted depths / sibling ranks (None for mode="ar") and
        the draft-forward count. ``any_prefilling``: host hint (the
        scheduler's cursor mirrors) selecting the AR window variant."""
        variant = "mixed" if (any_prefilling and self.mode == "ar") \
            else "decode"
        if variant not in self._step_fns:
            self._step_fns[variant] = jax.jit(self._build(variant),
                                              donate_argnums=(0,))
        self.state, a, _hist, rhist, rank, n_draft = \
            self._step_fns[variant](self.state)
        if a is None:
            return None, None, None, 0
        return (np.asarray(jax.device_get(a)),
                np.asarray(jax.device_get(rank)),
                np.asarray(jax.device_get(rhist)), int(n_draft))

    # --------------------------------------------------------------- host
    def read_n(self) -> np.ndarray:
        return np.asarray(jax.device_get(self.state.n))

    def read_gen(self) -> np.ndarray:
        return np.asarray(jax.device_get(self.state.gen))
