"""Block-paged KV-cache pool — the vLLM-style layout for the serving engine.

The contiguous layout allocates ``max_batch x max_len`` cache rows up front,
so HBM footprint is decoupled from what requests actually use. This module
decouples them (DESIGN.md §5):

  * attention KV lives in a shared pool of fixed-size blocks
    ``[num_blocks, block_size, ...]`` (per layer; scanned layers carry a
    leading repeats dim);
  * each slot owns a *block table* row ``[max_blocks_per_seq]`` mapping
    absolute position ``p`` to ``(table[p // block_size], p % block_size)``;
  * a host-side free list hands blocks out at admission and takes them back
    in O(1) at completion. Prefill writes straight into the allocated blocks
    through the table (copy-free admission — no full-pool row scatter);
  * SSM / conv states are O(1) per row and stay batch-indexed.

Invariants (tested in tests/test_engine.py and tests/test_kv_pool.py):

  I1. Block 0 is RESERVED as the garbage block. Unallocated table entries
      are 0, so any write past a row's allocation lands there; reads never
      see it because validity is ``kv_index < kv_len``.
  I2. Live blocks are owned by exactly one slot; the flattened scatter in
      models.attention.write_cache_paged therefore never collides.
  I3. A slot's allocation covers every position the decode loop can write:
      ``prompt + max_new + 2K + 2`` tokens (the speculative write window).
  I4. A released slot's table row is zeroed (on host) before its blocks can
      be handed to another slot, so a frozen row's stale writes route to
      the garbage block, never into a new owner's blocks.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from ..models import ssm as ssm_mod
from ..models.config import (ATTN_CROSS, ATTN_GLOBAL, ATTN_LOCAL, ATTN_MLA,
                             SSM, ModelConfig, scan_plan)

ATTN_MIXERS = (ATTN_GLOBAL, ATTN_LOCAL, ATTN_MLA)


def blocks_for(n_tokens: int, block_size: int) -> int:
    return -(-int(n_tokens) // block_size)


def default_num_blocks(max_batch: int, max_len: int, block_size: int) -> int:
    """Worst-case pool size (every slot filled to max_len) + garbage block.

    Serving deployments pass something smaller and rely on admission
    backpressure; this default keeps the paged engine drop-in safe.
    """
    return max_batch * blocks_for(max_len, block_size) + 1


# ---------------------------------------------------------------------------
# Pool init
# ---------------------------------------------------------------------------

def _paged_layer_cache(cfg: ModelConfig, spec, num_blocks, block_size, batch,
                       dtype):
    if spec.mixer in (ATTN_GLOBAL, ATTN_LOCAL):
        hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        return {"k": jnp.zeros((num_blocks, block_size, hkv, hd), dtype),
                "v": jnp.zeros((num_blocks, block_size, hkv, hd), dtype)}
    if spec.mixer == ATTN_MLA:
        width = cfg.kv_lora_rank + cfg.qk_rope_head_dim
        return {"ckv": jnp.zeros((num_blocks, block_size, width), dtype)}
    if spec.mixer == SSM:
        return ssm_mod.init_mamba2_state(cfg, batch, jnp.float32)
    if spec.mixer == ATTN_CROSS:
        return {}
    raise ValueError(spec.mixer)


def init_paged_caches(cfg: ModelConfig, batch: int, num_blocks: int,
                      block_size: int, dtype=jnp.bfloat16):
    """Cache pytree with the SAME structure as models.init_caches, but
    attention leaves are shared block pools [NB, bs, ...] (no batch dim);
    SSM states remain [batch, ...]."""
    plan = scan_plan(cfg)
    return {
        "prefix": [_paged_layer_cache(cfg, s, num_blocks, block_size, batch,
                                      dtype)
                   for s in plan.prefix],
        "scan": [jax.tree.map(
            lambda x: jnp.broadcast_to(x, (plan.n_repeats,) + x.shape).copy()
            if hasattr(x, "shape") else x,
            _paged_layer_cache(cfg, s, num_blocks, block_size, batch, dtype))
            for s in plan.period],
    }


def prefill_cache_view(cfg: ModelConfig, pool, paged: bool):
    """The cache tree a single-request prefill forward should run against.

    Paged: attention leaves ARE the pool (the forward writes through the
    slot's block-table row — copy-free admission), SSM leaves a fresh
    one-row state. Contiguous: handled by the caller (init_caches(cfg, 1)).
    """
    assert paged
    plan = scan_plan(cfg)

    def one(spec, entry, scanned):
        if spec.mixer != SSM:
            return entry
        row = ssm_mod.init_mamba2_state(cfg, 1, jnp.float32)
        if scanned:
            row = jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x, (plan.n_repeats,) + x.shape).copy(), row)
        return row

    return {
        "prefix": [one(s, pool["prefix"][i], False)
                   for i, s in enumerate(plan.prefix)],
        "scan": [one(s, pool["scan"][j], True)
                 for j, s in enumerate(plan.period)],
    }


def scatter_row_caches(cfg: ModelConfig, pool, row, slot, paged: bool):
    """Merge a prefill result into the engine's cache pools at ``slot``.

    Paged: attention entries in ``row`` are the already-updated pools
    (adopted as-is); only the O(1) SSM states are scattered. Contiguous:
    every leaf is a [1, ...] row scattered at batch index ``slot`` (prefix
    leaves carry batch at axis 0, scanned leaves at axis 1).
    ``slot`` may be traced (dynamic_update_slice start).
    """
    plan = scan_plan(cfg)
    slot = jnp.asarray(slot, jnp.int32)

    def ins_axis(axis):
        def ins(p, r):
            idx = [jnp.zeros((), jnp.int32)] * p.ndim
            idx[axis] = slot
            return jax.lax.dynamic_update_slice(p, r.astype(p.dtype),
                                                tuple(idx))
        return ins

    def merge(spec, pool_e, row_e, axis):
        if paged and spec.mixer in ATTN_MIXERS:
            return row_e                       # row IS the updated pool
        return jax.tree.map(ins_axis(axis), pool_e, row_e)

    return {
        "prefix": [merge(s, pool["prefix"][i], row["prefix"][i], 0)
                   for i, s in enumerate(plan.prefix)],
        "scan": [merge(s, pool["scan"][j], row["scan"][j], 1)
                 for j, s in enumerate(plan.period)],
    }


# ---------------------------------------------------------------------------
# Bytes accounting
# ---------------------------------------------------------------------------

def _attn_leaves(cfg: ModelConfig, tree):
    plan = scan_plan(cfg)
    out = []
    for i, s in enumerate(plan.prefix):
        if s.mixer in ATTN_MIXERS:
            out += jax.tree.leaves(tree["prefix"][i])
    for j, s in enumerate(plan.period):
        if s.mixer in ATTN_MIXERS:
            out += jax.tree.leaves(tree["scan"][j])
    return out


def kv_capacity_bytes(cfg: ModelConfig, tree) -> int:
    """HBM resident for the attention KV leaves (either layout)."""
    return int(sum(leaf.nbytes for leaf in _attn_leaves(cfg, tree)))


def kv_bytes_per_block(cfg: ModelConfig, tree, num_blocks: int) -> int:
    """Bytes one pool block costs across all attention leaves (scanned
    leaves count each repeat, since the pool exists per repeat-layer)."""
    return int(sum(leaf.nbytes // num_blocks
                   for leaf in _attn_leaves(cfg, tree)))


# ---------------------------------------------------------------------------
# Allocator
# ---------------------------------------------------------------------------

class BlockAllocator:
    """Host-side free-list block allocator + block-table shadow.

    The device copy of ``tables`` is refreshed by the engine whenever
    ``version`` changes (admission / release), so frozen rows' stale writes
    always route through an up-to-date table (invariant I4).
    """

    def __init__(self, num_blocks: int, block_size: int, max_batch: int,
                 max_len: int):
        assert num_blocks >= 2, "need at least one block beyond the reserved 0"
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.max_blocks_per_seq = blocks_for(max_len, block_size)
        # LIFO free list; block 0 reserved as the garbage block (I1)
        self.free: List[int] = list(range(num_blocks - 1, 0, -1))
        self.tables = np.zeros((max_batch, self.max_blocks_per_seq), np.int32)
        self.owned: Dict[int, List[int]] = {}
        self.version = 0

    # -- queries ---------------------------------------------------------
    def blocks_needed(self, n_tokens: int) -> int:
        return blocks_for(n_tokens, self.block_size)

    def can_allocate(self, n_blocks: int) -> bool:
        return len(self.free) >= n_blocks

    @property
    def blocks_in_use(self) -> int:
        return sum(len(v) for v in self.owned.values())

    # -- mutation --------------------------------------------------------
    def allocate(self, slot: int, n_tokens: int) -> None:
        assert slot not in self.owned, f"slot {slot} already allocated"
        nb = self.blocks_needed(n_tokens)
        if nb > self.max_blocks_per_seq:
            # never clamp: a short allocation would break I3 and let decode
            # attend garbage-block KV as if it were valid context
            raise ValueError(
                f"{n_tokens} tokens need {nb} blocks but a sequence's block "
                f"table holds {self.max_blocks_per_seq} (max_len too small)")
        assert self.can_allocate(nb), "allocate() without can_allocate()"
        blocks = [self.free.pop() for _ in range(nb)]
        self.owned[slot] = blocks
        self.tables[slot, :] = 0
        self.tables[slot, :nb] = blocks
        self.version += 1

    def grow(self, slot: int, n_tokens: int) -> bool:
        """Extend a live slot's allocation in place to cover ``n_tokens``
        (adaptive tree reshaping: a request switching to a wider template
        needs a larger write window, I3). Appends blocks to the slot's
        table row; returns False — leaving the allocation untouched — when
        the free list or the table row cannot cover the request, so the
        caller can keep the old template instead. Never shrinks: a narrower
        template simply stops reading the extra blocks (they free with the
        slot, keeping release O(1))."""
        cur = self.owned.get(slot)
        assert cur is not None, f"grow() on unallocated slot {slot}"
        nb = self.blocks_needed(n_tokens)
        if nb <= len(cur):
            return True
        extra = nb - len(cur)
        if nb > self.max_blocks_per_seq or not self.can_allocate(extra):
            return False
        blocks = [self.free.pop() for _ in range(extra)]
        self.tables[slot, len(cur):nb] = blocks
        cur.extend(blocks)
        self.version += 1
        return True

    def release(self, slot: int) -> List[int]:
        """O(1) in tokens: just returns the slot's blocks to the free list
        and zeroes its table row (stale writes -> garbage block, I4)."""
        blocks = self.owned.pop(slot, [])
        self.free.extend(blocks)
        self.tables[slot, :] = 0
        self.version += 1
        return blocks
