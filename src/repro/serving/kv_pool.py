"""Block-paged KV-cache pool — the vLLM-style layout for the serving engine.

The contiguous layout allocates ``max_batch x max_len`` cache rows up front,
so HBM footprint is decoupled from what requests actually use. This module
decouples them (DESIGN.md §5):

  * attention KV lives in a shared pool of fixed-size blocks
    ``[num_blocks, block_size, ...]`` (per layer; scanned layers carry a
    leading repeats dim);
  * each slot owns a *block table* row ``[max_blocks_per_seq]`` mapping
    absolute position ``p`` to ``(table[p // block_size], p % block_size)``;
  * a host-side free list hands blocks out at admission and takes them back
    in O(1) at completion. Prefill writes straight into the allocated blocks
    through the table (copy-free admission — no full-pool row scatter);
  * SSM / conv states are O(1) per row and stay batch-indexed.

Invariants (tested in tests/test_engine.py, tests/test_kv_pool.py and
tests/test_prefix_cache.py):

  I1. Block 0 is RESERVED as the garbage block. Unallocated table entries
      are 0, so any write past a row's allocation lands there; reads never
      see it because validity is ``kv_index < kv_len``.
  I2. Every block a slot can WRITE is owned by exactly one slot
      (refcount 1), so the flattened scatter in
      models.attention.write_cache_paged never collides. Prefix-cached
      blocks map into several tables at once (refcount = #mappers) but are
      READ-ONLY: every position a row writes lies past its shared prefix
      (DESIGN.md §8), and ``copy_on_write`` exists as the escape hatch.
  I3. A slot's allocation covers every position the decode loop can write:
      ``prompt + max_new + 2K + 2`` tokens (the speculative write window).
  I4. A released slot's table row is zeroed (on host) before its blocks can
      be handed to another slot, so a frozen row's stale writes route to
      the garbage block, never into a new owner's blocks.
  I5. A block is on the free list or the eviction LRU iff its refcount is
      zero; matching only returns COMPUTED blocks (content fully written by
      the registering row's prefill), so a cache hit can never serve
      half-prefilled KV.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import ssm as ssm_mod
from ..models.attention import kv_dtype_is_quantized, resolve_kv_dtype
from ..models.config import (ATTN_CROSS, ATTN_GLOBAL, ATTN_LOCAL, ATTN_MLA,
                             SSM, ModelConfig, scan_plan)

ATTN_MIXERS = (ATTN_GLOBAL, ATTN_LOCAL, ATTN_MLA)


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks covering ``n_tokens`` positions (ceiling division)."""
    return -(-int(n_tokens) // block_size)


def default_num_blocks(max_batch: int, max_len: int, block_size: int) -> int:
    """Worst-case pool size (every slot filled to max_len) + garbage block.

    Serving deployments pass something smaller and rely on admission
    backpressure; this default keeps the paged engine drop-in safe.
    """
    return max_batch * blocks_for(max_len, block_size) + 1


# ---------------------------------------------------------------------------
# Pool init
# ---------------------------------------------------------------------------

def _paged_layer_cache(cfg: ModelConfig, spec, num_blocks, block_size, batch,
                       dtype):
    quant = kv_dtype_is_quantized(dtype)
    if spec.mixer in (ATTN_GLOBAL, ATTN_LOCAL):
        hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        c = {"k": jnp.zeros((num_blocks, block_size, hkv, hd), dtype),
             "v": jnp.zeros((num_blocks, block_size, hkv, hd), dtype)}
        if quant:
            # per-(slot, head) dequant scales ride alongside the pool and
            # through the same block-table indirection (DESIGN.md §10);
            # scale 1 keeps the garbage block dequantizing to exact zeros
            c["k_scale"] = jnp.ones((num_blocks, block_size, hkv),
                                    jnp.float32)
            c["v_scale"] = jnp.ones((num_blocks, block_size, hkv),
                                    jnp.float32)
        return c
    if spec.mixer == ATTN_MLA:
        width = cfg.kv_lora_rank + cfg.qk_rope_head_dim
        c = {"ckv": jnp.zeros((num_blocks, block_size, width), dtype)}
        if quant:
            # one scale per compressed-KV vector
            c["ckv_scale"] = jnp.ones((num_blocks, block_size), jnp.float32)
        return c
    if spec.mixer == SSM:
        return ssm_mod.init_mamba2_state(cfg, batch, jnp.float32)
    if spec.mixer == ATTN_CROSS:
        return {}
    raise ValueError(spec.mixer)


def init_paged_caches(cfg: ModelConfig, batch: int, num_blocks: int,
                      block_size: int, dtype=jnp.bfloat16, mesh=None):
    """Cache pytree with the SAME structure as models.init_caches, but
    attention leaves are shared block pools [NB, bs, ...] (no batch dim);
    SSM states remain [batch, ...]. ``dtype`` accepts a kv_dtype name
    ("bf16"/"fp32"/"int8"/"fp8") or a jnp dtype; quantized dtypes add
    sibling *_scale pool leaves. ``mesh`` shards the pools for tensor-
    parallel serving per sharding.specs.paged_cache_specs (KV heads over
    the "model" axis, quant scales alongside, everything else replicated —
    DESIGN.md §11)."""
    dtype = resolve_kv_dtype(dtype)
    plan = scan_plan(cfg)
    pool = {
        "prefix": [_paged_layer_cache(cfg, s, num_blocks, block_size, batch,
                                      dtype)
                   for s in plan.prefix],
        "scan": [jax.tree.map(
            lambda x: jnp.broadcast_to(x, (plan.n_repeats,) + x.shape).copy()
            if hasattr(x, "shape") else x,
            _paged_layer_cache(cfg, s, num_blocks, block_size, batch, dtype))
            for s in plan.period],
    }
    if mesh is not None:
        from ..sharding import specs as _specs
        pool = jax.device_put(
            pool, _specs.to_named(_specs.paged_cache_specs(pool, mesh), mesh))
    return pool


def prefill_cache_view(cfg: ModelConfig, pool, paged: bool):
    """The cache tree a single-request prefill forward should run against.

    Paged: attention leaves ARE the pool (the forward writes through the
    slot's block-table row — copy-free admission), SSM leaves a fresh
    one-row state. Contiguous: handled by the caller (init_caches(cfg, 1)).
    """
    assert paged
    plan = scan_plan(cfg)

    def one(spec, entry, scanned):
        if spec.mixer != SSM:
            return entry
        row = ssm_mod.init_mamba2_state(cfg, 1, jnp.float32)
        if scanned:
            row = jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x, (plan.n_repeats,) + x.shape).copy(), row)
        return row

    return {
        "prefix": [one(s, pool["prefix"][i], False)
                   for i, s in enumerate(plan.prefix)],
        "scan": [one(s, pool["scan"][j], True)
                 for j, s in enumerate(plan.period)],
    }


def scatter_row_caches(cfg: ModelConfig, pool, row, slot, paged: bool):
    """Merge a prefill result into the engine's cache pools at ``slot``.

    Paged: attention entries in ``row`` are the already-updated pools
    (adopted as-is); only the O(1) SSM states are scattered. Contiguous:
    every leaf is a [1, ...] row scattered at batch index ``slot`` (prefix
    leaves carry batch at axis 0, scanned leaves at axis 1).
    ``slot`` may be traced (dynamic_update_slice start).
    """
    plan = scan_plan(cfg)
    slot = jnp.asarray(slot, jnp.int32)

    def ins_axis(axis):
        def ins(p, r):
            idx = [jnp.zeros((), jnp.int32)] * p.ndim
            idx[axis] = slot
            return jax.lax.dynamic_update_slice(p, r.astype(p.dtype),
                                                tuple(idx))
        return ins

    def merge(spec, pool_e, row_e, axis):
        if paged and spec.mixer in ATTN_MIXERS:
            return row_e                       # row IS the updated pool
        return jax.tree.map(ins_axis(axis), pool_e, row_e)

    return {
        "prefix": [merge(s, pool["prefix"][i], row["prefix"][i], 0)
                   for i, s in enumerate(plan.prefix)],
        "scan": [merge(s, pool["scan"][j], row["scan"][j], 1)
                 for j, s in enumerate(plan.period)],
    }


# ---------------------------------------------------------------------------
# Bytes accounting
# ---------------------------------------------------------------------------

def _attn_leaves(cfg: ModelConfig, tree):
    plan = scan_plan(cfg)
    out = []
    for i, s in enumerate(plan.prefix):
        if s.mixer in ATTN_MIXERS:
            out += jax.tree.leaves(tree["prefix"][i])
    for j, s in enumerate(plan.period):
        if s.mixer in ATTN_MIXERS:
            out += jax.tree.leaves(tree["scan"][j])
    return out


def kv_capacity_bytes(cfg: ModelConfig, tree) -> int:
    """HBM resident for the attention KV leaves (either layout)."""
    return int(sum(leaf.nbytes for leaf in _attn_leaves(cfg, tree)))


def kv_bytes_per_block(cfg: ModelConfig, tree, num_blocks: int) -> int:
    """Bytes one pool block costs across all attention leaves (scanned
    leaves count each repeat, since the pool exists per repeat-layer)."""
    return int(sum(leaf.nbytes // num_blocks
                   for leaf in _attn_leaves(cfg, tree)))


# ---------------------------------------------------------------------------
# Allocator
# ---------------------------------------------------------------------------

def prefix_block_keys(prompt, block_size: int,
                      kv_dtype: str = "bf16") -> List[bytes]:
    """Content-chained cache keys for the FULL blocks inside ``prompt[:-1]``
    (the region admission prefills — the last prompt token is re-processed
    by the first verify window and its block is written by decode).

    ``key[i]`` identifies the exact token prefix ``prompt[:(i+1)*bs]``: the
    raw byte string of the prefix, so two prompts share a key iff they share
    the tokens verbatim — content-exact, no hash collisions, and chaining is
    implicit (a block's key embeds every preceding token). Target and draft
    KV are keyed TOGETHER: both models cache the same absolute positions
    through one shared block table, so one key covers both pools.

    Keys are SALTED with ``kv_dtype``: a block's cached payload is the
    dtype-specific encoding (quantized values + scales vs full precision),
    so the same token prefix under different kv_dtypes must never alias —
    an int8 engine re-reading an fp32 engine's key (or vice versa) would
    serve bytes in the wrong encoding.
    """
    p = np.ascontiguousarray(np.asarray(prompt, np.int32))
    n_full = max(0, (len(p) - 1)) // block_size
    salt = kv_dtype.encode() + b"|"
    return [salt + p[:(i + 1) * block_size].tobytes() for i in range(n_full)]


class PrefixIndex:
    """The one shared content-keyed prefix-cache index spanning every
    engine replica (DESIGN.md §12).

    Block ids are physical pool slots and mean nothing across replicas, so
    each replica's :class:`BlockAllocator` keeps its own ``key -> block``
    map; this object is the registry of those per-replica maps. Admission
    asks :meth:`best_replica` which replica already holds a prompt's
    leading blocks (prefix-affinity routing) — a hit routes the request to
    the owning replica, a miss falls back to least-loaded. With ``dp=1``
    the index degenerates to a thin wrapper over the single allocator and
    routing is a no-op.
    """

    def __init__(self):
        self.allocators: Dict[int, "BlockAllocator"] = {}

    def register(self, replica: int, alloc: "BlockAllocator") -> None:
        """Attach ``alloc`` as replica ``replica``'s block map (done by
        ``BlockAllocator.__init__`` when constructed with this index)."""
        if replica in self.allocators:
            raise ValueError(f"replica {replica} already registered")
        self.allocators[replica] = alloc

    def match(self, keys: Sequence[bytes]) -> Dict[int, List[int]]:
        """Per-replica ``match_prefix`` results for ``keys`` (pure query)."""
        return {r: a.match_prefix(keys)
                for r, a in sorted(self.allocators.items())}

    def best_replica(self, keys: Sequence[bytes]):
        """``(replica, blocks)`` for the replica holding the LONGEST
        computed cached prefix of ``keys``, or ``(None, [])`` when no
        replica holds any block. Ties go to the lowest replica id (stable
        under re-query, so routing is deterministic)."""
        best_r, best = None, []
        for r, a in sorted(self.allocators.items()):
            m = a.match_prefix(keys)
            if len(m) > len(best):
                best_r, best = r, m
        return best_r, best


class BlockAllocator:
    """Host-side refcounted block allocator + block-table shadow + prompt
    prefix cache (DESIGN.md §5/§8).

    The device copy of ``tables`` is refreshed by the engine whenever
    ``version`` changes (admission / release / COW), so frozen rows' stale
    writes always route through an up-to-date table (invariant I4).

    Prefix caching: ``allocate(..., keys=)`` registers the row's full
    prompt blocks under content-exact keys (``prefix_block_keys``); the
    scheduler marks them COMPUTED as the chunked-prefill cursor passes
    them. ``match_prefix`` returns the longest run of computed cached
    blocks for a new prompt; ``allocate(..., prefix=)`` maps them
    copy-free into the new row's table (refcount + 1) so the row only
    prefills the uncovered tail. Released cached blocks (refcount 0) park
    on an LRU instead of the free list and are evicted — unregistered and
    recycled — only when allocation outgrows the free list.
    """

    def __init__(self, num_blocks: int, block_size: int, max_batch: int,
                 max_len: int, *, replica: int = 0,
                 prefix_index: Optional[PrefixIndex] = None):
        assert num_blocks >= 2, "need at least one block beyond the reserved 0"
        self.num_blocks = num_blocks
        # data-parallel serving (DESIGN.md §12): which engine replica this
        # pool backs, and the shared cross-replica index it reports to
        self.replica = replica
        self.prefix_index = prefix_index
        if prefix_index is not None:
            prefix_index.register(replica, self)
        self.block_size = block_size
        self.max_blocks_per_seq = blocks_for(max_len, block_size)
        # LIFO free list; block 0 reserved as the garbage block (I1)
        self.free: List[int] = list(range(num_blocks - 1, 0, -1))
        self.tables = np.zeros((max_batch, self.max_blocks_per_seq), np.int32)
        self.owned: Dict[int, List[int]] = {}
        self.ref = np.zeros(num_blocks, np.int32)     # mappers per block
        self.index: Dict[bytes, int] = {}             # cache key -> block
        self.block_key: Dict[int, bytes] = {}         # block -> cache key
        self.computed: set = set()                    # content fully written
        self.lru: "OrderedDict[int, None]" = OrderedDict()  # ref-0 cached
        # slot -> table indices mapped READ-ONLY (prefix-matched blocks);
        # copy_on_write removes an index once privately remapped
        self.read_only: Dict[int, set] = {}
        self.version = 0

    # -- queries ---------------------------------------------------------
    def blocks_needed(self, n_tokens: int) -> int:
        return blocks_for(n_tokens, self.block_size)

    def can_allocate(self, n_blocks: int,
                     prefix: Sequence[int] = ()) -> bool:
        """True when ``n_blocks`` FRESH blocks are claimable (free list
        plus evictable ref-0 cached blocks). When the admission also maps
        ``prefix`` blocks, pass them: matched blocks currently parked on
        the LRU are about to be ref-bumped OFF it by ``allocate``, so they
        must not be counted as reclaimable too."""
        lru_hits = sum(1 for b in prefix if b in self.lru)
        return len(self.free) + len(self.lru) - lru_hits >= n_blocks

    @property
    def blocks_in_use(self) -> int:
        """Unique blocks mapped by live slots (shared blocks count once —
        that is the point of prefix sharing)."""
        return int((self.ref > 0).sum())

    def match_prefix(self, keys: Sequence[bytes]) -> List[int]:
        """Longest run of cached, COMPUTED blocks covering ``keys`` from
        the front (I5: a half-prefilled registration never matches). Pure
        query: refcounts move in ``allocate(prefix=...)``."""
        out: List[int] = []
        for key in keys:
            b = self.index.get(key)
            if b is None or b not in self.computed:
                break
            out.append(b)
        return out

    # -- internals -------------------------------------------------------
    def _unregister(self, block: int) -> None:
        key = self.block_key.pop(block, None)
        if key is not None and self.index.get(key) == block:
            del self.index[key]
        self.computed.discard(block)

    def _take_block(self) -> int:
        """A writable block: free list first, then evict the LRU ref-0
        cached block (unregistered before reuse, so a stale key can never
        resolve to recycled content)."""
        if self.free:
            return self.free.pop()
        block, _ = self.lru.popitem(last=False)       # least recently parked
        self._unregister(block)
        return block

    # -- mutation --------------------------------------------------------
    def allocate(self, slot: int, n_tokens: int,
                 prefix: Sequence[int] = (),
                 keys: Sequence[bytes] = ()) -> None:
        """Claim blocks covering ``n_tokens`` for ``slot``.

        ``prefix``: cached blocks from ``match_prefix`` to map copy-free as
        the row's leading blocks (refcount + 1; read-only for this row —
        its first writable position lies past them). ``keys``: the row's
        ``prefix_block_keys``; its full prompt blocks are registered under
        them for future reuse (first registration wins when identical
        prompts race)."""
        assert slot not in self.owned, f"slot {slot} already allocated"
        nb = self.blocks_needed(n_tokens)
        if nb > self.max_blocks_per_seq:
            # never clamp: a short allocation would break I3 and let decode
            # attend garbage-block KV as if it were valid context
            raise ValueError(
                f"{n_tokens} tokens need {nb} blocks but a sequence's block "
                f"table holds {self.max_blocks_per_seq} (max_len too small)")
        prefix = list(prefix)
        assert len(prefix) <= nb, "prefix longer than the allocation"
        assert self.can_allocate(nb - len(prefix), prefix), \
            "allocate() without can_allocate()"
        # bump shared refs FIRST so eviction below can never take them
        for b in prefix:
            if self.ref[b] == 0:
                self.lru.pop(b)
            self.ref[b] += 1
        fresh = [self._take_block() for _ in range(nb - len(prefix))]
        for b in fresh:
            self.ref[b] = 1
        blocks = prefix + fresh
        self.owned[slot] = blocks
        self.read_only[slot] = set(range(len(prefix)))
        for i, key in enumerate(keys[:nb]):
            b = blocks[i]
            if key not in self.index and b not in self.block_key:
                self.index[key] = b
                self.block_key[b] = key
        self.tables[slot, :] = 0
        self.tables[slot, :nb] = blocks
        self.version += 1

    def mark_computed(self, slot: int, n_tokens: int) -> None:
        """Flag the slot's registered blocks whose content is fully covered
        by ``n_tokens`` valid cache positions (the prefill cursor) as
        matchable (I5). Called by the scheduler as chunked prefill
        advances; prefix-matched blocks are already computed."""
        for i, b in enumerate(self.owned.get(slot, ())):
            if (i + 1) * self.block_size > n_tokens:
                break
            if b in self.block_key:
                self.computed.add(b)

    def grow(self, slot: int, n_tokens: int) -> bool:
        """Extend a live slot's allocation in place to cover ``n_tokens``
        (adaptive tree reshaping: a request switching to a wider template
        needs a larger write window, I3). Appends blocks to the slot's
        table row; returns False — leaving the allocation untouched — when
        the free list or the table row cannot cover the request, so the
        caller can keep the old template instead. Never shrinks: a narrower
        template simply stops reading the extra blocks (they free with the
        slot, keeping release O(1))."""
        cur = self.owned.get(slot)
        assert cur is not None, f"grow() on unallocated slot {slot}"
        nb = self.blocks_needed(n_tokens)
        if nb <= len(cur):
            return True
        extra = nb - len(cur)
        if nb > self.max_blocks_per_seq or not self.can_allocate(extra):
            return False
        blocks = [self._take_block() for _ in range(extra)]
        for b in blocks:
            self.ref[b] = 1
        self.tables[slot, len(cur):nb] = blocks
        cur.extend(blocks)
        self.version += 1
        return True

    def copy_on_write(self, slot: int,
                      block_idx: int) -> Optional[Tuple[int, int]]:
        """Make the slot's ``block_idx``-th block privately writable.

        Returns ``(old, new)`` when a fresh block was mapped — the CALLER
        must copy the device KV ``old -> new`` in every pool before the
        next forward — or None when the block was already exclusive (a
        sole-owner cached block is detached from the index instead of
        copied: its content is about to diverge from its key)."""
        blocks = self.owned[slot]
        old = blocks[block_idx]
        if self.ref[old] == 1:
            if old in self.block_key:
                self._unregister(old)
            self.read_only.get(slot, set()).discard(block_idx)
            return None
        if not self.can_allocate(1):
            # the caller NEEDS the write — failing to copy would corrupt a
            # shared block — so this is a hard error with a clear message,
            # not the bare KeyError an empty LRU pop would raise. (Callers
            # that can wait should check can_allocate(1) first.)
            raise RuntimeError(
                f"copy-on-write of shared block {old} needs a free block "
                f"but the pool is exhausted; raise kv_num_blocks")
        new = self._take_block()
        self.ref[new] = 1
        self.ref[old] -= 1
        blocks[block_idx] = new
        self.read_only.get(slot, set()).discard(block_idx)
        self.tables[slot, block_idx] = new
        self.version += 1
        return old, new

    def release(self, slot: int) -> List[int]:
        """O(1) in tokens: drop the slot's mappings and zero its table row
        (stale writes -> garbage block, I4). Blocks reaching refcount 0
        return to the free list — except computed cached blocks, which park
        on the eviction LRU so a later identical prompt can still hit them
        (I5); a block with surviving mappers stays exactly where it is."""
        blocks = self.owned.pop(slot, [])
        self.read_only.pop(slot, None)
        for b in blocks:
            self.ref[b] -= 1
            assert self.ref[b] >= 0, f"refcount underflow on block {b}"
            if self.ref[b] == 0:
                if b in self.block_key and b in self.computed:
                    self.lru[b] = None
                    self.lru.move_to_end(b)       # most recently released
                else:
                    self._unregister(b)
                    self.free.append(b)
        self.tables[slot, :] = 0
        self.version += 1
        return blocks
