"""Host-side half of the serving stack (DESIGN.md §8).

The ``Scheduler`` owns every decision that does NOT touch the device:

  * request queue + FIFO-fair skip-ahead admission: a bounded prefix of the
    queue (``admit_window``) is scanned per free slot, so one pool-oversized
    request cannot starve smaller ones behind it while blocks are free —
    the head admits the moment its resources exist, and nothing beyond the
    window may overtake it;
  * chunked-prefill budgeting: admission caps CONCURRENT prefilling rows at
    ``prefill_budget // chunk_width`` lanes so a burst of long prompts
    cannot crowd decode compute out of the fused steps (None = no throttle;
    full-prefix cache hits consume no lane);
  * prefix-cache admission: the longest computed block-aligned prefix of
    the new prompt is mapped copy-free from ``kv_pool.BlockAllocator``'s
    hash index, and only the uncovered tail is prefilled (the prefill
    cursor starts past the hit);
  * per-request latency accounting: queue wait, TTFT, per-token
    inter-commit latency percentiles, and per-step host overhead (the
    wall time from harvest-complete to the next dispatch), recorded on
    every ``Completion`` and summarised by ``latency_summary``;
  * the adaptive tree-template controller (``TreeController``) and the
    between-windows reshaping cadence.

Stepping follows the executor's dispatch/harvest split (DESIGN.md §9):
``dispatch()`` issues one fused step non-blocking — applying every staged
mutation (retirements from the previous harvest, template re-selection)
on device ahead of the inner step — and immediately advances all
DISPATCH-DETERMINISTIC accounting: step counters, the prefill cursor
mirrors (the chunk schedule depends only on the cursor, never on step
results), computed-block flags. ``process(handle)`` harvests a step's
results in one batched transfer and folds in everything RESULT-DEPENDENT:
acceptance stats and controller updates from the device-reported live
mask, completions (EOS / max_new), and retirement — STAGED, applied at
the next dispatch boundary. The synchronous loop is the depth-1 special
case of the same protocol (dispatch immediately followed by process), so
the pipelined loop's semantics are the synchronous ones shifted by at
most one step.

Data parallelism (DESIGN.md §12): the scheduler can manage SEVERAL
executor replicas — each an independent ``(SpecDecoder, Executor,
BlockAllocator)`` triple with its own ``DecodeState`` and KV pool on its
own mesh row — behind the ONE shared queue. Per-replica host mirrors live
in ``_Replica`` records; admission routes each request by
prefix-affinity-then-least-loaded over the shared content-keyed prefix
index (a replica already holding the prompt's cached blocks gets the
request; misses go to the emptiest replica; a full preferred replica is
skipped, never stalled on). With ``dp=1`` every path below degenerates to
the historical single-engine behaviour.

Device work (cache pools, jitted fused steps, row state) lives in
``serving.executor.Executor``; ``serving.engine.Engine`` is the thin
facade wiring the two together.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from ..core.spec_decode import SpecDecoder, TemplateBank
from . import kv_pool
from .config import SamplingParams
from .executor import NO_LIMIT, Executor, StepHandle, StepResult


@dataclasses.dataclass
class Request:
    """One queued request. Per-request decode options travel as ONE
    ``SamplingParams`` value object (serving/config.py); the flat
    accessors below keep every consumer of the old loose fields
    (admission, template selection, completion accounting) unchanged."""
    rid: int
    prompt: np.ndarray          # 1-D int32
    params: SamplingParams

    @property
    def max_new(self) -> int:
        return self.params.max_new

    @property
    def temperature(self) -> Optional[float]:
        return self.params.temperature    # None = the engine default

    @property
    def tree_idx(self) -> Optional[int]:
        return self.params.tree_idx       # pinned bank template (None =
        #                                   controller / template 0)

    @property
    def seed(self) -> Optional[int]:
        return self.params.seed           # None = (engine seed, rid) stream


@dataclasses.dataclass
class Completion:
    """A finished request as handed back by ``Engine.run``: the committed
    tokens plus the per-request latency accounting (all wall-clock
    seconds; ``tok_*`` are inter-commit percentiles in milliseconds)."""

    rid: int
    tokens: np.ndarray          # prompt + generated
    generated: int
    wall_submitted: float
    wall_done: float
    queue_wait: float = 0.0     # submit -> admission
    ttft: float = 0.0           # submit -> first generated token committed
    tok_p50: float = 0.0        # per-token inter-commit latency percentiles
    tok_p95: float = 0.0


def _weighted_percentile(samples: List, q: float) -> float:
    """Percentile over (value, weight) pairs — weights are token counts, so
    a step that committed 3 tokens contributes its per-token latency x3."""
    if not samples:
        return 0.0
    vals = np.repeat([v for v, _ in samples], [c for _, c in samples])
    return float(np.percentile(vals, q))


class TreeController:
    """Acceptance-statistics template selection (DESIGN.md §7).

    Maintains, per slot and per (depth d, sibling rank c), an EWMA of the
    indicator "depth d was evaluated this step and rank c's candidate was
    the accepted one" — updated ONLY at steps where rank c was actually
    OFFERED (c < the in-use template's branching at d), so the estimate is
    the conditional accept probability P(rank c wins | depth d reached,
    rank c offered) regardless of which template happened to be active.
    A template's score is its expected accepted length under independence
    across ranks: E(t) = sum_d prod_{d' <= d} min(1, sum_{c < b_d'} p[d',c]).

    New requests have no history, so admission selects on a GLOBAL EWMA
    that every retiring request folds its learned row into; per-slot rows
    are seeded from the global one at admission and drive the between-
    windows re-selection (``Scheduler._reshape_slots``).
    """

    def __init__(self, bank: TemplateBank, max_batch: int, ewma: float = 0.2):
        self.bank = bank
        self.ewma = ewma
        d, mb = bank.max_depth, bank.max_branching
        self.offer = np.zeros((len(bank), d), np.int32)   # [T, D] branching
        for t, tpl in enumerate(bank.templates):
            self.offer[t] = tpl.branching
        # optimistic prior: rank 0 accepts half the time, each extra rank
        # adds a little — wide templates stay in play until data arrives
        prior = np.zeros((d, mb))
        prior[:, 0] = 0.5
        if mb > 1:
            prior[:, 1:] = 0.15
        self.global_p = prior.copy()
        self.slot_p = np.tile(prior, (max_batch, 1, 1))
        # cached scoring machinery (the adaptive-tree host hot path runs
        # every harvested step over dp*max_batch rows — keep it vectorized):
        # offered-rank mask per template [T, D, mb] and scratch rank ids
        self._offer_mask = (np.arange(mb)[None, None, :]
                            < self.offer[:, :, None])
        self._ranks = np.arange(mb)
        self._depths = np.arange(d)

    def seed_slot(self, slot: int) -> None:
        self.slot_p[slot] = self.global_p

    def retire_slot(self, slot: int) -> None:
        """Fold a finished request's learned statistics into the admission
        prior (an EWMA over requests, like the per-step one over windows)."""
        self.global_p += 0.5 * (self.slot_p[slot] - self.global_p)

    def update(self, live: np.ndarray, tree_idx: np.ndarray, a: np.ndarray,
               rank: np.ndarray) -> None:
        """live [B] (rows decoding BEFORE the step), tree_idx [B], a [B]
        accepted depths, rank [B, D] accepted sibling rank per depth (-1
        where the depth rejected or was never reached).

        One vectorized EWMA write over [live, D, mb]: a cell (slot, dep, c)
        updates iff the depth was evaluated this step (dep <= a — depths
        1..a accepted, depth a+1 evaluated and rejected, deeper ones carry
        no information) AND rank c was offered (c < the in-use template's
        branching at dep). Cell updates are independent, so this computes
        bit-identical values to the scalar triple loop it replaced."""
        idx = np.nonzero(live)[0]
        if idx.size == 0:
            return
        br = self.offer[np.asarray(tree_idx)[idx]]            # [n, D]
        evaluated = self._depths[None, :] <= np.asarray(a)[idx, None]
        offered = self._ranks[None, None, :] < br[:, :, None]  # [n, D, mb]
        upd = evaluated[:, :, None] & offered
        obs = (np.asarray(rank)[idx][:, :, None]
               == self._ranks[None, None, :]).astype(self.slot_p.dtype)
        p = self.slot_p[idx]
        self.slot_p[idx] = np.where(upd, p + self.ewma * (obs - p), p)

    def select(self, slot: Optional[int] = None,
               feasible=None) -> int:
        """Best-scoring template (per-slot stats, or the global prior for
        admission). ``feasible``: optional iterable of permitted template
        indices (allocation / max_len constraints)."""
        p = self.global_p if slot is None else self.slot_p[slot]
        cands = range(len(self.bank)) if feasible is None else list(feasible)
        # all templates scored in one shot against the cached offered-rank
        # masks: s[t, d] = min(1, sum_{c < b_d} p[d, c]), E(t) = sum of the
        # depth-wise survival cumprod
        s = np.minimum(1.0, np.where(self._offer_mask, p[None], 0.0).sum(-1))
        scores = np.cumprod(s, axis=1).sum(axis=1)
        best, best_e = next(iter(cands)), -1.0
        for t in cands:   # keep the earliest-wins 1e-9 tie-break semantics
            if scores[t] > best_e + 1e-9:
                best, best_e = t, float(scores[t])
        return best


class _Replica:
    """Host-side mirrors for ONE engine replica (DESIGN.md §12): its
    decoder/executor/allocator triple plus every per-slot array the
    scheduler maintains — slot table, commit limits, prefill cursors,
    latency samples, and the staged-retirement mask. With ``dp=1`` there
    is exactly one of these and the scheduler degenerates to the
    single-replica behaviour."""

    def __init__(self, rep: int, dec: SpecDecoder, ex: Executor,
                 alloc: Optional[kv_pool.BlockAllocator], max_batch: int):
        self.rep = rep
        self.base = rep * max_batch     # TreeController slot-row offset
        self.dec, self.ex, self.alloc = dec, ex, alloc
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.slot_limit = np.zeros(max_batch, np.int64)
        self.slot_tree = np.zeros(max_batch, np.int32)
        self.slot_steps = np.zeros(max_batch, np.int64)
        # host mirrors of the device prefill cursor (advanced in lockstep)
        self.slot_pf = np.zeros(max_batch, np.int64)
        self.slot_pf_len = np.zeros(max_batch, np.int64)
        # latency accounting
        self.slot_submit_t = np.zeros(max_batch)
        self.slot_admit_t = np.zeros(max_batch)
        self.slot_first_t = np.full(max_batch, np.nan)
        self.slot_last_t = np.zeros(max_batch)
        self.slot_last_n = np.zeros(max_batch, np.int64)
        self.slot_samples: List[List] = [[] for _ in range(max_batch)]
        # staged mutation protocol (DESIGN.md §9): decisions made while a
        # step may be in flight are applied at the NEXT dispatch boundary
        self.pending_retire = np.zeros(max_batch, bool)
        self._occ_cache: Optional[np.ndarray] = None

    def occupied_mask(self) -> np.ndarray:
        """[B] bool — slots holding a live request. Built once per slot
        mutation, not per query: admission and completion invalidate the
        cache; every mask consumer between them shares one array."""
        if self._occ_cache is None:
            self._occ_cache = np.asarray([s is not None for s in self.slots])
        return self._occ_cache

    def live_decode_mask(self) -> np.ndarray:
        """Rows occupied AND past their prefill (the rows a step commits
        tokens for)."""
        return self.occupied_mask() & (self.slot_pf >= self.slot_pf_len)

    def prefilling_count(self) -> int:
        """Occupied rows whose prefill cursor has not reached the prompt."""
        occ = self.occupied_mask()
        return int((occ & (self.slot_pf < self.slot_pf_len)).sum())

    def occupancy(self) -> int:
        """Occupied-slot count — the load metric admission routing uses."""
        return int(self.occupied_mask().sum())

    def first_free_slot(self) -> Optional[int]:
        """Lowest free slot index, or None when the replica is full."""
        for slot, s in enumerate(self.slots):
            if s is None:
                return slot
        return None

    def has_live(self) -> bool:
        """True when any slot holds a request (the replica needs steps)."""
        return any(s is not None for s in self.slots)


class Scheduler:
    """Queues, admission and accounting over one or more Executor replicas
    (see module docstring). The Engine drives ``admit() ->
    dispatch(replica)`` once per tick per live replica and
    ``process(handle)`` once per completed step — back-to-back in the
    synchronous loop, one step apart in the pipelined one."""

    def __init__(self, dec, executor, alloc, *, mode: str,
                 max_batch: int, max_len: int, temperature: float,
                 eos_id: Optional[int], bank: Optional[TemplateBank],
                 ctrl: Optional[TreeController], prefix_cache: bool,
                 admit_window: int, prefill_budget: Optional[int],
                 tree_reselect_every: int,
                 prefix_index: Optional[kv_pool.PrefixIndex] = None):
        """``dec`` / ``executor`` / ``alloc`` are either single objects
        (``dp=1``, the historical form) or equal-length sequences — one
        per data-parallel replica. ``prefix_index`` is the shared
        cross-replica prefix-cache index admission routes over (None for
        a single replica, where routing is a no-op)."""
        exs = list(executor) if isinstance(executor, (list, tuple)) \
            else [executor]
        decs = list(dec) if isinstance(dec, (list, tuple)) \
            else [dec] * len(exs)
        allocs = list(alloc) if isinstance(alloc, (list, tuple)) \
            else [alloc] * len(exs)
        if not (len(decs) == len(exs) == len(allocs)):
            raise ValueError(
                f"replica sequences disagree: {len(decs)} decoders, "
                f"{len(exs)} executors, {len(allocs)} allocators")
        self.replicas = [_Replica(r, d, e, a, max_batch)
                         for r, (d, e, a)
                         in enumerate(zip(decs, exs, allocs))]
        self.dp = len(self.replicas)
        self.prefix_index = prefix_index
        self.dec = decs[0]    # shape config: templates / slack / chunking
        self.mode = mode
        self.paged = allocs[0] is not None
        self.max_batch, self.max_len = max_batch, max_len
        self.temperature = temperature
        self.eos_id = eos_id
        self.bank, self.ctrl = bank, ctrl
        self.prefix_cache = prefix_cache
        self.admit_window = admit_window
        self.tree_reselect_every = tree_reselect_every
        self.chunk = self.dec.chunk_width
        # token budget per step for prompt chunks -> concurrent lanes
        # (per REPLICA: the budget protects each replica's own fused step)
        self.prefill_lanes = (None if prefill_budget is None
                              else max(1, prefill_budget // self.chunk))

        self.queue: deque[Request] = deque()
        self.completions: List[Completion] = []
        # per-step host overhead: harvest-complete -> next dispatch, ms
        self.host_overhead_ms: List[float] = []
        self._harvest_done_t: Optional[float] = None

        self._next_rid = 0
        self._submit_t_of: Dict[int, float] = {}   # rid -> submit wall time
        self.stats: Dict = dict(
            steps=0, committed=0, accepted=0, live_steps=0,
            draft_forwards=0, target_forwards=0, round_hist=None,
            prefill_chunks=0, prefill_tokens=0,
            prefix_lookup_blocks=0, prefix_hit_blocks=0,
            replica_steps=[0] * self.dp, affinity_routed=0)
        if bank is not None:
            self.stats["tree_hist"] = np.zeros(len(bank), np.int64)
            self.stats["tree_switches"] = 0

    # ---------------------------------------------- replica-0 conveniences
    # The historical single-replica attribute surface (engine facade,
    # tests, benchmarks) reads through to replica 0; with dp=1 that IS the
    # whole scheduler state.
    @property
    def ex(self) -> Executor:
        """Replica 0's executor (the only one with ``dp=1``)."""
        return self.replicas[0].ex

    @property
    def alloc(self) -> Optional[kv_pool.BlockAllocator]:
        """Replica 0's block allocator (None in the contiguous layout)."""
        return self.replicas[0].alloc

    @property
    def slots(self) -> List[Optional[Request]]:
        """Replica 0's slot table."""
        return self.replicas[0].slots

    # ------------------------------------------------------------- submit
    def submit(self, prompt, max_new: Optional[int] = None,
               temperature: Optional[float] = None,
               tree_idx: Optional[int] = None,
               params: Optional[SamplingParams] = None) -> int:
        if params is None:
            params = SamplingParams(max_new=max_new, temperature=temperature,
                                    tree_idx=tree_idx)
        else:
            if temperature is not None or tree_idx is not None:
                raise ValueError("pass per-request options inside "
                                 "SamplingParams, not alongside it")
            params = params.merged(max_new)
        max_new, tree_idx = params.max_new, params.tree_idx
        prompt = np.asarray(prompt, np.int32)
        if tree_idx is not None and (
                self.bank is None or not 0 <= tree_idx < len(self.bank)):
            raise ValueError(
                f"tree_idx={tree_idx} needs a TemplateBank with more "
                f"than {tree_idx} templates")
        if not self.paged or self.bank is None:
            # contiguous rows are written batch-wide (the widest window,
            # clamped dynamic_update_slice would corrupt committed KV past
            # max_len), so the bank-wide slack is the real requirement
            # whatever template the request pins
            slack = self.dec.window_slack
        elif tree_idx is not None:
            slack = self.dec.row_slack(tree_idx)
        else:
            slack = self.dec.min_row_slack
        need = len(prompt) + max_new + slack
        if len(prompt) < 2 or need > self.max_len:
            # a raised error, not an assert: past this point an oversized
            # request would outgrow its cache rows/blocks and silently
            # attend garbage
            raise ValueError(
                f"request needs {need} cache positions (prompt="
                f"{len(prompt)}, max_new={max_new}, window slack="
                f"{slack}) but max_len={self.max_len}; "
                f"prompts also need >= 2 tokens")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, prompt, params))
        self._submit_t_of[rid] = time.perf_counter()
        return rid

    def has_work(self) -> bool:
        """True while anything is queued or occupies a slot anywhere."""
        return bool(self.queue) or any(rep.has_live()
                                       for rep in self.replicas)

    def occupied_mask(self) -> np.ndarray:
        """Replica 0's occupancy mask (see ``_Replica.occupied_mask``)."""
        return self.replicas[0].occupied_mask()

    def live_decode_mask(self) -> np.ndarray:
        """Replica 0's live-decode mask (see ``_Replica``)."""
        return self.replicas[0].live_decode_mask()

    def prefilling_count(self) -> int:
        """Prefilling rows across ALL replicas (the lane budget itself is
        enforced per replica inside admission)."""
        return sum(rep.prefilling_count() for rep in self.replicas)

    # ---------------------------------------------------------- admission
    def _feasible_templates(self, req: Request) -> List[int]:
        """Bank templates whose window slack fits ``req`` inside max_len.
        Never empty: submit() validated the smallest slack (paged) or the
        bank-wide one (contiguous, where every template fits by then)."""
        budget = self.max_len - len(req.prompt) - req.max_new
        return [t for t in range(len(self.bank))
                if self.dec.row_slack(t) <= budget]

    def _pick_template(self, req: Request) -> int:
        if self.bank is None:
            return 0
        if req.tree_idx is not None:
            return req.tree_idx
        feasible = self._feasible_templates(req)
        if self.ctrl is None:
            return 0 if 0 in feasible else feasible[0]
        return self.ctrl.select(feasible=feasible)

    def _try_admit(self, rep: _Replica, slot: int, req: Request) -> bool:
        """Admit ``req`` into replica ``rep``'s ``slot`` if its resources
        exist right now: KV blocks (paged; after prefix matching against
        THIS replica's pool) and a prefill lane on this replica.
        Returns False without side effects when they don't."""
        p = len(req.prompt)
        tmpl = self._pick_template(req)
        slack = self.dec.row_slack(tmpl) if self.bank is not None \
            else self.dec.window_slack
        need = p + req.max_new + slack

        keys: List[bytes] = []
        hit: List[int] = []
        if self.paged:
            if self.prefix_cache:
                keys = kv_pool.prefix_block_keys(
                    req.prompt, rep.alloc.block_size,
                    kv_dtype=rep.ex.kv_dtype)
                hit = rep.alloc.match_prefix(keys)
            nb = rep.alloc.blocks_needed(need)
            if not rep.alloc.can_allocate(nb - len(hit), hit) \
                    and self.bank is not None and req.tree_idx is None:
                # the controller's pick outgrows the pool: serve the
                # request on the narrowest feasible template instead of
                # head-of-line blocking (reshaping can widen it later as
                # completions free blocks); pinned requests keep their
                # shape and wait
                tmpl = min(self._feasible_templates(req),
                           key=self.dec.row_slack)
                need = p + req.max_new + self.dec.row_slack(tmpl)
                nb = rep.alloc.blocks_needed(need)
            if not rep.alloc.can_allocate(nb - len(hit), hit):
                return False                       # memory backpressure
        pf_start = len(hit) * (rep.alloc.block_size if self.paged else 0)
        if pf_start < p - 1 and self.prefill_lanes is not None \
                and rep.prefilling_count() >= self.prefill_lanes:
            return False                           # prefill budget exhausted

        now = time.perf_counter()
        if self.paged:
            if self.prefix_cache:
                rep.alloc.allocate(slot, need, prefix=hit, keys=keys)
            else:
                # plain positional call — tests spy on allocate(slot, n)
                rep.alloc.allocate(slot, need)
            self.stats["prefix_lookup_blocks"] += len(keys)
            self.stats["prefix_hit_blocks"] += len(hit)
            # defensive COW (kv_pool I2): with block-aligned matching the
            # first writable position always lands past the shared prefix,
            # but if a future matching policy maps the boundary block this
            # is what keeps shared KV immutable
            first_write_block = min(pf_start, p - 1) // rep.alloc.block_size
            for i in sorted(rep.alloc.read_only.get(slot, ())):
                if i >= first_write_block:
                    pair = rep.alloc.copy_on_write(slot, i)
                    if pair is not None:
                        rep.ex.copy_block(*pair)
        t = self.temperature if req.temperature is None else req.temperature
        rep.ex.admit_row(slot, req.prompt, float(t), req.rid, int(tmpl),
                         pf_start, seed=req.seed)
        # admission fully reinitializes the row (the eager admit_row writes
        # enqueue AFTER any in-flight step, so its trailing writes to this
        # slot land first), making a still-staged retire of the previous
        # occupant a stale no-op — it MUST be cancelled or the next
        # dispatch would kill the fresh request
        rep.pending_retire[slot] = False
        rep.slots[slot] = req
        rep._occ_cache = None
        rep.slot_limit[slot] = p + req.max_new
        rep.slot_tree[slot] = tmpl
        rep.slot_steps[slot] = 0
        rep.slot_pf[slot] = pf_start
        rep.slot_pf_len[slot] = p - 1
        rep.slot_submit_t[slot] = self._submit_t_of.pop(req.rid, now)
        rep.slot_admit_t[slot] = now
        rep.slot_first_t[slot] = np.nan
        rep.slot_last_t[slot] = now
        rep.slot_last_n[slot] = p
        rep.slot_samples[slot] = []
        if self.ctrl is not None:
            self.ctrl.seed_slot(rep.base + slot)
        return True

    def _route_order(self, req: Request):
        """Replica visit order for admitting ``req`` — prefix-affinity
        first, then least-loaded (DESIGN.md §12). The replica holding the
        LONGEST computed cached prefix of the prompt goes first (it serves
        the hit copy-free from its own pool); the rest follow by occupancy
        (fewest occupied slots, ties to the lowest id). A preferred
        replica that is full or out of blocks is simply skipped — the
        request falls through to the next candidate instead of stalling.
        Returns ``(replica, hit_blocks)`` pairs."""
        reps = sorted(self.replicas, key=lambda r: (r.occupancy(), r.rep))
        if self.dp == 1 or not (self.paged and self.prefix_cache):
            return [(r, 0) for r in reps]
        keys = kv_pool.prefix_block_keys(
            req.prompt, self.replicas[0].alloc.block_size,
            kv_dtype=self.replicas[0].ex.kv_dtype)
        if not keys:
            return [(r, 0) for r in reps]
        if self.prefix_index is not None:
            hits = {r: len(m)
                    for r, m in self.prefix_index.match(keys).items()}
        else:
            hits = {r.rep: len(r.alloc.match_prefix(keys)) for r in reps}
        order = sorted(reps, key=lambda r: (-hits.get(r.rep, 0),
                                            r.occupancy(), r.rep))
        return [(r, hits.get(r.rep, 0)) for r in order]

    def admit(self) -> int:
        """Fill free slots from a bounded prefix of the queue (FIFO-fair
        skip-ahead): position 0 is always tried first, and a later request
        (within ``admit_window``) may only overtake when every earlier one
        cannot currently fit — so smaller requests flow around a
        pool-oversized head instead of starving behind it, while nothing
        beyond the window ever jumps the line. Each admission is routed
        across replicas by ``_route_order`` (prefix-affinity, then
        least-loaded; with ``dp=1`` the order is trivially [replica 0] and
        this is the historical single-engine admission loop)."""
        admitted = 0
        progress = True
        while progress and self.queue:
            progress = False
            window = min(len(self.queue), self.admit_window)
            for qi in range(window):
                req = self.queue[qi]
                for rep, hit_len in self._route_order(req):
                    slot = rep.first_free_slot()
                    if slot is None:
                        continue       # replica full: fall through
                    if self._try_admit(rep, slot, req):
                        del self.queue[qi]
                        admitted += 1
                        if hit_len > 0:
                            self.stats["affinity_routed"] += 1
                        progress = True
                        break
                if progress:
                    break              # re-scan from the queue head
        return admitted

    # ----------------------------------------------------------- stepping
    def dispatch(self, replica: int = 0) -> StepHandle:
        """Issue one fused step on ``replica``, non-blocking. The staged
        mutations from every ``process`` since that replica's last
        dispatch (retirements, template re-selections — already mirrored
        in its ``slot_tree``) are applied on device AHEAD of the inner
        step; per-slot commit limits ride along so a row that filled its
        budget in a still-unharvested step freezes itself. All
        dispatch-deterministic accounting advances immediately: the step
        counters, and the prefill cursor mirrors + computed-block flags
        (the chunk schedule is a pure function of the cursor, so admission
        decisions made while this step is in flight see exact cursors)."""
        rep = self.replicas[replica]
        occ = rep.occupied_mask()
        limits = np.where(occ, rep.slot_limit, NO_LIMIT).astype(np.int64)
        tree_sel = (rep.slot_tree.astype(np.int32, copy=True)
                    if self.bank is not None else None)
        now = time.perf_counter()
        if self._harvest_done_t is not None:
            self.host_overhead_ms.append((now - self._harvest_done_t) * 1e3)
            self._harvest_done_t = None
        # greedy-specialization hint: retired slots' device temps are
        # zeroed by THIS dispatch's staged mutations before the inner step
        # runs, so occupied host mirrors are exactly the rows whose temp
        # survives — when none samples, the executor picks the compiled
        # variant with the sampled machinery removed (token-identical)
        any_sampled = any(
            s is not None
            and (self.temperature if s.temperature is None
                 else s.temperature) > 0
            for s in rep.slots)
        handle = rep.ex.dispatch(
            retire=rep.pending_retire, tree_sel=tree_sel, limits=limits,
            any_prefilling=rep.prefilling_count() > 0,
            any_sampled=any_sampled)
        handle.rids = np.asarray(
            [-1 if s is None else s.rid for s in rep.slots], np.int64)
        handle.replica = replica
        rep.pending_retire = np.zeros(self.max_batch, bool)

        self.stats["steps"] += 1
        self.stats["replica_steps"][replica] += 1
        self.stats["target_forwards"] += 1
        self.stats["draft_forwards"] += handle.n_draft
        # advance the host prefill mirrors in lockstep with the device
        for slot in np.nonzero(occ)[0]:
            pf, pfl = rep.slot_pf[slot], rep.slot_pf_len[slot]
            if pf < pfl:
                cl = int(min(self.chunk, pfl - pf))
                rep.slot_pf[slot] = pf + cl
                self.stats["prefill_chunks"] += 1
                self.stats["prefill_tokens"] += cl
                if self.paged and self.prefix_cache:
                    # the blocks become readable once THIS step completes
                    # on device — before any later-dispatched step could
                    # read them through a prefix match (sequential stream)
                    rep.alloc.mark_computed(slot, int(rep.slot_pf[slot]))
        return handle

    def process(self, handle: StepHandle) -> None:
        """Harvest one in-flight step (ONE batched device transfer) and
        fold its results in: stats + controller from the device-reported
        live mask, then completions, with retirement staged for the next
        dispatch boundary."""
        rep = self.replicas[handle.replica]
        res = rep.ex.harvest(handle)
        self._harvest_done_t = time.perf_counter()
        self._note_results(rep, handle, res)
        self._harvest_completions(rep, handle, res)

    def _note_results(self, rep: _Replica, handle: StepHandle,
                      res: StepResult) -> None:
        """Result-dependent accounting. ``res.live`` is the mask of rows
        the step actually committed for, computed ON DEVICE from the
        post-mutation pre-step state — the host mirrors cannot stand in
        for it here, because by harvest time they already reflect
        decisions staged for the NEXT step (and a request completed at the
        previous harvest may legitimately run one final in-flight step)."""
        live = res.live
        n_live = int(live.sum())
        if res.a is not None:
            self.stats["accepted"] += int(res.a.sum())
            self.stats["live_steps"] += n_live
            self.stats["committed"] += int(res.a.sum()) + n_live
            if res.rhist is not None:
                self.stats["round_hist"] = (
                    res.rhist if self.stats["round_hist"] is None
                    else self.stats["round_hist"] + res.rhist)
        else:                                        # mode="ar"
            self.stats["committed"] += n_live
        if self.bank is not None:
            # attribute to the templates the step was DISPATCHED with —
            # slot_tree may hold re-selections staged after that
            np.add.at(self.stats["tree_hist"], handle.tree_sel[live], 1)
        # per-SLOT accounting (step cadence, controller EWMAs) only where
        # the slot still holds the request this step was dispatched for —
        # a re-admitted slot must not inherit the previous occupant's final
        # in-flight step
        cur = np.asarray([-1 if s is None else s.rid for s in rep.slots],
                         np.int64)
        acct = live & (handle.rids == cur)
        rep.slot_steps[acct] += 1
        if self.ctrl is not None and acct.any():
            # controller rows are indexed by GLOBAL slot (replica base +
            # local slot): pad the per-replica step arrays out to the
            # controller's row space (with dp=1 this is the identity)
            g = self.ctrl.slot_p.shape[0]
            b = self.max_batch
            acct_g = np.zeros(g, bool)
            acct_g[rep.base:rep.base + b] = acct
            tree_g = np.zeros(g, np.int32)
            tree_g[rep.base:rep.base + b] = handle.tree_sel
            a_g = np.zeros(g, res.a.dtype)
            a_g[rep.base:rep.base + b] = res.a
            rank_g = np.full((g,) + res.rank.shape[1:], -1, res.rank.dtype)
            rank_g[rep.base:rep.base + b] = res.rank
            self.ctrl.update(acct_g, tree_g, a_g, rank_g)
            self._reshape_slots(rep, acct)

    def _reshape_slots(self, rep: _Replica, live_mask) -> None:
        """Between-windows template re-selection (the adaptive controller).
        Every ``tree_reselect_every`` live steps a slot re-scores the bank
        under its own EWMA statistics and switches when a different
        template wins AND the slot can hold it: within max_len, and — paged
        — growable in place (``BlockAllocator.grow``; when the pool is too
        tight the slot just keeps its current shape). Greedy losslessness
        is shape-independent, so reshaping mid-request never changes
        committed tokens' correctness, only how many arrive per step."""
        for slot in np.nonzero(live_mask)[0]:
            req = rep.slots[slot]
            if req is None or req.tree_idx is not None:
                continue            # pinned requests keep their shape
            if rep.slot_steps[slot] % self.tree_reselect_every:
                continue
            best = self.ctrl.select(slot=int(rep.base + slot),
                                    feasible=self._feasible_templates(req))
            if best == int(rep.slot_tree[slot]):
                continue
            need = len(req.prompt) + req.max_new + self.dec.row_slack(best)
            if self.paged and not rep.alloc.grow(int(slot), need):
                continue            # pool too tight: keep the old shape
            # STAGED: the mirror update is picked up by the next dispatch's
            # tree_sel (no eager device scatter); growing the block table
            # above only ever widens a row, so a still-in-flight step using
            # the old table + old template stays within its allocation
            rep.slot_tree[slot] = best
            self.stats["tree_switches"] += 1

    # ------------------------------------------------------------ harvest
    def _harvest_completions(self, rep: _Replica, handle: StepHandle,
                             res: StepResult) -> None:
        """Detect finished requests from one harvested step's ``n``/``gen``
        (already on host — no extra transfers) and build their
        Completions. Retirement is STAGED (``pending_retire``), applied at
        the next dispatch boundary; the completion's tokens come from THIS
        step's snapshot, so anything a later in-flight step speculates for
        the slot never reaches the output. Block release is immediate and
        safe under the pipeline: an in-flight step's trailing writes for a
        released row land at positions >= its prompt length — never inside
        a prefix-cache-registered (prompt-covered) block — and complete on
        the sequential device stream before any step dispatched after the
        release could read the reused blocks."""
        n_host, gen_host = res.n, res.gen
        now = time.perf_counter()
        for slot, req in enumerate(rep.slots):
            if req is None:
                continue
            if int(handle.rids[slot]) != req.rid:
                # the slot was re-admitted while this step was in flight:
                # the snapshot belongs to the PREVIOUS occupant (already
                # completed) — attributing its n/gen to the new request
                # would instantly "finish" it with someone else's tokens
                continue
            p = len(req.prompt)
            # latency: tokens committed since the last tick
            c = int(n_host[slot] - rep.slot_last_n[slot])
            if c > 0:
                if np.isnan(rep.slot_first_t[slot]):
                    rep.slot_first_t[slot] = now
                rep.slot_samples[slot].append(
                    ((now - rep.slot_last_t[slot]) / c, c))
                rep.slot_last_t[slot] = now
                rep.slot_last_n[slot] = n_host[slot]

            limit = rep.slot_limit[slot]
            end, hit_eos = None, False
            if self.eos_id is not None and n_host[slot] > p:
                row = gen_host[slot, p:n_host[slot]].tolist()
                if self.eos_id in row:
                    # truncate AT the EOS: tokens speculatively committed
                    # after it in the same window are dropped from the
                    # completion (the old engine kept them — ISSUE 5)
                    end = min(p + row.index(self.eos_id) + 1, int(limit))
                    hit_eos = True
            if n_host[slot] >= limit or hit_eos:
                if end is None:
                    end = int(min(n_host[slot], limit))
                samples = rep.slot_samples[slot]
                ttft = (rep.slot_first_t[slot] - rep.slot_submit_t[slot]
                        if not np.isnan(rep.slot_first_t[slot]) else 0.0)
                self.completions.append(Completion(
                    rid=req.rid, tokens=gen_host[slot, :end].copy(),
                    generated=int(end - p),
                    wall_submitted=rep.slot_submit_t[slot],
                    wall_done=now,
                    queue_wait=rep.slot_admit_t[slot]
                    - rep.slot_submit_t[slot],
                    ttft=float(ttft),
                    tok_p50=_weighted_percentile(samples, 50),
                    tok_p95=_weighted_percentile(samples, 95)))
                rep.slots[slot] = None
                rep._occ_cache = None
                rep.slot_pf_len[slot] = 0
                rep.slot_pf[slot] = 0
                rep.pending_retire[slot] = True
                if self.ctrl is not None:
                    self.ctrl.retire_slot(rep.base + slot)
                if self.paged:
                    rep.alloc.release(slot)  # O(1); blocks reusable at once

    # ------------------------------------------------------------ summary
    def mean_accepted(self) -> float:
        """Mean committed tokens per live row per verify step (a + 1) —
        the tree/flat drafting quality metric gated in CI."""
        if not self.stats["live_steps"]:
            return 0.0
        return 1.0 + self.stats["accepted"] / self.stats["live_steps"]

    def prefix_hit_rate(self) -> float:
        """Fraction of looked-up prompt blocks served from the prefix
        cache (0.0 when caching is off or nothing was looked up)."""
        lookups = self.stats["prefix_lookup_blocks"]
        return self.stats["prefix_hit_blocks"] / lookups if lookups else 0.0

    def latency_summary(self) -> Dict[str, float]:
        """Percentiles over harvested completions, in milliseconds, plus
        the per-step host overhead (harvest-complete -> next dispatch) —
        the serial host time the pipeline exists to hide."""
        comps = self.completions

        def pct(vals, q):
            return float(np.percentile(vals, q)) * 1e3 if vals else 0.0

        ttfts = [c.ttft for c in comps]
        waits = [c.queue_wait for c in comps]
        ovh = self.host_overhead_ms
        return dict(
            requests=len(comps),
            queue_wait_p50_ms=pct(waits, 50),
            ttft_p50_ms=pct(ttfts, 50),
            ttft_p95_ms=pct(ttfts, 95),
            tok_p50_ms=_weighted_percentile(
                [(c.tok_p50, max(1, c.generated)) for c in comps], 50) * 1e3,
            tok_p95_ms=_weighted_percentile(
                [(c.tok_p95, max(1, c.generated)) for c in comps], 95) * 1e3,
            host_overhead_p50_ms=(float(np.percentile(ovh, 50))
                                  if ovh else 0.0),
            host_overhead_p95_ms=(float(np.percentile(ovh, 95))
                                  if ovh else 0.0),
        )
