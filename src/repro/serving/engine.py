"""Batched serving engine with continuous batching — the Transformers+/vLLM
analogue of the paper's evaluation stack.

Design (all fixed shapes, jit-once):
  * ONE ``DecodeState`` (core.spec_decode) holds the generation buffer,
    per-slot (n, m, done) counters, block tables and the target + draft
    cache handles; the decode steps are the exact jitted step functions
    ``SpecDecoder`` uses for uniform-batch generation — no duplicated
    AR/prefill machinery;
  * KV layout is either "paged" (default; serving/kv_pool.py — fixed-size
    blocks, per-slot block tables, free-list allocation, copy-free
    admission, O(1) release) or "contiguous" (one full-length row per slot,
    admission scatters the prefilled row into the pool);
  * admission: a free slot gets a PREFILL — the request's caches are
    computed in a [1, P_bucket] forward (prompt lengths bucketed to powers
    of two to bound recompilation). Paged: the forward writes straight into
    the slot's allocated blocks through its block-table row. When the pool
    has no free blocks, requests wait in the queue (memory backpressure)
    and admit as completions release blocks;
  * decode: ONE jitted speculative step advances all active slots together;
    finished slots free immediately and new requests admit on the next tick
    (continuous batching);
  * modes: "ar" (AR+ baseline), "vsd", "pard" — same engine, same pool;
    passing ``tree=`` (a core.spec_decode.TreeTemplate or a branching list)
    upgrades "pard" to tree-structured drafting with ancestor-mask
    verification (DESIGN.md §6) — allocation slack and the decode step come
    from the same SpecDecoder, so paged KV invariants are unchanged;
  * sampling is per REQUEST: ``submit(..., temperature=)`` overrides the
    engine default, so one batch mixes greedy (exact argmax) and sampled
    rows — every mode including tree drafting, whose multi-round sibling
    acceptance (core/acceptance.py) preserves the target distribution
    exactly. Each request draws from its own (seed, rid) PRNG key, so
    sampled output is deterministic per request across batch compositions
    and KV layouts.

SSM/hybrid targets work unchanged: the spec step's collect_ssm rollback is
per-row, SSM states stay batch-indexed in both KV layouts, and prefill
produces the row's (conv, ssm) state like any cache (DESIGN.md §3/§5).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import acceptance
from ..core.spec_decode import DecodeState, SpecDecoder, prefill_row
from ..models import init_caches
from ..models.config import ModelConfig
from . import kv_pool


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # 1-D int32
    max_new: int
    temperature: Optional[float] = None   # None = the engine default


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: np.ndarray          # prompt + generated
    generated: int
    wall_submitted: float
    wall_done: float


def _bucket(n: int) -> int:
    b = 8
    while b < n:
        b *= 2
    return b


class Engine:
    def __init__(self, target_params, target_cfg: ModelConfig,
                 draft_params=None, draft_cfg: Optional[ModelConfig] = None, *,
                 mode: str = "pard", k: int = 8, max_batch: int = 4,
                 max_len: int = 1024, temperature: float = 0.0,
                 eos_id: Optional[int] = None, seed: int = 0,
                 kv_layout: str = "paged", kv_block_size: int = 64,
                 kv_num_blocks: Optional[int] = None, tree=None):
        assert mode in ("ar", "vsd", "pard")
        assert kv_layout in ("paged", "contiguous")
        assert tree is None or mode == "pard", \
            "tree templates apply to the PARD draft path only"
        self.mode = mode
        self.paged = kv_layout == "paged"
        self.k = k if mode != "ar" else 1
        if mode == "ar":
            # the AR baseline never reads draft caches: drop the draft model
            # so admission skips its prefill and KV accounting excludes it
            draft_params = draft_cfg = None
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.temperature = temperature   # default for submit(temperature=None)
        self.dec = SpecDecoder(
            target_params, target_cfg, draft_params, draft_cfg, k=self.k,
            max_len=max_len, temperature=temperature,
            kv_block_size=kv_block_size if self.paged else 0,
            tree=tree if mode == "pard" else None)
        self.k = self.dec.k          # a tree template overrides k (== depth)
        self.tc, self.dc = target_cfg, draft_cfg
        # per-request sampling keys derive from (seed, rid) at admission, so
        # a request's sampled trajectory is independent of batch composition
        # and KV layout (seeded determinism)
        self._rng_base = jax.random.PRNGKey(seed)

        # cache pools + unified decode state
        if self.paged:
            nb = kv_num_blocks or kv_pool.default_num_blocks(
                max_batch, max_len, kv_block_size)
            self.alloc = kv_pool.BlockAllocator(nb, kv_block_size, max_batch,
                                                max_len)
            tcache = kv_pool.init_paged_caches(target_cfg, max_batch, nb,
                                               kv_block_size)
            dcache = (kv_pool.init_paged_caches(draft_cfg, max_batch, nb,
                                                kv_block_size)
                      if draft_cfg is not None else None)
            tables = jnp.asarray(self.alloc.tables)
            self._kv_per_block = (
                kv_pool.kv_bytes_per_block(target_cfg, tcache, nb)
                + (kv_pool.kv_bytes_per_block(draft_cfg, dcache, nb)
                   if dcache is not None else 0))
        else:
            self.alloc = None
            tcache = init_caches(target_cfg, max_batch, max_len)
            dcache = (init_caches(draft_cfg, max_batch, max_len)
                      if draft_cfg is not None else None)
            tables = None
            self._kv_per_block = 0
        self._kv_capacity = (
            kv_pool.kv_capacity_bytes(target_cfg, tcache)
            + (kv_pool.kv_capacity_bytes(draft_cfg, dcache)
               if dcache is not None else 0))
        # contiguous rows are committed whole-pool up front, so their peak
        # IS the capacity — consumers read this field for either layout
        self.peak_kv_bytes_in_use = 0 if self.paged else self._kv_capacity

        self.state = DecodeState(
            gen=jnp.zeros((max_batch, max_len), jnp.int32),
            n=jnp.ones((max_batch,), jnp.int32) * 2,   # dummy-safe
            m=jnp.ones((max_batch,), jnp.int32),
            done=jnp.ones((max_batch,), bool),         # empty slots = done
            tcache=tcache, dcache=dcache, tables=tables,
            temp=jnp.zeros((max_batch,), jnp.float32),
            rngs=acceptance.make_row_keys(seed, np.arange(max_batch)))
        self._tables_version = self.alloc.version if self.paged else 0

        # host state
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.slot_limit = np.zeros(max_batch, np.int64)
        self.slot_submit_t = np.zeros(max_batch)
        self.queue: deque[Request] = deque()
        self.completions: List[Completion] = []
        self._next_rid = 0
        self._spec_step = None
        self._ar_step = None
        self._prefill_cache: Dict[Any, Any] = {}
        self.stats = dict(steps=0, committed=0, accepted=0, live_steps=0,
                          draft_forwards=0, target_forwards=0,
                          round_hist=None)

    # ------------------------------------------------------------- public
    def submit(self, prompt, max_new: int,
               temperature: Optional[float] = None) -> int:
        """Queue a request. ``temperature`` overrides the engine default for
        this request only (0 = greedy) — one batch mixes greedy and sampled
        rows, each sampling under its own (seed, rid)-derived key."""
        prompt = np.asarray(prompt, np.int32)
        need = len(prompt) + max_new + self.dec.window_slack
        if len(prompt) < 2 or need > self.max_len:
            # a raised error, not an assert: past this point an oversized
            # request would outgrow its cache rows/blocks and silently
            # attend garbage
            raise ValueError(
                f"request needs {need} cache positions (prompt="
                f"{len(prompt)}, max_new={max_new}, window slack="
                f"{self.dec.window_slack}) but max_len={self.max_len}; "
                f"prompts also need >= 2 tokens")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, prompt, max_new, temperature))
        return rid

    def run(self, max_steps: int = 100000) -> List[Completion]:
        while (self.queue or any(s is not None for s in self.slots)) \
                and self.stats["steps"] < max_steps:
            self._admit()
            if self.queue and all(s is None for s in self.slots):
                # every slot (hence every block) is free and the head of the
                # queue STILL could not admit: it can never fit — fail loudly
                # instead of spinning on backpressure forever
                req = self.queue[0]
                raise RuntimeError(
                    f"request {req.rid} (prompt={len(req.prompt)}, "
                    f"max_new={req.max_new}) needs more KV blocks than the "
                    f"pool holds; raise kv_num_blocks or max_len")
            self._step()
            self._harvest()
        return self.completions

    def kv_capacity_bytes(self) -> int:
        """HBM resident for the attention KV cache (target + draft)."""
        return self._kv_capacity

    def kv_bytes_in_use(self) -> int:
        """KV bytes backing live requests. Contiguous rows are committed
        whole-pool up front; paged usage scales with actual allocation."""
        if not self.paged:
            return self._kv_capacity
        return self.alloc.blocks_in_use * self._kv_per_block

    # ------------------------------------------------------------ internals
    def _sync_tables(self):
        """Push the host block tables to the device state when stale. This
        runs before any forward that could consume them, so released rows'
        stale writes always route to the garbage block (kv_pool I4)."""
        if self.paged and self._tables_version != self.alloc.version:
            self.state = dataclasses.replace(
                self.state, tables=jnp.asarray(self.alloc.tables))
            self._tables_version = self.alloc.version

    def _prefill_fns(self, p_bucket: int):
        key = p_bucket
        if key in self._prefill_cache:
            return self._prefill_cache[key]
        paged = self.paged
        bs = self.dec.kv_block_size

        def one(params, cfg, slot, toks, plen, pool, tables):
            if paged:
                row_t = jax.lax.dynamic_index_in_dim(tables, slot, 0,
                                                     keepdims=True)
                cin = kv_pool.prefill_cache_view(cfg, pool, True)
            else:
                row_t = None
                cin = init_caches(cfg, 1, self.max_len)
            row = prefill_row(params, cfg, toks, plen, cin, tables=row_t,
                              block_size=bs)
            return kv_pool.scatter_row_caches(cfg, pool, row, slot, paged)

        def prefill(tp, dp, slot, toks, plen, tcache, dcache, tables):
            # single-row prefill; tokens right-padded to the bucket. Padded
            # tail KV lands at positions >= plen — never valid (kv_len
            # bookkeeping) — and SSM state is rolled back (DESIGN.md §3).
            tcache = one(tp, self.tc, slot, toks, plen, tcache, tables)
            if self.dc is not None:
                dcache = one(dp, self.dc, slot, toks, plen, dcache, tables)
            return tcache, dcache

        fn = jax.jit(prefill, donate_argnums=(5, 6))
        self._prefill_cache[key] = fn
        return fn

    def _admit(self):
        # phase 1 (host): claim slots and, in paged mode, KV blocks. When
        # the pool is exhausted the queue waits — completions release blocks
        pending = []
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue[0]
            p = len(req.prompt)
            # validated at submit(); covers draft + verify windows (I3)
            need = p + req.max_new + self.dec.window_slack
            if self.paged:
                nb = self.alloc.blocks_needed(need)
                if not self.alloc.can_allocate(nb):
                    break                      # memory backpressure
                self.alloc.allocate(slot, need)
            self.queue.popleft()
            self.slots[slot] = req
            self.slot_limit[slot] = p + req.max_new
            self.slot_submit_t[slot] = time.perf_counter()
            pending.append((slot, req))
        if not pending:
            return
        self._sync_tables()
        if self.paged:
            self.peak_kv_bytes_in_use = max(self.peak_kv_bytes_in_use,
                                            self.kv_bytes_in_use())

        # phase 2 (device): per-request prefill — paged admission writes
        # directly into the slot's blocks (no full-pool row scatter)
        for slot, req in pending:
            p = len(req.prompt)
            bucket = _bucket(p - 1)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :p - 1] = req.prompt[:-1]
            fn = self._prefill_fns(bucket)
            st = self.state
            tcache, dcache = fn(self.dec.tp, self.dec.dp, slot,
                                jnp.asarray(toks), p - 1, st.tcache,
                                st.dcache, st.tables)
            gen_row = np.zeros((self.max_len,), np.int32)
            gen_row[:p] = req.prompt
            t = self.temperature if req.temperature is None \
                else req.temperature
            self.state = dataclasses.replace(
                st,
                gen=st.gen.at[slot].set(jnp.asarray(gen_row)),
                n=st.n.at[slot].set(p),
                m=st.m.at[slot].set(p - 1),
                done=st.done.at[slot].set(False),
                temp=st.temp.at[slot].set(float(t)),
                rngs=st.rngs.at[slot].set(
                    jax.random.fold_in(self._rng_base, req.rid)),
                tcache=tcache, dcache=dcache)

    def _step(self):
        if bool(jnp.all(self.state.done)):
            return
        self._sync_tables()
        if self.mode == "ar":
            self._step_ar()
        else:
            self._step_spec()
        self.stats["steps"] += 1

    def _step_spec(self):
        if self._spec_step is None:
            if self.dec.tree is not None:
                builder = self.dec._build_tree_step()
            else:
                builder = self.dec._build_spec_step(
                    "pard" if self.mode == "pard" else "vsd")
            self._spec_step = jax.jit(builder, donate_argnums=(0,))
        live = int(jnp.sum(~self.state.done))
        self.state, a, hist, rhist, n_draft = self._spec_step(self.state)
        self.stats["draft_forwards"] += int(n_draft)
        self.stats["target_forwards"] += 1
        self.stats["accepted"] += int(jnp.sum(a))
        self.stats["live_steps"] += live
        rh = np.asarray(jax.device_get(rhist))
        self.stats["round_hist"] = rh if self.stats["round_hist"] is None \
            else self.stats["round_hist"] + rh
        self.stats["committed"] += int(jnp.sum(a) +
                                       jnp.sum(~self.state.done))

    def mean_accepted(self) -> float:
        """Mean committed tokens per live row per verify step (a + 1) —
        the tree/flat drafting quality metric gated in CI."""
        if not self.stats["live_steps"]:
            return 0.0
        return 1.0 + self.stats["accepted"] / self.stats["live_steps"]

    def _step_ar(self):
        if self._ar_step is None:
            self._ar_step = jax.jit(self.dec._build_ar_step(),
                                    donate_argnums=(0,))
        self.state = self._ar_step(self.state)
        self.stats["target_forwards"] += 1
        self.stats["committed"] += int(jnp.sum(~self.state.done))

    def _harvest(self):
        n_host = np.asarray(jax.device_get(self.state.n))
        gen_host = None
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            limit = self.slot_limit[slot]
            hit_eos = False
            if self.eos_id is not None:
                if gen_host is None:
                    gen_host = np.asarray(jax.device_get(self.state.gen))
                row = gen_host[slot, len(req.prompt):n_host[slot]]
                hit_eos = self.eos_id in row.tolist()
            if n_host[slot] >= limit or hit_eos:
                if gen_host is None:
                    gen_host = np.asarray(jax.device_get(self.state.gen))
                end = min(n_host[slot], limit)
                toks = gen_host[slot, :end].copy()
                self.completions.append(Completion(
                    rid=req.rid, tokens=toks,
                    generated=int(end - len(req.prompt)),
                    wall_submitted=self.slot_submit_t[slot],
                    wall_done=time.perf_counter()))
                self.slots[slot] = None
                # temp resets with the slot: a retired sampled request must
                # not keep forcing later all-greedy batches onto the
                # sampled lax.cond branch (jnp.any(temp > 0))
                self.state = dataclasses.replace(
                    self.state, done=self.state.done.at[slot].set(True),
                    temp=self.state.temp.at[slot].set(0.0))
                if self.paged:
                    self.alloc.release(slot)   # O(1); blocks reusable at once
