"""Batched serving engine with continuous batching — the Transformers+/vLLM
analogue of the paper's evaluation stack.

Design (all fixed shapes, jit-once):
  * ONE ``DecodeState`` (core.spec_decode) holds the generation buffer,
    per-slot (n, m, done) counters, block tables and the target + draft
    cache handles; the decode steps are the exact jitted step functions
    ``SpecDecoder`` uses for uniform-batch generation — no duplicated
    AR/prefill machinery;
  * KV layout is either "paged" (default; serving/kv_pool.py — fixed-size
    blocks, per-slot block tables, free-list allocation, copy-free
    admission, O(1) release) or "contiguous" (one full-length row per slot,
    admission scatters the prefilled row into the pool);
  * admission: a free slot gets a PREFILL — the request's caches are
    computed in a [1, P_bucket] forward (prompt lengths bucketed to powers
    of two to bound recompilation). Paged: the forward writes straight into
    the slot's allocated blocks through its block-table row. When the pool
    has no free blocks, requests wait in the queue (memory backpressure)
    and admit as completions release blocks;
  * decode: ONE jitted speculative step advances all active slots together;
    finished slots free immediately and new requests admit on the next tick
    (continuous batching);
  * modes: "ar" (AR+ baseline), "vsd", "pard" — same engine, same pool;
    passing ``tree=`` (a core.spec_decode.TreeTemplate, a branching list,
    or a TemplateBank) upgrades "pard" to tree-structured drafting with
    ancestor-mask verification (DESIGN.md §6) — allocation slack and the
    decode step come from the same SpecDecoder, so paged KV invariants
    are unchanged. With a TemplateBank the tree shape is PER REQUEST
    (``submit(..., tree_idx=)`` pins one; paged rows allocate blocks for
    their own template's window, not the bank-wide widest), and
    ``adaptive_tree=True`` adds the EWMA acceptance-statistics controller
    (``TreeController``) that selects each request's template at admission
    and reshapes it between windows (DESIGN.md §7);
  * sampling is per REQUEST: ``submit(..., temperature=)`` overrides the
    engine default, so one batch mixes greedy (exact argmax) and sampled
    rows — every mode including tree drafting, whose multi-round sibling
    acceptance (core/acceptance.py) preserves the target distribution
    exactly. Each request draws from its own (seed, rid) PRNG key, so
    sampled output is deterministic per request across batch compositions
    and KV layouts.

SSM/hybrid targets work unchanged: the spec step's collect_ssm rollback is
per-row, SSM states stay batch-indexed in both KV layouts, and prefill
produces the row's (conv, ssm) state like any cache (DESIGN.md §3/§5).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import acceptance
from ..core.spec_decode import (DecodeState, SpecDecoder, TemplateBank,
                                prefill_row)
from ..models import init_caches
from ..models.config import ModelConfig
from . import kv_pool


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # 1-D int32
    max_new: int
    temperature: Optional[float] = None   # None = the engine default
    tree_idx: Optional[int] = None        # pinned bank template (None =
    #                                       controller / template 0)


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: np.ndarray          # prompt + generated
    generated: int
    wall_submitted: float
    wall_done: float


def _bucket(n: int) -> int:
    b = 8
    while b < n:
        b *= 2
    return b


class TreeController:
    """Acceptance-statistics template selection (DESIGN.md §7).

    Maintains, per slot and per (depth d, sibling rank c), an EWMA of the
    indicator "depth d was evaluated this step and rank c's candidate was
    the accepted one" — updated ONLY at steps where rank c was actually
    OFFERED (c < the in-use template's branching at d), so the estimate is
    the conditional accept probability P(rank c wins | depth d reached,
    rank c offered) regardless of which template happened to be active.
    A template's score is its expected accepted length under independence
    across ranks: E(t) = sum_d prod_{d' <= d} min(1, sum_{c < b_d'} p[d',c]).

    New requests have no history, so admission selects on a GLOBAL EWMA
    that every retiring request folds its learned row into; per-slot rows
    are seeded from the global one at admission and drive the between-
    windows re-selection (``Engine._reshape_slots``).
    """

    def __init__(self, bank: TemplateBank, max_batch: int, ewma: float = 0.2):
        self.bank = bank
        self.ewma = ewma
        d, mb = bank.max_depth, bank.max_branching
        self.offer = np.zeros((len(bank), d), np.int32)   # [T, D] branching
        for t, tpl in enumerate(bank.templates):
            self.offer[t] = tpl.branching
        # optimistic prior: rank 0 accepts half the time, each extra rank
        # adds a little — wide templates stay in play until data arrives
        prior = np.zeros((d, mb))
        prior[:, 0] = 0.5
        if mb > 1:
            prior[:, 1:] = 0.15
        self.global_p = prior.copy()
        self.slot_p = np.tile(prior, (max_batch, 1, 1))

    def seed_slot(self, slot: int) -> None:
        self.slot_p[slot] = self.global_p

    def retire_slot(self, slot: int) -> None:
        """Fold a finished request's learned statistics into the admission
        prior (an EWMA over requests, like the per-step one over windows)."""
        self.global_p += 0.5 * (self.slot_p[slot] - self.global_p)

    def update(self, live: np.ndarray, tree_idx: np.ndarray, a: np.ndarray,
               rank: np.ndarray) -> None:
        """live [B] (rows live BEFORE the step), tree_idx [B], a [B]
        accepted depths, rank [B, D] accepted sibling rank per depth (-1
        where the depth rejected or was never reached)."""
        d = self.slot_p.shape[1]
        for slot in np.nonzero(live)[0]:
            br = self.offer[tree_idx[slot]]
            # depths 1..a were accepted; depth a+1 was evaluated and
            # rejected (if it exists); deeper depths carry no information
            for dep in range(min(int(a[slot]) + 1, d)):
                r = int(rank[slot, dep])
                for c in range(int(br[dep])):
                    obs = 1.0 if r == c else 0.0
                    self.slot_p[slot, dep, c] += \
                        self.ewma * (obs - self.slot_p[slot, dep, c])

    def select(self, slot: Optional[int] = None,
               feasible=None) -> int:
        """Best-scoring template (per-slot stats, or the global prior for
        admission). ``feasible``: optional iterable of permitted template
        indices (allocation / max_len constraints)."""
        p = self.global_p if slot is None else self.slot_p[slot]
        cands = range(len(self.bank)) if feasible is None else list(feasible)
        best, best_e = next(iter(cands)), -1.0
        for t in cands:
            surv, e = 1.0, 0.0
            for dep in range(p.shape[0]):
                surv *= min(1.0, float(p[dep, :self.offer[t, dep]].sum()))
                e += surv
            if e > best_e + 1e-9:
                best, best_e = t, e
        return best


class Engine:
    def __init__(self, target_params, target_cfg: ModelConfig,
                 draft_params=None, draft_cfg: Optional[ModelConfig] = None, *,
                 mode: str = "pard", k: int = 8, max_batch: int = 4,
                 max_len: int = 1024, temperature: float = 0.0,
                 eos_id: Optional[int] = None, seed: int = 0,
                 kv_layout: str = "paged", kv_block_size: int = 64,
                 kv_num_blocks: Optional[int] = None, tree=None,
                 adaptive_tree: bool = False, tree_ewma: float = 0.2,
                 tree_reselect_every: int = 4):
        assert mode in ("ar", "vsd", "pard")
        assert kv_layout in ("paged", "contiguous")
        assert tree is None or mode == "pard", \
            "tree templates apply to the PARD draft path only"
        if adaptive_tree:
            assert mode == "pard", "adaptive trees require mode='pard'"
            if tree is None:
                tree = TemplateBank.default(k)
            assert isinstance(tree, TemplateBank), \
                "adaptive_tree selects from a TemplateBank"
        self.adaptive = adaptive_tree
        self.tree_reselect_every = tree_reselect_every
        self.mode = mode
        self.paged = kv_layout == "paged"
        self.k = k if mode != "ar" else 1
        if mode == "ar":
            # the AR baseline never reads draft caches: drop the draft model
            # so admission skips its prefill and KV accounting excludes it
            draft_params = draft_cfg = None
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.temperature = temperature   # default for submit(temperature=None)
        self.dec = SpecDecoder(
            target_params, target_cfg, draft_params, draft_cfg, k=self.k,
            max_len=max_len, temperature=temperature,
            kv_block_size=kv_block_size if self.paged else 0,
            tree=tree if mode == "pard" else None)
        self.k = self.dec.k          # a tree template overrides k (== depth)
        self.bank = self.dec.tree    # TemplateBank (or None: no tree)
        self.ctrl = (TreeController(self.bank, max_batch, tree_ewma)
                     if self.adaptive else None)
        self.tc, self.dc = target_cfg, draft_cfg
        # per-request sampling keys derive from (seed, rid) at admission, so
        # a request's sampled trajectory is independent of batch composition
        # and KV layout (seeded determinism)
        self._rng_base = jax.random.PRNGKey(seed)

        # cache pools + unified decode state
        if self.paged:
            nb = kv_num_blocks or kv_pool.default_num_blocks(
                max_batch, max_len, kv_block_size)
            self.alloc = kv_pool.BlockAllocator(nb, kv_block_size, max_batch,
                                                max_len)
            tcache = kv_pool.init_paged_caches(target_cfg, max_batch, nb,
                                               kv_block_size)
            dcache = (kv_pool.init_paged_caches(draft_cfg, max_batch, nb,
                                                kv_block_size)
                      if draft_cfg is not None else None)
            tables = jnp.asarray(self.alloc.tables)
            self._kv_per_block = (
                kv_pool.kv_bytes_per_block(target_cfg, tcache, nb)
                + (kv_pool.kv_bytes_per_block(draft_cfg, dcache, nb)
                   if dcache is not None else 0))
        else:
            self.alloc = None
            tcache = init_caches(target_cfg, max_batch, max_len)
            dcache = (init_caches(draft_cfg, max_batch, max_len)
                      if draft_cfg is not None else None)
            tables = None
            self._kv_per_block = 0
        self._kv_capacity = (
            kv_pool.kv_capacity_bytes(target_cfg, tcache)
            + (kv_pool.kv_capacity_bytes(draft_cfg, dcache)
               if dcache is not None else 0))
        # contiguous rows are committed whole-pool up front, so their peak
        # IS the capacity — consumers read this field for either layout
        self.peak_kv_bytes_in_use = 0 if self.paged else self._kv_capacity

        self.state = DecodeState(
            gen=jnp.zeros((max_batch, max_len), jnp.int32),
            n=jnp.ones((max_batch,), jnp.int32) * 2,   # dummy-safe
            m=jnp.ones((max_batch,), jnp.int32),
            done=jnp.ones((max_batch,), bool),         # empty slots = done
            tcache=tcache, dcache=dcache, tables=tables,
            temp=jnp.zeros((max_batch,), jnp.float32),
            rngs=acceptance.make_row_keys(seed, np.arange(max_batch)),
            tree_idx=(jnp.zeros((max_batch,), jnp.int32)
                      if self.bank is not None else None))
        self._tables_version = self.alloc.version if self.paged else 0

        # host state
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.slot_limit = np.zeros(max_batch, np.int64)
        self.slot_submit_t = np.zeros(max_batch)
        # host shadows of per-slot tree state: the active template index
        # and the step count since admission (re-selection cadence)
        self.slot_tree = np.zeros(max_batch, np.int32)
        self.slot_steps = np.zeros(max_batch, np.int64)
        self.queue: deque[Request] = deque()
        self.completions: List[Completion] = []
        self._next_rid = 0
        self._spec_step = None
        self._ar_step = None
        self._prefill_cache: Dict[Any, Any] = {}
        self.stats = dict(steps=0, committed=0, accepted=0, live_steps=0,
                          draft_forwards=0, target_forwards=0,
                          round_hist=None)
        if self.bank is not None:
            # live-steps decoded under each template + controller switches
            self.stats["tree_hist"] = np.zeros(len(self.bank), np.int64)
            self.stats["tree_switches"] = 0

    # ------------------------------------------------------------- public
    def submit(self, prompt, max_new: int,
               temperature: Optional[float] = None,
               tree_idx: Optional[int] = None) -> int:
        """Queue a request. ``temperature`` overrides the engine default for
        this request only (0 = greedy) — one batch mixes greedy and sampled
        rows, each sampling under its own (seed, rid)-derived key.
        ``tree_idx`` pins the request to one bank template (tree engines);
        left None, the adaptive controller (or template 0) decides at
        admission and may reshape the request between windows.

        In the paged layout the max_len feasibility check uses the
        request's own window slack: a pinned template's slack exactly,
        otherwise the smallest slack any bank template needs — admission
        and re-selection then only ever consider templates that actually
        fit, and rows allocate blocks for their OWN template rather than
        the bank-wide widest. Contiguous rows are written batch-wide (the
        widest window), so there the bank-wide slack is always required."""
        prompt = np.asarray(prompt, np.int32)
        if tree_idx is not None and (
                self.bank is None or not 0 <= tree_idx < len(self.bank)):
            raise ValueError(
                f"tree_idx={tree_idx} needs a TemplateBank with more "
                f"than {tree_idx} templates")
        if not self.paged or self.bank is None:
            # contiguous rows are written batch-wide (the widest window,
            # clamped dynamic_update_slice would corrupt committed KV past
            # max_len), so the bank-wide slack is the real requirement
            # whatever template the request pins
            slack = self.dec.window_slack
        elif tree_idx is not None:
            slack = self.dec.row_slack(tree_idx)
        else:
            slack = self.dec.min_row_slack
        need = len(prompt) + max_new + slack
        if len(prompt) < 2 or need > self.max_len:
            # a raised error, not an assert: past this point an oversized
            # request would outgrow its cache rows/blocks and silently
            # attend garbage
            raise ValueError(
                f"request needs {need} cache positions (prompt="
                f"{len(prompt)}, max_new={max_new}, window slack="
                f"{slack}) but max_len={self.max_len}; "
                f"prompts also need >= 2 tokens")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, prompt, max_new, temperature,
                                  tree_idx))
        return rid

    def run(self, max_steps: int = 100000) -> List[Completion]:
        while (self.queue or any(s is not None for s in self.slots)) \
                and self.stats["steps"] < max_steps:
            self._admit()
            if self.queue and all(s is None for s in self.slots):
                # every slot (hence every block) is free and the head of the
                # queue STILL could not admit: it can never fit — fail loudly
                # instead of spinning on backpressure forever
                req = self.queue[0]
                raise RuntimeError(
                    f"request {req.rid} (prompt={len(req.prompt)}, "
                    f"max_new={req.max_new}) needs more KV blocks than the "
                    f"pool holds; raise kv_num_blocks or max_len")
            self._step()
            self._harvest()
        return self.completions

    def kv_capacity_bytes(self) -> int:
        """HBM resident for the attention KV cache (target + draft)."""
        return self._kv_capacity

    def kv_bytes_in_use(self) -> int:
        """KV bytes backing live requests. Contiguous rows are committed
        whole-pool up front; paged usage scales with actual allocation."""
        if not self.paged:
            return self._kv_capacity
        return self.alloc.blocks_in_use * self._kv_per_block

    # ------------------------------------------------------------ internals
    def _sync_tables(self):
        """Push the host block tables to the device state when stale. This
        runs before any forward that could consume them, so released rows'
        stale writes always route to the garbage block (kv_pool I4)."""
        if self.paged and self._tables_version != self.alloc.version:
            self.state = dataclasses.replace(
                self.state, tables=jnp.asarray(self.alloc.tables))
            self._tables_version = self.alloc.version

    def _prefill_fns(self, p_bucket: int):
        key = p_bucket
        if key in self._prefill_cache:
            return self._prefill_cache[key]
        paged = self.paged
        bs = self.dec.kv_block_size

        def one(params, cfg, slot, toks, plen, pool, tables):
            if paged:
                row_t = jax.lax.dynamic_index_in_dim(tables, slot, 0,
                                                     keepdims=True)
                cin = kv_pool.prefill_cache_view(cfg, pool, True)
            else:
                row_t = None
                cin = init_caches(cfg, 1, self.max_len)
            row = prefill_row(params, cfg, toks, plen, cin, tables=row_t,
                              block_size=bs)
            return kv_pool.scatter_row_caches(cfg, pool, row, slot, paged)

        def prefill(tp, dp, slot, toks, plen, tcache, dcache, tables):
            # single-row prefill; tokens right-padded to the bucket. Padded
            # tail KV lands at positions >= plen — never valid (kv_len
            # bookkeeping) — and SSM state is rolled back (DESIGN.md §3).
            tcache = one(tp, self.tc, slot, toks, plen, tcache, tables)
            if self.dc is not None:
                dcache = one(dp, self.dc, slot, toks, plen, dcache, tables)
            return tcache, dcache

        fn = jax.jit(prefill, donate_argnums=(5, 6))
        self._prefill_cache[key] = fn
        return fn

    def _feasible_templates(self, req: Request) -> List[int]:
        """Bank templates whose window slack fits ``req`` inside max_len.
        Never empty: submit() validated the smallest slack (paged) or the
        bank-wide one (contiguous, where every template fits by then)."""
        budget = self.max_len - len(req.prompt) - req.max_new
        return [t for t in range(len(self.bank))
                if self.dec.row_slack(t) <= budget]

    def _pick_template(self, req: Request) -> int:
        """Admission-time template choice: the request's pinned index, the
        adaptive controller's global-prior pick over templates that fit the
        request in max_len, or template 0."""
        if self.bank is None:
            return 0
        if req.tree_idx is not None:
            return req.tree_idx
        feasible = self._feasible_templates(req)
        if self.ctrl is None:
            return 0 if 0 in feasible else feasible[0]
        return self.ctrl.select(feasible=feasible)

    def _admit(self):
        # phase 1 (host): claim slots and, in paged mode, KV blocks sized
        # for the request's OWN template (per-request window slack). When
        # the pool is exhausted the queue waits — completions release blocks
        pending = []
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue[0]
            p = len(req.prompt)
            tmpl = self._pick_template(req)
            # validated at submit(); covers draft + verify windows (I3) —
            # for the row's own template; the batch's wider window writes
            # route to the garbage block and are never read
            slack = self.dec.row_slack(tmpl) if self.bank is not None \
                else self.dec.window_slack
            need = p + req.max_new + slack
            if self.paged:
                if not self.alloc.can_allocate(self.alloc.blocks_needed(need)) \
                        and self.bank is not None and req.tree_idx is None:
                    # the controller's pick outgrows the pool: serve the
                    # request on the narrowest feasible template instead of
                    # head-of-line blocking (reshaping can widen it later
                    # as completions free blocks); pinned requests keep
                    # their shape and wait
                    tmpl = min(self._feasible_templates(req),
                               key=self.dec.row_slack)
                    need = p + req.max_new + self.dec.row_slack(tmpl)
                nb = self.alloc.blocks_needed(need)
                if not self.alloc.can_allocate(nb):
                    break                      # memory backpressure
                self.alloc.allocate(slot, need)
            self.queue.popleft()
            self.slots[slot] = req
            self.slot_limit[slot] = p + req.max_new
            self.slot_submit_t[slot] = time.perf_counter()
            self.slot_tree[slot] = tmpl
            self.slot_steps[slot] = 0
            if self.ctrl is not None:
                self.ctrl.seed_slot(slot)
            pending.append((slot, req))
        if not pending:
            return
        self._sync_tables()
        if self.paged:
            self.peak_kv_bytes_in_use = max(self.peak_kv_bytes_in_use,
                                            self.kv_bytes_in_use())

        # phase 2 (device): per-request prefill — paged admission writes
        # directly into the slot's blocks (no full-pool row scatter)
        for slot, req in pending:
            p = len(req.prompt)
            bucket = _bucket(p - 1)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :p - 1] = req.prompt[:-1]
            fn = self._prefill_fns(bucket)
            st = self.state
            tcache, dcache = fn(self.dec.tp, self.dec.dp, slot,
                                jnp.asarray(toks), p - 1, st.tcache,
                                st.dcache, st.tables)
            gen_row = np.zeros((self.max_len,), np.int32)
            gen_row[:p] = req.prompt
            t = self.temperature if req.temperature is None \
                else req.temperature
            self.state = dataclasses.replace(
                st,
                gen=st.gen.at[slot].set(jnp.asarray(gen_row)),
                n=st.n.at[slot].set(p),
                m=st.m.at[slot].set(p - 1),
                done=st.done.at[slot].set(False),
                temp=st.temp.at[slot].set(float(t)),
                rngs=st.rngs.at[slot].set(
                    jax.random.fold_in(self._rng_base, req.rid)),
                tree_idx=(st.tree_idx if st.tree_idx is None else
                          st.tree_idx.at[slot].set(
                              int(self.slot_tree[slot]))),
                tcache=tcache, dcache=dcache)

    def _step(self):
        if bool(jnp.all(self.state.done)):
            return
        self._sync_tables()
        if self.mode == "ar":
            self._step_ar()
        else:
            self._step_spec()
        self.stats["steps"] += 1

    def _step_spec(self):
        if self._spec_step is None:
            if self.dec.tree is not None:
                builder = self.dec._build_tree_step()
            else:
                builder = self.dec._build_spec_step(
                    "pard" if self.mode == "pard" else "vsd")
            self._spec_step = jax.jit(builder, donate_argnums=(0,))
        live_mask = ~np.asarray(jax.device_get(self.state.done))
        live = int(live_mask.sum())
        self.state, a, hist, rhist, rank, n_draft = \
            self._spec_step(self.state)
        self.stats["draft_forwards"] += int(n_draft)
        self.stats["target_forwards"] += 1
        self.stats["accepted"] += int(jnp.sum(a))
        self.stats["live_steps"] += live
        rh = np.asarray(jax.device_get(rhist))
        self.stats["round_hist"] = rh if self.stats["round_hist"] is None \
            else self.stats["round_hist"] + rh
        self.stats["committed"] += int(jnp.sum(a) +
                                       jnp.sum(~self.state.done))
        if self.bank is not None:
            np.add.at(self.stats["tree_hist"], self.slot_tree[live_mask], 1)
            self.slot_steps[live_mask] += 1
        if self.ctrl is not None and live:
            self.ctrl.update(live_mask, self.slot_tree,
                             np.asarray(jax.device_get(a)),
                             np.asarray(jax.device_get(rank)))
            self._reshape_slots(live_mask)

    def _reshape_slots(self, live_mask) -> None:
        """Between-windows template re-selection (the adaptive controller).
        Every ``tree_reselect_every`` live steps a slot re-scores the bank
        under its own EWMA statistics and switches when a different
        template wins AND the slot can hold it: within max_len, and — paged
        — growable in place (``BlockAllocator.grow``; when the pool is too
        tight the slot just keeps its current shape). Greedy losslessness
        is shape-independent, so reshaping mid-request never changes
        committed tokens' correctness, only how many arrive per step."""
        for slot in np.nonzero(live_mask)[0]:
            req = self.slots[slot]
            if req is None or req.tree_idx is not None:
                continue            # pinned requests keep their shape
            if self.slot_steps[slot] % self.tree_reselect_every:
                continue
            best = self.ctrl.select(slot=int(slot),
                                    feasible=self._feasible_templates(req))
            if best == int(self.slot_tree[slot]):
                continue
            need = len(req.prompt) + req.max_new + self.dec.row_slack(best)
            if self.paged and not self.alloc.grow(int(slot), need):
                continue            # pool too tight: keep the old shape
            self.slot_tree[slot] = best
            self.state = dataclasses.replace(
                self.state,
                tree_idx=self.state.tree_idx.at[int(slot)].set(int(best)))
            self.stats["tree_switches"] += 1

    def mean_accepted(self) -> float:
        """Mean committed tokens per live row per verify step (a + 1) —
        the tree/flat drafting quality metric gated in CI."""
        if not self.stats["live_steps"]:
            return 0.0
        return 1.0 + self.stats["accepted"] / self.stats["live_steps"]

    def _step_ar(self):
        if self._ar_step is None:
            self._ar_step = jax.jit(self.dec._build_ar_step(),
                                    donate_argnums=(0,))
        self.state = self._ar_step(self.state)
        self.stats["target_forwards"] += 1
        self.stats["committed"] += int(jnp.sum(~self.state.done))

    def _harvest(self):
        n_host = np.asarray(jax.device_get(self.state.n))
        gen_host = None
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            limit = self.slot_limit[slot]
            hit_eos = False
            if self.eos_id is not None:
                if gen_host is None:
                    gen_host = np.asarray(jax.device_get(self.state.gen))
                row = gen_host[slot, len(req.prompt):n_host[slot]]
                hit_eos = self.eos_id in row.tolist()
            if n_host[slot] >= limit or hit_eos:
                if gen_host is None:
                    gen_host = np.asarray(jax.device_get(self.state.gen))
                end = min(n_host[slot], limit)
                toks = gen_host[slot, :end].copy()
                self.completions.append(Completion(
                    rid=req.rid, tokens=toks,
                    generated=int(end - len(req.prompt)),
                    wall_submitted=self.slot_submit_t[slot],
                    wall_done=time.perf_counter()))
                self.slots[slot] = None
                # temp resets with the slot: a retired sampled request must
                # not keep forcing later all-greedy batches onto the
                # sampled lax.cond branch (jnp.any(temp > 0))
                self.state = dataclasses.replace(
                    self.state, done=self.state.done.at[slot].set(True),
                    temp=self.state.temp.at[slot].set(0.0))
                if self.ctrl is not None:
                    self.ctrl.retire_slot(slot)
                if self.paged:
                    self.alloc.release(slot)   # O(1); blocks reusable at once
