"""Batched serving engine — the thin facade over the layered serving stack
(DESIGN.md §8).

The engine is three layers with one owner each:

  * ``serving.scheduler.Scheduler`` (host): request queue, FIFO-fair
    skip-ahead admission, chunked-prefill budgeting, prefix-cache matching,
    adaptive tree-template control, per-request latency accounting (queue
    wait, TTFT, per-token p50/p95);
  * ``serving.executor.Executor`` (device): the ONE ``DecodeState``
    (core.spec_decode), the cache pools in either KV layout, and the fused
    jitted step functions;
  * ``Engine`` (this module): construction + the run loop, preserving the
    original public API (``submit`` / ``run`` / ``stats`` / KV accounting)
    so existing callers and tests keep working.

Design (all fixed shapes, jit-once):
  * PREFILL IS A STEP WORKLOAD, not an admission one: admission only claims
    a slot + KV blocks and writes the prompt into the generation buffer;
    the fused step then advances decoding rows AND consumes a bounded
    prompt chunk for every prefilling row in the SAME forward (Sarathi-
    style chunked prefill) — no per-request ``[1, P_bucket]`` prefill
    forwards, no jit cache over prompt buckets, and admission never stalls
    live decode rows;
  * KV layout is either "paged" (default; serving/kv_pool.py — fixed-size
    blocks, per-slot block tables, refcounted free-list allocation, O(1)
    release) or "contiguous" (one full-length row per slot);
  * ``prefix_cache=True`` (paged only) reuses prompt KV across requests:
    full prompt blocks register in a content-keyed index, admission maps
    the longest computed block-aligned prefix copy-free into the new row's
    table (refcount + 1, target and draft keyed together) and only
    prefills the tail; refcount-0 cached blocks are evicted LRU;
  * decode: ONE jitted speculative step advances all active slots together;
    finished slots free immediately and new requests admit on the next tick
    (continuous batching);
  * ``run(pipelined=True)`` / ``run_pipelined()`` overlap host scheduling
    with device execution: step t+1 is dispatched (donated state buffers,
    staged mutations) while step t's results are still in flight, and every
    step's outputs arrive in one batched transfer (DESIGN.md §9) —
    token-identical to the synchronous loop;
  * modes: "ar" (AR+ baseline), "vsd", "pard" — same engine, same pool;
    ``tree=`` upgrades "pard" to tree-structured drafting (DESIGN.md §6),
    per-request via a TemplateBank, ``adaptive_tree=True`` adds the EWMA
    controller (DESIGN.md §7);
  * sampling is per REQUEST (``submit(..., temperature=)``), each request
    drawing from its own (seed, rid) PRNG key — deterministic per request
    across batch compositions and KV layouts.

SSM/hybrid targets work unchanged: chunked prefill gathers the recurrent
state after each chunk's last real token (DESIGN.md §3), admission zeroes
the recycled slot's state, and SSM states stay batch-indexed in both KV
layouts.
"""
from __future__ import annotations

import warnings
from collections import deque
from typing import Optional

from ..core.spec_decode import SpecDecoder
from ..models.config import ModelConfig
from . import kv_pool
from .config import EngineConfig, SamplingParams  # noqa: F401  (re-export)
from .executor import Executor
from .scheduler import (Completion, Request, Scheduler,  # noqa: F401
                        TreeController)


class Engine:
    """Primary construction path: ``Engine(tp, tc, dp, dc, config=cfg)``
    with a typed, validated ``EngineConfig`` (serving/config.py). The
    historical loose-kwargs form still works — it builds the same config
    through a DeprecationWarning shim — so existing callers keep running
    while new code gets one construction surface."""

    def __init__(self, target_params, target_cfg: ModelConfig,
                 draft_params=None, draft_cfg: Optional[ModelConfig] = None, *,
                 config: Optional[EngineConfig] = None, **legacy):
        if legacy:
            if config is not None:
                raise TypeError(
                    "pass either config=EngineConfig(...) or the legacy "
                    f"keyword arguments, not both (got {sorted(legacy)})")
            warnings.warn(
                "Engine(**kwargs) is deprecated; build an EngineConfig and "
                "pass Engine(params, cfg, ..., config=engine_config)",
                DeprecationWarning, stacklevel=2)
            config = EngineConfig(**legacy)
        elif config is None:
            config = EngineConfig()
        self.config = config
        self.adaptive = config.adaptive_tree
        self.mode = mode = config.mode
        self.paged = config.paged
        self.k = config.k if mode != "ar" else 1
        if mode == "ar":
            # the AR baseline never reads draft caches: drop the draft model
            # so admission skips its KV accounting entirely
            draft_params = draft_cfg = None
        self.max_batch = max_batch = config.max_batch
        self.max_len = max_len = config.max_len
        self.eos_id = config.eos_id
        self.temperature = config.temperature  # submit(temperature=None)
        self.mesh = config.mesh                # None = single-device serving
        # data-parallel replicas (DESIGN.md §12): dp > 1 splits the mesh
        # into one (1, tp) row per replica and builds one (SpecDecoder,
        # BlockAllocator, Executor) triple on each — independent device
        # programs with their own DecodeState and KV pool behind the one
        # host-side scheduler. dp=1 is the historical single-triple path.
        self.dp = dp = config.dp
        if dp > 1:
            from ..launch.mesh import replica_submeshes
            meshes = replica_submeshes(config.mesh)
        else:
            meshes = [config.mesh]
        decs = [SpecDecoder(
            target_params, target_cfg, draft_params, draft_cfg, k=self.k,
            max_len=max_len, temperature=config.temperature,
            kv_block_size=config.kv_block_size if self.paged else 0,
            tree=config.tree if mode == "pard" else None,
            prefill_chunk=config.prefill_chunk, kv_dtype=config.kv_dtype,
            mesh=m, tp_ruleset=config.tp_ruleset) for m in meshes]
        self.dec = decs[0]
        self.k = self.dec.k          # a tree template overrides k (== depth)
        self.bank = self.dec.tree    # TemplateBank (or None: no tree)
        self.tc, self.dc = target_cfg, draft_cfg

        if self.paged:
            # kv_num_blocks is PER REPLICA: each replica owns a full pool
            nb = config.kv_num_blocks or kv_pool.default_num_blocks(
                max_batch, max_len, config.kv_block_size)
            # the shared cross-replica prefix index admission routes over;
            # pointless (and absent) with a single replica
            self.prefix_index = kv_pool.PrefixIndex() if dp > 1 else None
            allocs = [kv_pool.BlockAllocator(
                nb, config.kv_block_size, max_batch, max_len, replica=r,
                prefix_index=self.prefix_index) for r in range(dp)]
        else:
            nb = None
            allocs = [None] * dp
            self.prefix_index = None
        self.alloc = allocs[0]
        exs = [Executor(decs[r], target_cfg, draft_cfg, mode, max_batch,
                        max_len, self.paged, config.kv_block_size, nb,
                        config.seed, kv_dtype=config.kv_dtype,
                        mesh=meshes[r], replica=r,
                        tp_ruleset=config.tp_ruleset) for r in range(dp)]
        self.ex = exs[0]
        ctrl = (TreeController(self.bank, max_batch * dp, config.tree_ewma)
                if config.adaptive_tree else None)
        self.sched = Scheduler(
            decs if dp > 1 else decs[0], exs if dp > 1 else exs[0],
            allocs if dp > 1 else allocs[0], mode=mode, max_batch=max_batch,
            max_len=max_len, temperature=config.temperature,
            eos_id=config.eos_id, bank=self.bank, ctrl=ctrl,
            prefix_cache=config.prefix_cache,
            admit_window=config.admit_window,
            prefill_budget=config.prefill_budget,
            tree_reselect_every=config.tree_reselect_every,
            prefix_index=self.prefix_index)
        self.ctrl = ctrl
        # contiguous rows are committed whole-pool up front, so their peak
        # IS the capacity — consumers read this field for either layout
        self.peak_kv_bytes_in_use = (0 if self.paged
                                     else self.kv_capacity_bytes())

    # ------------------------------------------------------------- public
    def submit(self, prompt, max_new: Optional[int] = None,
               temperature: Optional[float] = None,
               tree_idx: Optional[int] = None,
               params: Optional[SamplingParams] = None) -> int:
        """Queue a request. Preferred: ``submit(prompt, params=
        SamplingParams(max_new=.., temperature=.., seed=.., tree_idx=..))``.
        The loose keywords still work (``temperature`` overrides the engine
        default for this request only, 0 = greedy; ``tree_idx`` pins one
        bank template) and fold into the same SamplingParams. Validation
        happens here, with the request's OWN window slack in the paged
        layout — see Scheduler.submit."""
        return self.sched.submit(prompt, max_new, temperature, tree_idx,
                                 params=params)

    def run(self, max_steps: int = 100000,
            pipelined: Optional[bool] = None):
        """Drive the serve loop to completion. ``pipelined=False`` runs
        the depth-1 (synchronous) pipeline: each step is dispatched and
        its results processed back-to-back — the exact historical
        semantics. ``pipelined=True`` runs depth 2: step t+1 is dispatched
        (with the mutations staged from step t-1's results) BEFORE step
        t's results are harvested, so host-side scheduling overlaps device
        execution (DESIGN.md §9). Both depths share this one loop; the
        only difference is how many handles may be in flight.
        ``pipelined=None`` defaults to ``config.pipelined``."""
        if pipelined is None:
            pipelined = self.config.pipelined
        sched = self.sched
        depth = 2 if pipelined else 1
        # one independent dispatch/harvest pipeline PER replica: each
        # replica's handles retire in its own dispatch order, and all
        # replicas' steps are dispatched back-to-back before any harvest
        # blocks (on real multi-device hardware the replicas' device work
        # overlaps; dp=1 reduces to the single historical deque)
        inflight = {rep.rep: deque() for rep in sched.replicas}

        def pending() -> int:
            return sum(len(q) for q in inflight.values())

        sched._harvest_done_t = None   # don't count inter-run wall time
        while sched.has_work() or pending():
            dispatched = False
            if sched.has_work() and sched.stats["steps"] < max_steps:
                admitted = sched.admit()
                if sched.queue and not admitted and not pending() \
                        and not any(rep.has_live()
                                    for rep in sched.replicas):
                    # every slot (hence every block) is free, nothing is in
                    # flight that could free more, and NOTHING in the
                    # admission window could admit: the head can never fit
                    # — fail loudly instead of spinning forever
                    req = sched.queue[0]
                    raise RuntimeError(
                        f"request {req.rid} (prompt={len(req.prompt)}, "
                        f"max_new={req.max_new}) needs more KV blocks than "
                        f"the pool holds; raise kv_num_blocks or max_len")
                for rep in sched.replicas:
                    rep.ex.sync_tables(rep.alloc)
                if self.paged:
                    self.peak_kv_bytes_in_use = max(
                        self.peak_kv_bytes_in_use, self.kv_bytes_in_use())
                for rep in sched.replicas:
                    if rep.has_live():
                        inflight[rep.rep].append(sched.dispatch(rep.rep))
                        dispatched = True
            for q in inflight.values():
                if q and (len(q) >= depth or not dispatched):
                    sched.process(q.popleft())
            if not dispatched and not pending():
                break                  # step budget exhausted, fully drained
        return sched.completions

    def run_pipelined(self, max_steps: int = 100000):
        """``run`` with the two-deep dispatch/harvest pipeline."""
        return self.run(max_steps, pipelined=True)

    def mean_accepted(self) -> float:
        return self.sched.mean_accepted()

    def prefix_hit_rate(self) -> float:
        return self.sched.prefix_hit_rate()

    def latency_summary(self):
        return self.sched.latency_summary()

    def kv_capacity_bytes(self) -> int:
        """HBM resident for the attention KV cache (target + draft),
        summed over all replicas."""
        return sum(rep.ex.kv_capacity for rep in self.sched.replicas)

    def kv_bytes_in_use(self) -> int:
        """KV bytes backing live requests, summed over all replicas.
        Contiguous rows are committed whole-pool up front; paged usage
        counts each UNIQUE mapped block once (prefix-shared blocks are
        the point of sharing)."""
        if not self.paged:
            return self.kv_capacity_bytes()
        return sum(rep.alloc.blocks_in_use * rep.ex.kv_per_block
                   for rep in self.sched.replicas)

    # --------------------------------------------------- facade accessors
    @property
    def state(self):
        return self.ex.state

    @property
    def stats(self):
        return self.sched.stats

    @property
    def queue(self):
        return self.sched.queue

    @property
    def slots(self):
        return self.sched.slots

    @property
    def completions(self):
        return self.sched.completions
