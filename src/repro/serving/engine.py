"""Batched serving engine with continuous batching — the Transformers+/vLLM
analogue of the paper's evaluation stack.

Design (all fixed shapes, jit-once):
  * a KV-cache POOL of ``max_batch`` slots (target + draft), a generation
    buffer, and per-slot host state (committed count n, draft progress m,
    done flag, request id);
  * admission: a free slot gets a PREFILL — the request's caches are
    computed in a [1, P_bucket] forward (prompt lengths bucketed to powers
    of two to bound recompilation) and scattered into the pool at the slot's
    batch index;
  * decode: ONE jitted speculative step (from core.spec_decode) advances all
    active slots together; finished slots free immediately and new requests
    admit on the next tick (continuous batching);
  * modes: "ar" (AR+ baseline), "vsd", "pard" — same engine, same pool.

SSM/hybrid targets work unchanged: the spec step's collect_ssm rollback is
per-row, and prefill produces the row's (conv, ssm) state like any cache.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.spec_decode import SpecDecoder
from ..models import forward, init_caches
from ..models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # 1-D int32
    max_new: int


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: np.ndarray          # prompt + generated
    generated: int
    wall_submitted: float
    wall_done: float


def _bucket(n: int) -> int:
    b = 8
    while b < n:
        b *= 2
    return b


def _row_insert(pool_tree, row_tree, slot: int):
    """Scatter a [1, ...] cache row into the pool at batch index ``slot``.
    The cache pytree structure is {"prefix": [...], "scan": [...]}: prefix
    leaves carry batch at axis 0, scanned leaves at axis 1 (repeats first)."""
    def ins_axis(axis):
        def ins(pool, row):
            idx = [0] * pool.ndim
            idx[axis] = slot
            return jax.lax.dynamic_update_slice(pool, row.astype(pool.dtype),
                                                tuple(idx))
        return ins

    return {
        "prefix": jax.tree.map(ins_axis(0), pool_tree["prefix"],
                               row_tree["prefix"]),
        "scan": jax.tree.map(ins_axis(1), pool_tree["scan"],
                             row_tree["scan"]),
    }


class Engine:
    def __init__(self, target_params, target_cfg: ModelConfig,
                 draft_params=None, draft_cfg: Optional[ModelConfig] = None, *,
                 mode: str = "pard", k: int = 8, max_batch: int = 4,
                 max_len: int = 1024, temperature: float = 0.0,
                 eos_id: Optional[int] = None, seed: int = 0):
        assert mode in ("ar", "vsd", "pard")
        self.mode = mode
        self.k = k if mode != "ar" else 1
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.dec = SpecDecoder(target_params, target_cfg, draft_params,
                               draft_cfg, k=self.k, max_len=max_len,
                               temperature=temperature)
        self.tc, self.dc = target_cfg, draft_cfg
        self.rng = jax.random.PRNGKey(seed)

        # pools
        self.tcache = init_caches(target_cfg, max_batch, max_len)
        self.dcache = (init_caches(draft_cfg, max_batch, max_len)
                       if draft_cfg is not None else None)
        self.gen = jnp.zeros((max_batch, max_len), jnp.int32)
        self.n = jnp.ones((max_batch,), jnp.int32) * 2   # dummy-safe
        self.m = jnp.ones((max_batch,), jnp.int32)
        self.done = jnp.ones((max_batch,), bool)         # empty slots = done

        # host state
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.slot_limit = np.zeros(max_batch, np.int64)
        self.slot_submit_t = np.zeros(max_batch)
        self.queue: deque[Request] = deque()
        self.completions: List[Completion] = []
        self._next_rid = 0
        self._spec_step = None
        self._ar_step = None
        self._prefill_cache: Dict[Any, Any] = {}
        self.stats = dict(steps=0, committed=0, draft_forwards=0,
                          target_forwards=0)

    # ------------------------------------------------------------- public
    def submit(self, prompt, max_new: int) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, np.asarray(prompt, np.int32), max_new))
        return rid

    def run(self, max_steps: int = 100000) -> List[Completion]:
        while (self.queue or any(s is not None for s in self.slots)) \
                and self.stats["steps"] < max_steps:
            self._admit()
            self._step()
            self._harvest()
        return self.completions

    # ------------------------------------------------------------ internals
    def _prefill_fns(self, p_bucket: int):
        key = p_bucket
        if key in self._prefill_cache:
            return self._prefill_cache[key]

        from ..core.spec_decode import _has_ssm, gather_ssm_states
        t_ssm = _has_ssm(self.tc)
        d_ssm = _has_ssm(self.dc) if self.dc is not None else False

        def one(params, cfg, toks, plen, has_ssm):
            c = init_caches(cfg, 1, self.max_len)
            _, cache, _ = forward(params, cfg, toks, caches=c,
                                  cache_pos=jnp.zeros((1,), jnp.int32),
                                  collect_ssm=has_ssm)
            if has_ssm:
                # padded tail tokens would corrupt SSM state: roll back to
                # the state after the last REAL prompt token (index plen-1
                # of the plen processed tokens)
                idx = jnp.asarray(plen - 1, jnp.int32).reshape(1)
                cache = gather_ssm_states(cfg, cache, idx)
            return cache

        def prefill(tp, dp, toks, plen):
            # single-row caches; tokens right-padded to the bucket. The
            # padded tail writes attention KV at positions >= plen — never
            # valid (kv_len bookkeeping) — and SSM state is rolled back.
            tcache = one(tp, self.tc, toks, plen, t_ssm)
            dcache = None
            if self.dc is not None:
                dcache = one(dp, self.dc, toks, plen, d_ssm)
            return tcache, dcache

        fn = jax.jit(prefill)
        self._prefill_cache[key] = fn
        return fn

    def _admit(self):
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            p = len(req.prompt)
            assert p >= 2 and p + req.max_new + 2 * self.k + 2 <= self.max_len
            bucket = _bucket(p - 1)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :p - 1] = req.prompt[:-1]
            # NOTE: padded tail tokens write cache entries at positions
            # >= p-1; they are re-covered by the first decode/verify write
            # (cache_pos = p-1) or masked by kv_len — never attended.
            fn = self._prefill_fns(bucket)
            tr, dr = fn(self.dec.tp, self.dec.dp, jnp.asarray(toks),
                        p - 1)
            self.tcache = _row_insert(self.tcache, tr, slot)
            if dr is not None:
                self.dcache = _row_insert(self.dcache, dr, slot)
            gen_row = np.zeros((self.max_len,), np.int32)
            gen_row[:p] = req.prompt
            self.gen = self.gen.at[slot].set(jnp.asarray(gen_row))
            self.n = self.n.at[slot].set(p)
            self.m = self.m.at[slot].set(p - 1)
            self.done = self.done.at[slot].set(False)
            self.slots[slot] = req
            self.slot_limit[slot] = p + req.max_new
            self.slot_submit_t[slot] = time.perf_counter()

    def _step(self):
        if bool(jnp.all(self.done)):
            return
        if self.mode == "ar":
            self._step_ar()
        else:
            self._step_spec()
        self.stats["steps"] += 1

    def _step_spec(self):
        if self._spec_step is None:
            self._spec_step = jax.jit(self.dec._build_spec_step(
                "pard" if self.mode == "pard" else "vsd"),
                donate_argnums=(0, 4, 5))
        self.rng, sub = jax.random.split(self.rng)
        (self.gen, self.n, self.m, self.tcache, self.dcache, a, hist,
         n_draft) = self._spec_step(self.gen, self.n, self.m, self.done,
                                    self.tcache, self.dcache, sub)
        self.stats["draft_forwards"] += int(n_draft)
        self.stats["target_forwards"] += 1
        self.stats["committed"] += int(jnp.sum(a) + jnp.sum(~self.done))

    def _step_ar(self):
        if self._ar_step is None:
            def ar_step(gen, n, done, tcache):
                last = jnp.take_along_axis(gen, (n - 1)[:, None], axis=1)
                logits, tcache, _ = forward(
                    self.dec.tp, self.tc, last.astype(jnp.int32),
                    caches=tcache, cache_pos=n - 1)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                gen2 = jax.vmap(
                    lambda g, t, p: jax.lax.dynamic_update_slice(g, t[None], (p,))
                )(gen, nxt, n)
                gen = jnp.where(done[:, None], gen, gen2)
                n = jnp.where(done, n, n + 1)
                return gen, n, tcache
            self._ar_step = jax.jit(ar_step, donate_argnums=(3,))
        self.gen, self.n, self.tcache = self._ar_step(
            self.gen, self.n, self.done, self.tcache)
        self.stats["target_forwards"] += 1
        self.stats["committed"] += int(jnp.sum(~self.done))

    def _harvest(self):
        n_host = np.asarray(jax.device_get(self.n))
        gen_host = None
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            limit = self.slot_limit[slot]
            hit_eos = False
            if self.eos_id is not None:
                if gen_host is None:
                    gen_host = np.asarray(jax.device_get(self.gen))
                row = gen_host[slot, len(req.prompt):n_host[slot]]
                hit_eos = self.eos_id in row.tolist()
            if n_host[slot] >= limit or hit_eos:
                if gen_host is None:
                    gen_host = np.asarray(jax.device_get(self.gen))
                end = min(n_host[slot], limit)
                toks = gen_host[slot, :end].copy()
                self.completions.append(Completion(
                    rid=req.rid, tokens=toks,
                    generated=int(end - len(req.prompt)),
                    wall_submitted=self.slot_submit_t[slot],
                    wall_done=time.perf_counter()))
                self.slots[slot] = None
                self.done = self.done.at[slot].set(True)
