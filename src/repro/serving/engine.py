"""Batched serving engine — the thin facade over the layered serving stack
(DESIGN.md §8).

The engine is three layers with one owner each:

  * ``serving.scheduler.Scheduler`` (host): request queue, FIFO-fair
    skip-ahead admission, chunked-prefill budgeting, prefix-cache matching,
    adaptive tree-template control, per-request latency accounting (queue
    wait, TTFT, per-token p50/p95);
  * ``serving.executor.Executor`` (device): the ONE ``DecodeState``
    (core.spec_decode), the cache pools in either KV layout, and the fused
    jitted step functions;
  * ``Engine`` (this module): construction + the run loop, preserving the
    original public API (``submit`` / ``run`` / ``stats`` / KV accounting)
    so existing callers and tests keep working.

Design (all fixed shapes, jit-once):
  * PREFILL IS A STEP WORKLOAD, not an admission one: admission only claims
    a slot + KV blocks and writes the prompt into the generation buffer;
    the fused step then advances decoding rows AND consumes a bounded
    prompt chunk for every prefilling row in the SAME forward (Sarathi-
    style chunked prefill) — no per-request ``[1, P_bucket]`` prefill
    forwards, no jit cache over prompt buckets, and admission never stalls
    live decode rows;
  * KV layout is either "paged" (default; serving/kv_pool.py — fixed-size
    blocks, per-slot block tables, refcounted free-list allocation, O(1)
    release) or "contiguous" (one full-length row per slot);
  * ``prefix_cache=True`` (paged only) reuses prompt KV across requests:
    full prompt blocks register in a content-keyed index, admission maps
    the longest computed block-aligned prefix copy-free into the new row's
    table (refcount + 1, target and draft keyed together) and only
    prefills the tail; refcount-0 cached blocks are evicted LRU;
  * decode: ONE jitted speculative step advances all active slots together;
    finished slots free immediately and new requests admit on the next tick
    (continuous batching);
  * ``run(pipelined=True)`` / ``run_pipelined()`` overlap host scheduling
    with device execution: step t+1 is dispatched (donated state buffers,
    staged mutations) while step t's results are still in flight, and every
    step's outputs arrive in one batched transfer (DESIGN.md §9) —
    token-identical to the synchronous loop;
  * modes: "ar" (AR+ baseline), "vsd", "pard" — same engine, same pool;
    ``tree=`` upgrades "pard" to tree-structured drafting (DESIGN.md §6),
    per-request via a TemplateBank, ``adaptive_tree=True`` adds the EWMA
    controller (DESIGN.md §7);
  * sampling is per REQUEST (``submit(..., temperature=)``), each request
    drawing from its own (seed, rid) PRNG key — deterministic per request
    across batch compositions and KV layouts.

SSM/hybrid targets work unchanged: chunked prefill gathers the recurrent
state after each chunk's last real token (DESIGN.md §3), admission zeroes
the recycled slot's state, and SSM states stay batch-indexed in both KV
layouts.
"""
from __future__ import annotations

from collections import deque
from typing import Optional

from ..core.spec_decode import SpecDecoder, TemplateBank
from ..models.attention import KV_DTYPES
from ..models.config import ModelConfig
from . import kv_pool
from .executor import Executor
from .scheduler import (Completion, Request, Scheduler,  # noqa: F401
                        TreeController)


class Engine:
    def __init__(self, target_params, target_cfg: ModelConfig,
                 draft_params=None, draft_cfg: Optional[ModelConfig] = None, *,
                 mode: str = "pard", k: int = 8, max_batch: int = 4,
                 max_len: int = 1024, temperature: float = 0.0,
                 eos_id: Optional[int] = None, seed: int = 0,
                 kv_layout: str = "paged", kv_block_size: int = 64,
                 kv_num_blocks: Optional[int] = None, tree=None,
                 adaptive_tree: bool = False, tree_ewma: float = 0.2,
                 tree_reselect_every: int = 4, prefix_cache: bool = False,
                 prefill_chunk: int = 8, prefill_budget: Optional[int] = None,
                 admit_window: int = 8, kv_dtype: str = "bf16"):
        assert mode in ("ar", "vsd", "pard")
        assert kv_layout in ("paged", "contiguous")
        assert kv_dtype in KV_DTYPES, \
            f"kv_dtype must be one of {sorted(KV_DTYPES)}"
        assert tree is None or mode == "pard", \
            "tree templates apply to the PARD draft path only"
        if adaptive_tree:
            assert mode == "pard", "adaptive trees require mode='pard'"
            if tree is None:
                tree = TemplateBank.default(k)
            assert isinstance(tree, TemplateBank), \
                "adaptive_tree selects from a TemplateBank"
        self.adaptive = adaptive_tree
        self.mode = mode
        self.paged = kv_layout == "paged"
        assert not (prefix_cache and not self.paged), \
            "prefix_cache requires the paged KV layout"
        self.k = k if mode != "ar" else 1
        if mode == "ar":
            # the AR baseline never reads draft caches: drop the draft model
            # so admission skips its KV accounting entirely
            draft_params = draft_cfg = None
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.temperature = temperature   # default for submit(temperature=None)
        self.dec = SpecDecoder(
            target_params, target_cfg, draft_params, draft_cfg, k=self.k,
            max_len=max_len, temperature=temperature,
            kv_block_size=kv_block_size if self.paged else 0,
            tree=tree if mode == "pard" else None,
            prefill_chunk=prefill_chunk, kv_dtype=kv_dtype)
        self.k = self.dec.k          # a tree template overrides k (== depth)
        self.bank = self.dec.tree    # TemplateBank (or None: no tree)
        self.tc, self.dc = target_cfg, draft_cfg

        if self.paged:
            nb = kv_num_blocks or kv_pool.default_num_blocks(
                max_batch, max_len, kv_block_size)
            self.alloc = kv_pool.BlockAllocator(nb, kv_block_size, max_batch,
                                                max_len)
        else:
            nb = None
            self.alloc = None
        self.ex = Executor(self.dec, target_cfg, draft_cfg, mode, max_batch,
                           max_len, self.paged, kv_block_size, nb, seed,
                           kv_dtype=kv_dtype)
        ctrl = (TreeController(self.bank, max_batch, tree_ewma)
                if adaptive_tree else None)
        self.sched = Scheduler(
            self.dec, self.ex, self.alloc, mode=mode, max_batch=max_batch,
            max_len=max_len, temperature=temperature, eos_id=eos_id,
            bank=self.bank, ctrl=ctrl, prefix_cache=prefix_cache,
            admit_window=admit_window, prefill_budget=prefill_budget,
            tree_reselect_every=tree_reselect_every)
        self.ctrl = ctrl
        # contiguous rows are committed whole-pool up front, so their peak
        # IS the capacity — consumers read this field for either layout
        self.peak_kv_bytes_in_use = 0 if self.paged else self.ex.kv_capacity

    # ------------------------------------------------------------- public
    def submit(self, prompt, max_new: int,
               temperature: Optional[float] = None,
               tree_idx: Optional[int] = None) -> int:
        """Queue a request. ``temperature`` overrides the engine default
        for this request only (0 = greedy); ``tree_idx`` pins one bank
        template (tree engines). Validation happens here, with the
        request's OWN window slack in the paged layout — see
        Scheduler.submit."""
        return self.sched.submit(prompt, max_new, temperature, tree_idx)

    def run(self, max_steps: int = 100000, pipelined: bool = False):
        """Drive the serve loop to completion. ``pipelined=False`` runs
        the depth-1 (synchronous) pipeline: each step is dispatched and
        its results processed back-to-back — the exact historical
        semantics. ``pipelined=True`` runs depth 2: step t+1 is dispatched
        (with the mutations staged from step t-1's results) BEFORE step
        t's results are harvested, so host-side scheduling overlaps device
        execution (DESIGN.md §9). Both depths share this one loop; the
        only difference is how many handles may be in flight."""
        sched, ex = self.sched, self.ex
        depth = 2 if pipelined else 1
        inflight = deque()
        sched._harvest_done_t = None   # don't count inter-run wall time
        while sched.has_work() or inflight:
            dispatched = False
            if sched.has_work() and sched.stats["steps"] < max_steps:
                admitted = sched.admit()
                if sched.queue and not admitted and not inflight \
                        and all(s is None for s in sched.slots):
                    # every slot (hence every block) is free, nothing is in
                    # flight that could free more, and NOTHING in the
                    # admission window could admit: the head can never fit
                    # — fail loudly instead of spinning forever
                    req = sched.queue[0]
                    raise RuntimeError(
                        f"request {req.rid} (prompt={len(req.prompt)}, "
                        f"max_new={req.max_new}) needs more KV blocks than "
                        f"the pool holds; raise kv_num_blocks or max_len")
                ex.sync_tables(self.alloc)
                if self.paged:
                    self.peak_kv_bytes_in_use = max(
                        self.peak_kv_bytes_in_use, self.kv_bytes_in_use())
                if any(s is not None for s in sched.slots):
                    inflight.append(sched.dispatch())
                    dispatched = True
            if inflight and (len(inflight) >= depth or not dispatched):
                sched.process(inflight.popleft())
            elif not dispatched and not inflight:
                break                  # step budget exhausted, fully drained
        return sched.completions

    def run_pipelined(self, max_steps: int = 100000):
        """``run`` with the two-deep dispatch/harvest pipeline."""
        return self.run(max_steps, pipelined=True)

    def mean_accepted(self) -> float:
        return self.sched.mean_accepted()

    def prefix_hit_rate(self) -> float:
        return self.sched.prefix_hit_rate()

    def latency_summary(self):
        return self.sched.latency_summary()

    def kv_capacity_bytes(self) -> int:
        """HBM resident for the attention KV cache (target + draft)."""
        return self.ex.kv_capacity

    def kv_bytes_in_use(self) -> int:
        """KV bytes backing live requests. Contiguous rows are committed
        whole-pool up front; paged usage counts each UNIQUE mapped block
        once (prefix-shared blocks are the point of sharing)."""
        if not self.paged:
            return self.ex.kv_capacity
        return self.alloc.blocks_in_use * self.ex.kv_per_block

    # --------------------------------------------------- facade accessors
    @property
    def state(self):
        return self.ex.state

    @property
    def stats(self):
        return self.sched.stats

    @property
    def queue(self):
        return self.sched.queue

    @property
    def slots(self):
        return self.sched.slots

    @property
    def completions(self):
        return self.sched.completions
