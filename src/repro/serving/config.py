"""Typed configuration surface for the serving stack (DESIGN.md §8, §11).

``EngineConfig`` is the single source of truth for engine construction:
every knob the engine understands is a field, validation happens once in
``__post_init__`` (construction-time, not deep inside the stack), and
``Engine(tp, tc, dp, dc, config=cfg)`` is the primary constructor path.
The legacy ``Engine(**kwargs)`` sprawl still works through a deprecation
shim that simply builds an ``EngineConfig`` from the kwargs.

``SamplingParams`` is the per-request companion (vLLM-style): everything
``submit`` used to take as loose keywords — plus a per-request ``seed`` —
travels as one value object that the scheduler carries on the Request.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from ..core.spec_decode import TemplateBank, TreeTemplate
from ..models.attention import KV_DTYPES


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode options.

    ``max_new``     tokens to generate (required by submit time; the field
                    is optional so partially-specified params can be merged
                    with a positional ``max_new``).
    ``temperature`` 0 = greedy; None = the engine default.
    ``seed``        per-request PRNG seed. None derives the request stream
                    from the engine seed and rid (the historical behaviour);
                    setting it makes the request's sampled tokens
                    reproducible independent of engine seed and batch
                    composition.
    ``tree_idx``    pins one TemplateBank template (tree engines only).
    """
    max_new: Optional[int] = None
    temperature: Optional[float] = None
    seed: Optional[int] = None
    tree_idx: Optional[int] = None

    def __post_init__(self):
        if self.max_new is not None and self.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {self.max_new}")
        if self.temperature is not None and self.temperature < 0:
            raise ValueError(
                f"temperature must be >= 0, got {self.temperature}")

    def merged(self, max_new: Optional[int]) -> "SamplingParams":
        """Resolve a positional ``max_new`` against this params object.
        A params object with its own max_new wins conflicts only if the
        two agree; otherwise the ambiguity is an error."""
        if max_new is None:
            if self.max_new is None:
                raise ValueError("max_new is required: pass it positionally "
                                 "or set SamplingParams.max_new")
            return self
        if self.max_new is not None and self.max_new != max_new:
            raise ValueError(
                f"conflicting max_new: positional {max_new} vs "
                f"SamplingParams.max_new={self.max_new}")
        return dataclasses.replace(self, max_new=max_new)


@dataclasses.dataclass
class EngineConfig:
    """Engine construction knobs. Validation that used to live in
    ``Engine.__init__`` runs in ``__post_init__`` (same assert semantics —
    existing callers catch AssertionError); new range checks raise
    ValueError. Model params/configs are NOT fields — they stay positional
    on ``Engine`` so one config object can serve many model pairs."""

    mode: str = "pard"
    k: int = 8
    max_batch: int = 4
    max_len: int = 1024
    temperature: float = 0.0
    eos_id: Optional[int] = None
    seed: int = 0
    kv_layout: str = "paged"
    kv_block_size: int = 64
    kv_num_blocks: Optional[int] = None
    kv_dtype: str = "bf16"
    tree: Any = None                 # branching iterable / TreeTemplate / TemplateBank
    adaptive_tree: bool = False
    tree_ewma: float = 0.2
    tree_reselect_every: int = 4
    prefix_cache: bool = False
    prefill_chunk: int = 8
    prefill_budget: Optional[int] = None
    admit_window: int = 8
    pipelined: bool = False          # default for Engine.run()
    # -- sharded serving (DESIGN.md §11, §12) ---------------------------
    # tp > 1 or dp > 1 without an explicit mesh builds a (data=dp,
    # model=tp) host mesh; an explicit mesh must carry a "model" axis of
    # size tp and a "data" axis of size dp (when they were given) and
    # wins otherwise. dp = N serves N independent engine replicas — each
    # with its own executor, DecodeState and KV pool on its own mesh row
    # — behind the one host-side scheduler (DESIGN.md §12).
    tp: int = 1
    dp: int = 1
    mesh: Any = None                 # jax.sharding.Mesh
    # "exact" = reduction-free output-dim sharding, tokens bitwise
    # identical across mesh shapes (DESIGN.md §11). "throughput" =
    # Megatron-style row-parallel down-projections, one psum per
    # attention block / MLP, tokens match tp1 to tolerance only
    # (DESIGN.md §13).
    tp_ruleset: str = "exact"

    def __post_init__(self):
        assert self.mode in ("ar", "vsd", "pard")
        assert self.kv_layout in ("paged", "contiguous")
        assert self.kv_dtype in KV_DTYPES, \
            f"kv_dtype must be one of {sorted(KV_DTYPES)}"
        assert self.tree is None or self.mode == "pard", \
            "tree templates apply to the PARD draft path only"
        if self.adaptive_tree:
            assert self.mode == "pard", "adaptive trees require mode='pard'"
            if self.tree is None:
                self.tree = TemplateBank.default(self.k)
            assert isinstance(self.tree, TemplateBank), \
                "adaptive_tree selects from a TemplateBank"
        assert not (self.prefix_cache and self.kv_layout != "paged"), \
            "prefix_cache requires the paged KV layout"
        if self.tree is not None and not isinstance(self.tree, TemplateBank):
            # canonical form: branching iterable / TreeTemplate -> a
            # one-template bank (what SpecDecoder normalises to anyway)
            if not isinstance(self.tree, TreeTemplate):
                self.tree = TreeTemplate.from_branching(self.tree)
            self.tree = TemplateBank.from_templates((self.tree,))
        for name in ("k", "max_batch", "max_len", "kv_block_size",
                     "prefill_chunk", "admit_window", "tree_reselect_every"):
            if getattr(self, name) < 1:
                raise ValueError(f"{name} must be >= 1, "
                                 f"got {getattr(self, name)}")
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, "
                             f"got {self.temperature}")
        if not 0.0 < self.tree_ewma <= 1.0:
            raise ValueError(f"tree_ewma must be in (0, 1], "
                             f"got {self.tree_ewma}")
        if self.tp < 1:
            raise ValueError(f"tp must be >= 1, got {self.tp}")
        if self.dp < 1:
            raise ValueError(f"dp must be >= 1, got {self.dp}")
        if self.tp_ruleset not in ("exact", "throughput"):
            raise ValueError("tp_ruleset must be 'exact' or 'throughput', "
                             f"got {self.tp_ruleset!r}")
        if self.mesh is None and (self.tp > 1 or self.dp > 1):
            from ..launch import mesh as mesh_mod
            self.mesh = mesh_mod.make_host_mesh(model=self.tp, data=self.dp)
        if self.mesh is not None:
            if "model" not in self.mesh.axis_names:
                raise ValueError("the serving mesh needs a 'model' axis "
                                 f"(got axes {self.mesh.axis_names})")
            if self.tp > 1 and self.mesh.shape["model"] != self.tp:
                raise ValueError(
                    f"mesh 'model' axis has {self.mesh.shape['model']} "
                    f"devices but tp={self.tp}")
            if self.dp > 1:
                if "data" not in self.mesh.axis_names:
                    raise ValueError(
                        "dp > 1 needs a mesh with a 'data' axis "
                        f"(got axes {self.mesh.axis_names})")
                if self.mesh.shape["data"] != self.dp:
                    raise ValueError(
                        f"mesh 'data' axis has {self.mesh.shape['data']} "
                        f"devices but dp={self.dp}")

    @property
    def paged(self) -> bool:
        return self.kv_layout == "paged"

    @classmethod
    def from_args(cls, ns) -> "EngineConfig":
        """Build from an argparse namespace (repro.launch.serve and the
        benchmarks share this mapping). Missing attributes fall back to
        field defaults, so partial namespaces work; ``ns.tree`` is the CLI
        string form ("2,2,1"), normalised here."""
        tree = getattr(ns, "tree", None)
        adaptive = bool(getattr(ns, "adaptive_tree", False))
        mode = getattr(ns, "mode", "pard")
        if adaptive:
            assert mode == "pard", "--adaptive-tree requires --mode pard"
            assert tree is None, \
                "--adaptive-tree selects its own bank; drop --tree"
        elif tree is not None:
            assert mode == "pard", "--tree requires --mode pard"
            if isinstance(tree, str):
                tree = TreeTemplate.from_branching(
                    int(x) for x in tree.split(","))
        kw = {}
        for f in dataclasses.fields(cls):
            if f.name in ("tree", "mesh"):
                continue
            if hasattr(ns, f.name):
                kw[f.name] = getattr(ns, f.name)
        return cls(tree=tree, mesh=getattr(ns, "mesh", None), **kw)
