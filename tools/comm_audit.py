"""Collective-accounting audit of the compiled serving step (DESIGN.md §13).

  PYTHONPATH=src python tools/comm_audit.py --target tiny-target \
      --draft tiny-draft --tp 4 --devices 4

Wall-clock on CPU-emulated collectives is not a trustworthy gate, so the
throughput tensor-parallel ruleset is gated on what the compiler actually
emitted: this module walks post-GSPMD HLO, counts the collective ops
(all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute) and sums their output byte volumes — the per-step
communication bill a real interconnect would pay.

Two lowerings are audited. The GATE-bearing one is ``audit_forward``: the
model decode-window forward jitted with the params as EXPLICIT sharded
arguments, which is what a real deployment pays — weights resident as
sharded device buffers that XLA cannot constant-fold. The engine's fused
step (``audit_executor`` / ``Executor.step_hlo``) is recorded alongside
as a diagnostic: there the params enter the jit as closure constants, so
on the tiny CI models XLA folds the exact ruleset's weight/activation
gathers into replicated constants and under-reports its traffic (the
recorded numbers show exactly that, which is why they don't bear the
gate). The ``serve_sharded`` benchmark records both audits for both
rulesets in BENCH_serve.json and ``benchmarks.run --scenario sharded``
gates the forward-audit ratio (throughput must cut collective bytes
>= 2x vs exact on tp4 and bound all-reduces at <= 2 per layer).
"""
from __future__ import annotations

import re
from typing import Dict

# ops counted as collectives; async -start forms count once, -done forms
# (same transfer, second half of the pair) are skipped
COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1,
}

# one HLO instruction: `%name = <result shape(s)> op-name(...`
_INSTR = re.compile(
    r"=\s+(?P<shape>[^=]*?)\s+(?P<op>[a-z0-9-]+)(?:-start)?\(")
# one array shape inside a result: `f32[2,4,64]` (layout suffix ignored)
_SHAPE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(shape_text: str) -> int:
    """Total bytes of every array in an HLO result shape (tuples sum)."""
    total = 0
    for dtype, dims in _SHAPE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_WHILE_BODY = re.compile(r"body=%?([\w.\-]+)")


def collective_stats(hlo_text: str, *, loop_repeats: int = 1) -> Dict:
    """Count collectives and their byte volumes in compiled HLO text.

    Returns ``{"counts": {op: n}, "bytes": {op: n}, "total_count": n,
    "total_bytes": n}``; byte volume is the op's RESULT shape size (for an
    all-gather: the gathered array; for an all-reduce: the reduced array)
    — a device-count-independent proxy for the data each collective moves.

    ``loop_repeats``: a ``lax.scan`` over a stacked layer period compiles
    to a while loop whose body appears ONCE in the HLO text but executes
    per repeat — collectives inside while-BODY computations are therefore
    counted ``loop_repeats`` times (the scan trip count; collectives in
    the entry computation, e.g. hoisted weight reshards and the logits
    gather, stay at 1). Default 1 = raw static instruction counts.
    """
    bodies = (set(_WHILE_BODY.findall(hlo_text))
              if loop_repeats != 1 else set())
    counts = {op: 0 for op in COLLECTIVE_OPS}
    nbytes = {op: 0 for op in COLLECTIVE_OPS}
    mult = 1
    for line in hlo_text.splitlines():
        if ((line.startswith("%") or line.startswith("ENTRY"))
                and line.rstrip().endswith("{")):
            # computation header — while bodies get the repeat multiplier
            name = line.split("(", 1)[0].replace("ENTRY", "").strip()
            mult = loop_repeats if name.lstrip("%") in bodies else 1
            continue
        m = _INSTR.search(line)
        if m is None:
            continue
        op = m.group("op")
        if op.endswith("-done"):
            continue
        if op.endswith("-start"):
            op = op[:-len("-start")]
        if op not in counts:
            continue
        counts[op] += mult
        nbytes[op] += mult * _shape_bytes(m.group("shape"))
    return {
        "counts": {k: v for k, v in counts.items() if v},
        "bytes": {k: v for k, v in nbytes.items() if v},
        "total_count": sum(counts.values()),
        "total_bytes": sum(nbytes.values()),
    }


def forward_hlo(params, cfg, mesh, ruleset: str, *, batch: int = 2,
                width: int = 5) -> str:
    """Compiled HLO of the decode-window forward with the params as
    EXPLICIT jit arguments placed by the ruleset's ``param_specs`` — the
    collective pattern a deployment with resident sharded weights pays
    (closure-constant params would let XLA fold the exact ruleset's
    gathers away; see module docstring)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops as _ops
    from repro.models import transformer
    from repro.sharding import specs as _specs

    pspecs = _specs.param_specs(params, mesh, serving=True, ruleset=ruleset)
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    def fwd(p, toks):
        logits, _, _ = transformer.forward(p, cfg, toks)
        return logits

    jitted = jax.jit(fwd,
                     in_shardings=(_specs.to_named(pspecs, mesh), repl),
                     out_shardings=repl)
    toks = jnp.zeros((batch, width), jnp.int32)
    with _ops.activation_mesh(mesh, ruleset):
        return jitted.lower(params, toks).compile().as_text()


def audit_forward(params, cfg, mesh, ruleset: str, **kw) -> Dict:
    """Collective stats of the params-as-arguments forward (the
    gate-bearing audit), plus the per-layer all-reduce bound. The layer
    stack lowers as a lax.scan, so per-layer collectives live in a while
    body — they are scaled by the scan trip count to get the true
    per-step bill (see ``collective_stats``)."""
    from repro.models import scan_plan
    repeats = max(1, scan_plan(cfg).n_repeats)
    stats = collective_stats(forward_hlo(params, cfg, mesh, ruleset, **kw),
                             loop_repeats=repeats)
    n_layers = max(1, cfg.num_layers)
    stats["n_layers"] = n_layers
    stats["all_reduces_per_layer"] = round(
        stats["counts"].get("all-reduce", 0) / n_layers, 4)
    stats["tp_ruleset"] = ruleset
    return stats


def audit_executor(ex, *, tree: bool = False,
                   any_sampled: bool = False) -> Dict:
    """Collective stats of one executor's fused decode step — DIAGNOSTIC
    only (closure-constant params let XLA fold exact's gathers, and the
    step contains several loops — draft scan, layer scans of two models —
    so static instruction counts are not scaled to executions)."""
    stats = collective_stats(ex.step_hlo(tree=tree, any_sampled=any_sampled))
    n_layers = max(1, ex.tc.num_layers)
    stats["n_layers"] = n_layers
    stats["all_reduces_per_layer"] = round(
        stats["counts"].get("all-reduce", 0) / n_layers, 4)
    stats["tp_ruleset"] = ex.tp_ruleset
    return stats


def audit_engine(engine, **kw) -> Dict:
    """Audit an Engine's (replica-0) executor step."""
    return audit_executor(engine.ex, **kw)


def main() -> int:
    """CLI: build a tiny engine per ruleset and print both audits."""
    import argparse
    import json
    import os
    import sys

    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--target", default="tiny-target")
    ap.add_argument("--draft", default="tiny-draft")
    ap.add_argument("--tp", type=int, default=4)
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--rulesets", default="exact,throughput")
    args = ap.parse_args()

    from repro.launch.mesh import ensure_host_devices, make_host_mesh
    ensure_host_devices(args.devices or args.tp)

    import jax
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving.config import EngineConfig
    from repro.serving.engine import Engine

    tc, dc = get_config(args.target), get_config(args.draft)
    tparams = init_params(jax.random.PRNGKey(0), tc)
    dparams = init_params(jax.random.PRNGKey(1), dc)

    out = {}
    for ruleset in args.rulesets.split(","):
        eng = Engine(tparams, tc, dparams, dc, config=EngineConfig(
            mode="pard", k=4, max_batch=2, max_len=256, kv_layout="paged",
            kv_block_size=16, mesh=make_host_mesh(model=args.tp, data=1),
            tp=args.tp, tp_ruleset=ruleset))
        out[ruleset] = audit_engine(eng)
    print(json.dumps(out, indent=2, sort_keys=True))
    if len(out) == 2:
        ex_b = out["exact"]["total_bytes"]
        th_b = out["throughput"]["total_bytes"]
        ratio = ex_b / max(1, th_b)
        print(f"# collective bytes exact/throughput = {ratio:.2f}x")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
