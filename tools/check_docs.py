"""Docs gate: README.md must document every serve-launcher flag.

  python tools/check_docs.py

Runs ``repro.launch.serve --help`` in a subprocess (PYTHONPATH=src is
added automatically), extracts every ``--flag`` the parser exposes, and
fails with the missing list unless each one is mentioned somewhere in
README.md — so a new serve flag cannot land without its documentation.
The CI ``docs-gate`` job runs this and then executes
``examples/quickstart.py`` (the README's 5-minute path) end-to-end.
"""
import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def serve_help() -> str:
    """The launcher's --help text, run exactly as the README tells users
    to run it (module mode, src/ on PYTHONPATH)."""
    env = dict(os.environ)
    src = os.path.join(ROOT, "src")
    old = env.get("PYTHONPATH")
    env["PYTHONPATH"] = f"{src}{os.pathsep}{old}" if old else src
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--help"],
        capture_output=True, text=True, env=env, cwd=ROOT)
    if out.returncode != 0:
        print(out.stderr, file=sys.stderr)
        raise SystemExit(f"serve --help exited {out.returncode}")
    return out.stdout


def main() -> int:
    """Exit 0 iff README.md mentions every serve flag; print the gaps."""
    flags = sorted(set(re.findall(r"--[a-z][a-z0-9-]*", serve_help())))
    # argparse's own plumbing, not engine surface
    flags = [f for f in flags if f != "--help"]
    with open(os.path.join(ROOT, "README.md")) as f:
        readme = f.read()
    missing = [f for f in flags if f not in readme]
    if missing:
        print(f"docs-gate: README.md does not mention these "
              f"repro.launch.serve flags: {', '.join(missing)}",
              file=sys.stderr)
        return 1
    print(f"docs-gate: all {len(flags)} serve flags documented in "
          f"README.md")
    return 0


if __name__ == "__main__":
    sys.exit(main())
