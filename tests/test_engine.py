"""Batched serving engine: continuous batching, slot reuse, mode equality,
EOS handling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.spec_decode import SpecDecoder
from repro.models import init_params
from repro.serving.engine import Engine


@pytest.fixture(scope="module")
def models():
    tc = get_config("tiny-target")
    dc = get_config("tiny-draft")
    tp = init_params(jax.random.PRNGKey(0), tc)
    dp = init_params(jax.random.PRNGKey(1), dc)
    return tc, tp, dc, dp


def _prompts(rng, n, vocab=512):
    return [rng.integers(0, vocab, size=int(l)).astype(np.int32)
            for l in rng.integers(4, 14, size=n)]


def test_single_request_matches_specdecoder(models):
    tc, tp, dc, dp = models
    rng = np.random.default_rng(1)
    p = rng.integers(0, 512, size=7).astype(np.int32)
    dec = SpecDecoder(tp, tc, dp, dc, k=4, max_len=256)
    ref = np.asarray(dec.generate_ar(jnp.asarray(p)[None], 16)[0][0])
    eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=1, max_len=256)
    eng.submit(p, 16)
    out = eng.run()[0]
    assert np.array_equal(ref, out.tokens)


def test_modes_agree_batched(models):
    """ar / vsd / pard must produce identical tokens per request under the
    same batching (lossless property at engine level)."""
    tc, tp, dc, dp = models
    rng = np.random.default_rng(2)
    prompts = _prompts(rng, 5)
    results = {}
    for mode in ("ar", "vsd", "pard"):
        eng = Engine(tp, tc, dp, dc, mode=mode, k=4, max_batch=2, max_len=256)
        rids = {eng.submit(p, 12): i for i, p in enumerate(prompts)}
        comps = eng.run()
        assert len(comps) == len(prompts)
        results[mode] = {rids[c.rid]: c.tokens for c in comps}
    for i in range(len(prompts)):
        assert np.array_equal(results["ar"][i], results["vsd"][i])
        assert np.array_equal(results["ar"][i], results["pard"][i])


def test_continuous_batching_slot_reuse(models):
    tc, tp, dc, dp = models
    rng = np.random.default_rng(3)
    prompts = _prompts(rng, 7)
    eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=2, max_len=256)
    for p in prompts:
        eng.submit(p, 10)
    comps = eng.run()
    assert len(comps) == 7
    for c in comps:
        assert c.generated == 10


def test_eos_stops_early(models):
    tc, tp, dc, dp = models
    rng = np.random.default_rng(4)
    p = rng.integers(0, 512, size=6).astype(np.int32)
    # find what the model actually generates, then use its 3rd token as EOS
    eng0 = Engine(tp, tc, dp, dc, mode="ar", k=4, max_batch=1, max_len=256)
    eng0.submit(p, 12)
    full = eng0.run()[0].tokens
    eos = int(full[len(p) + 2])
    eng = Engine(tp, tc, dp, dc, mode="ar", k=4, max_batch=1, max_len=256,
                 eos_id=eos)
    eng.submit(p, 12)
    out = eng.run()[0]
    assert out.generated <= 12
    assert eos in out.tokens[len(p):].tolist()


def test_hybrid_engine(models):
    jc = get_config("jamba-1.5-large-398b-smoke")
    jp = init_params(jax.random.PRNGKey(4), jc)
    rng = np.random.default_rng(5)
    p = rng.integers(0, jc.vocab_size, size=7).astype(np.int32)
    dec = SpecDecoder(jp, jc, jp, jc, k=4, max_len=128)
    ref = np.asarray(dec.generate_ar(jnp.asarray(p)[None], 10)[0][0])
    eng = Engine(jp, jc, jp, jc, mode="pard", k=4, max_batch=1, max_len=128)
    eng.submit(p, 10)
    out = eng.run()[0]
    assert np.array_equal(ref, out.tokens)
