"""Batched serving engine: continuous batching, slot reuse, mode equality,
EOS handling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.spec_decode import SpecDecoder
from repro.models import init_params
from repro.serving.engine import Engine


@pytest.fixture(scope="module")
def models():
    tc = get_config("tiny-target")
    dc = get_config("tiny-draft")
    tp = init_params(jax.random.PRNGKey(0), tc)
    dp = init_params(jax.random.PRNGKey(1), dc)
    return tc, tp, dc, dp


def _prompts(rng, n, vocab=512):
    return [rng.integers(0, vocab, size=int(n_tok)).astype(np.int32)
            for n_tok in rng.integers(4, 14, size=n)]


def test_single_request_matches_specdecoder(models):
    tc, tp, dc, dp = models
    rng = np.random.default_rng(1)
    p = rng.integers(0, 512, size=7).astype(np.int32)
    dec = SpecDecoder(tp, tc, dp, dc, k=4, max_len=256)
    ref = np.asarray(dec.generate_ar(jnp.asarray(p)[None], 16)[0][0])
    eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=1, max_len=256)
    eng.submit(p, 16)
    out = eng.run()[0]
    assert np.array_equal(ref, out.tokens)


def test_modes_agree_batched(models):
    """ar / vsd / pard must produce identical tokens per request under the
    same batching (lossless property at engine level)."""
    tc, tp, dc, dp = models
    rng = np.random.default_rng(2)
    prompts = _prompts(rng, 5)
    results = {}
    for mode in ("ar", "vsd", "pard"):
        eng = Engine(tp, tc, dp, dc, mode=mode, k=4, max_batch=2, max_len=256)
        rids = {eng.submit(p, 12): i for i, p in enumerate(prompts)}
        comps = eng.run()
        assert len(comps) == len(prompts)
        results[mode] = {rids[c.rid]: c.tokens for c in comps}
    for i in range(len(prompts)):
        assert np.array_equal(results["ar"][i], results["vsd"][i])
        assert np.array_equal(results["ar"][i], results["pard"][i])


def test_continuous_batching_slot_reuse(models):
    tc, tp, dc, dp = models
    rng = np.random.default_rng(3)
    prompts = _prompts(rng, 7)
    eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=2, max_len=256)
    for p in prompts:
        eng.submit(p, 10)
    comps = eng.run()
    assert len(comps) == 7
    for c in comps:
        assert c.generated == 10


def test_eos_stops_early(models):
    tc, tp, dc, dp = models
    rng = np.random.default_rng(4)
    p = rng.integers(0, 512, size=6).astype(np.int32)
    # find what the model actually generates, then use its 3rd token as EOS
    eng0 = Engine(tp, tc, dp, dc, mode="ar", k=4, max_batch=1, max_len=256)
    eng0.submit(p, 12)
    full = eng0.run()[0].tokens
    eos = int(full[len(p) + 2])
    eng = Engine(tp, tc, dp, dc, mode="ar", k=4, max_batch=1, max_len=256,
                 eos_id=eos)
    eng.submit(p, 12)
    out = eng.run()[0]
    assert out.generated <= 12
    assert eos in out.tokens[len(p):].tolist()


def test_paged_matches_contiguous(models):
    """Acceptance parity: greedy PARD outputs must be identical between the
    block-paged and the contiguous KV layout under the same ragged
    batching."""
    tc, tp, dc, dp = models
    rng = np.random.default_rng(6)
    prompts = _prompts(rng, 6)
    results = {}
    for layout in ("contiguous", "paged"):
        eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=2,
                     max_len=256, kv_layout=layout, kv_block_size=32)
        rids = {eng.submit(p, 12): i for i, p in enumerate(prompts)}
        comps = eng.run()
        assert len(comps) == len(prompts)
        results[layout] = {rids[c.rid]: c.tokens for c in comps}
    for i in range(len(prompts)):
        assert np.array_equal(results["contiguous"][i], results["paged"][i])


def test_paged_bytes_scale_with_fill(models):
    """Short-prompt workload at max_len=1024: the paged engine's peak KV
    bytes in use must stay under 50% of the contiguous pool (acceptance
    criterion — HBM tracks actual fill, not max_batch x max_len)."""
    tc, tp, dc, dp = models
    rng = np.random.default_rng(7)
    prompts = _prompts(rng, 5)
    cont = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=2,
                  max_len=1024, kv_layout="contiguous")
    paged = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=2,
                   max_len=1024, kv_layout="paged", kv_block_size=64)
    for p in prompts:
        cont.submit(p, 16)
        paged.submit(p, 16)
    ref = {c.rid: c.tokens for c in cont.run()}
    out = {c.rid: c.tokens for c in paged.run()}
    for rid in ref:
        assert np.array_equal(ref[rid], out[rid])
    assert paged.peak_kv_bytes_in_use > 0
    assert paged.peak_kv_bytes_in_use < 0.5 * cont.kv_capacity_bytes()
    assert paged.kv_bytes_in_use() == 0          # everything released


def test_paged_ragged_arrival_order(models):
    """More ragged requests than slots, arriving in one burst: every
    completion must match its own single-request greedy reference (no
    cross-request KV leakage through the shared pool)."""
    tc, tp, dc, dp = models
    rng = np.random.default_rng(8)
    prompts = _prompts(rng, 5)
    max_news = [9, 14, 7, 12, 10]
    refs = {}
    for i, (p, mn) in enumerate(zip(prompts, max_news)):
        dec = SpecDecoder(tp, tc, dp, dc, k=4, max_len=256)
        refs[i] = np.asarray(dec.generate_ar(jnp.asarray(p)[None], mn)[0][0])
    eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=2, max_len=256,
                 kv_layout="paged", kv_block_size=32)
    rids = {eng.submit(p, mn): i for i, (p, mn)
            in enumerate(zip(prompts, max_news))}
    comps = eng.run()
    assert len(comps) == len(prompts)
    for c in comps:
        assert np.array_equal(refs[rids[c.rid]], c.tokens)


def test_paged_eos_mid_verify_window(models):
    """EOS produced inside a speculative verify window (mode=pard commits
    up to K+1 tokens per step) must stop the request — and the tokens up to
    and including EOS must still match the AR reference."""
    tc, tp, dc, dp = models
    rng = np.random.default_rng(9)
    p = rng.integers(0, 512, size=6).astype(np.int32)
    dec = SpecDecoder(tp, tc, dp, dc, k=4, max_len=256)
    full = np.asarray(dec.generate_ar(jnp.asarray(p)[None], 16)[0][0])
    eos = int(full[len(p) + 5])                  # mid-window position
    eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=1, max_len=256,
                 eos_id=eos, kv_layout="paged", kv_block_size=32)
    eng.submit(p, 16)
    out = eng.run()[0]
    assert out.generated <= 16
    gen = out.tokens[len(p):]
    assert eos in gen.tolist()
    cut = gen.tolist().index(eos) + 1
    assert np.array_equal(out.tokens[:len(p) + cut], full[:len(p) + cut])


def test_paged_slot_reuse_reallocates_blocks(models):
    """Continuous batching through a deliberately tight pool: freed slots'
    blocks MUST be handed to later requests (the pool is too small to serve
    them otherwise), old KV is never attended (outputs match per-request
    references), and admission backpressure never deadlocks."""
    tc, tp, dc, dp = models
    rng = np.random.default_rng(10)
    prompts = _prompts(rng, 6)
    need_blocks = max(len(p) + 10 + 2 * 4 + 2 for p in prompts) // 32 + 1
    # room for ~2 concurrent requests; 6 requests => reuse is forced
    eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=2, max_len=256,
                 kv_layout="paged", kv_block_size=32,
                 kv_num_blocks=1 + 2 * need_blocks)
    allocs = []

    def spy(slot, n, _orig=eng.alloc.allocate):
        _orig(slot, n)
        allocs.append(list(eng.alloc.owned[slot]))

    eng.alloc.allocate = spy
    rids = {eng.submit(p, 10): i for i, p in enumerate(prompts)}
    comps = eng.run()
    assert len(comps) == len(prompts)
    seen = [b for al in allocs for b in al]
    assert len(seen) > len(set(seen))            # some block served >1 request
    for c in comps:
        i = rids[c.rid]
        dec = SpecDecoder(tp, tc, dp, dc, k=4, max_len=256)
        ref = np.asarray(dec.generate_ar(
            jnp.asarray(prompts[i])[None], 10)[0][0])
        assert np.array_equal(ref, c.tokens)
    assert eng.alloc.blocks_in_use == 0


def test_submit_rejects_request_exceeding_max_len(models):
    """Oversized requests must fail at submit() with a real error — past
    admission they would outgrow their cache rows/blocks and silently
    attend garbage."""
    tc, tp, dc, dp = models
    eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=1, max_len=64)
    with pytest.raises(ValueError, match="cache positions"):
        eng.submit(np.arange(40, dtype=np.int32) % 512, 32)  # 40+32+10 > 64
    with pytest.raises(ValueError):
        eng.submit(np.asarray([1], np.int32), 4)             # prompt < 2


def test_paged_oversized_request_fails_loudly(models):
    """A request that cannot fit the pool even when it is empty must raise
    instead of spinning on admission backpressure forever."""
    tc, tp, dc, dp = models
    rng = np.random.default_rng(11)
    p = rng.integers(0, 512, size=16).astype(np.int32)
    eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=2, max_len=512,
                 kv_layout="paged", kv_block_size=32, kv_num_blocks=2)
    eng.submit(p, 24)                            # needs 2 blocks; pool has 1
    with pytest.raises(RuntimeError, match="KV blocks"):
        eng.run()


def test_hybrid_engine(models):
    jc = get_config("jamba-1.5-large-398b-smoke")
    jp = init_params(jax.random.PRNGKey(4), jc)
    rng = np.random.default_rng(5)
    p = rng.integers(0, jc.vocab_size, size=7).astype(np.int32)
    dec = SpecDecoder(jp, jc, jp, jc, k=4, max_len=128)
    ref = np.asarray(dec.generate_ar(jnp.asarray(p)[None], 10)[0][0])
    eng = Engine(jp, jc, jp, jc, mode="pard", k=4, max_batch=1, max_len=128)
    eng.submit(p, 10)
    out = eng.run()[0]
    assert np.array_equal(ref, out.tokens)
