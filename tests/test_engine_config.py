"""Typed config surface (serving/config.py): EngineConfig construction and
validation, the legacy-kwargs deprecation shim, SamplingParams equivalence
with the loose submit keywords, and per-request seeds."""
import argparse
import warnings

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serving.config import EngineConfig, SamplingParams
from repro.serving.engine import Engine


@pytest.fixture(scope="module")
def models():
    tc = get_config("tiny-target")
    dc = get_config("tiny-draft")
    tp = init_params(jax.random.PRNGKey(0), tc)
    dp = init_params(jax.random.PRNGKey(1), dc)
    return tc, tp, dc, dp


def _prompts(rng, n, vocab=512):
    return [rng.integers(0, vocab, size=int(n_tok)).astype(np.int32)
            for n_tok in rng.integers(4, 14, size=n)]


# ---------------------------------------------------------------- config
def test_config_validation():
    with pytest.raises(AssertionError):
        EngineConfig(mode="beam")
    with pytest.raises(AssertionError):
        EngineConfig(kv_layout="ragged")
    with pytest.raises(AssertionError, match="kv_dtype"):
        EngineConfig(kv_dtype="int4")
    with pytest.raises(AssertionError, match="paged"):
        EngineConfig(prefix_cache=True, kv_layout="contiguous")
    with pytest.raises(AssertionError, match="PARD"):
        EngineConfig(mode="ar", tree=(2, 2, 1))
    with pytest.raises(ValueError, match="max_batch"):
        EngineConfig(max_batch=0)
    with pytest.raises(ValueError, match="temperature"):
        EngineConfig(temperature=-0.5)
    with pytest.raises(ValueError, match="tree_ewma"):
        EngineConfig(tree_ewma=0.0)
    with pytest.raises(ValueError, match="tp"):
        EngineConfig(tp=0)
    with pytest.raises(ValueError, match="dp"):
        EngineConfig(dp=0)


def test_config_dp_device_validation():
    """dp * tp must fit the available devices, and an explicit mesh must
    carry a 'data' axis of exactly dp replicas."""
    import jax

    n = jax.device_count()
    with pytest.raises(ValueError, match="devices"):
        EngineConfig(dp=n + 1)                  # auto-mesh can't fit
    one = np.array(jax.devices()[:1]).reshape(1)
    with pytest.raises(ValueError, match="data"):
        EngineConfig(dp=2, mesh=jax.sharding.Mesh(one, ("model",)))
    mesh11 = jax.sharding.Mesh(one.reshape(1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="data"):
        EngineConfig(dp=2, mesh=mesh11)         # axis size 1 != dp=2
    assert EngineConfig(dp=1, mesh=mesh11).dp == 1


@pytest.mark.parametrize("layout", ["paged", "contiguous"])
def test_dp1_identical_to_no_dp(models, layout):
    """dp=1 (explicit single-replica mesh) is the historical single-engine
    path bit-for-bit, in both KV layouts."""
    import jax

    tc, tp, dc, dp = models
    rng = np.random.default_rng(14)
    prompts = _prompts(rng, 4)
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    out = {}
    for name, mesh_arg in (("no_dp", None), ("dp1", mesh)):
        cfg = EngineConfig(mode="pard", k=4, max_batch=2, max_len=256,
                           kv_layout=layout, kv_block_size=16, seed=5,
                           dp=1, mesh=mesh_arg)
        eng = Engine(tp, tc, dp, dc, config=cfg)
        rids = {eng.submit(p, 12): i for i, p in enumerate(prompts)}
        out[name] = {rids[c.rid]: c.tokens for c in eng.run()}
    for i in range(len(prompts)):
        assert np.array_equal(out["no_dp"][i], out["dp1"][i])


def test_config_adaptive_default_bank():
    from repro.core.spec_decode import TemplateBank
    cfg = EngineConfig(adaptive_tree=True, k=4)
    assert isinstance(cfg.tree, TemplateBank)
    assert cfg.tree.max_depth == 4


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="max_new"):
        SamplingParams(max_new=0)
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-1.0)
    with pytest.raises(ValueError, match="max_new"):
        SamplingParams().merged(None)
    with pytest.raises(ValueError, match="conflicting"):
        SamplingParams(max_new=8).merged(9)
    assert SamplingParams(max_new=8).merged(8).max_new == 8
    assert SamplingParams().merged(5).max_new == 5


def test_from_args_round_trip():
    """The serve launcher's argparse namespace maps onto the same config as
    direct construction; string trees normalise to TreeTemplate."""
    ns = argparse.Namespace(
        mode="pard", k=4, max_batch=2, max_len=256, temperature=0.7,
        seed=3, kv_layout="contiguous", kv_block_size=32, kv_dtype="bf16",
        tree="2,2,1", adaptive_tree=False, prefix_cache=False,
        pipelined=True)
    cfg = EngineConfig.from_args(ns)
    ref = EngineConfig(mode="pard", k=4, max_batch=2, max_len=256,
                       temperature=0.7, seed=3, kv_layout="contiguous",
                       kv_block_size=32, tree=(2, 2, 1), pipelined=True)
    assert cfg.max_batch == ref.max_batch and cfg.pipelined
    assert cfg.temperature == ref.temperature and cfg.seed == ref.seed
    # both normalise to a one-template bank of the same shape
    assert cfg.tree is not None
    assert [tuple(t.branching) for t in cfg.tree.templates] \
        == [tuple(t.branching) for t in ref.tree.templates]
    # partial namespaces fall back to field defaults
    sparse = EngineConfig.from_args(argparse.Namespace(mode="ar"))
    assert sparse.mode == "ar" and sparse.max_batch == 4


# ------------------------------------------------------------ deprecation
def test_legacy_kwargs_warn_and_match_config(models):
    tc, tp, dc, dp = models
    rng = np.random.default_rng(11)
    prompts = _prompts(rng, 3)

    with pytest.warns(DeprecationWarning, match="EngineConfig"):
        legacy = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=2,
                        max_len=256, kv_block_size=16)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)  # none expected
        cfg = EngineConfig(mode="pard", k=4, max_batch=2, max_len=256,
                           kv_block_size=16)
        typed = Engine(tp, tc, dp, dc, config=cfg)

    out = {}
    for name, eng in (("legacy", legacy), ("typed", typed)):
        rids = {eng.submit(p, 12): i for i, p in enumerate(prompts)}
        out[name] = {rids[c.rid]: c.tokens for c in eng.run()}
    for i in range(len(prompts)):
        assert np.array_equal(out["legacy"][i], out["typed"][i])


def test_config_plus_legacy_kwargs_rejected(models):
    tc, tp, dc, dp = models
    with pytest.raises(TypeError, match="not both"):
        Engine(tp, tc, dp, dc, config=EngineConfig(), max_batch=2)


def test_unknown_kwarg_rejected(models):
    tc, tp, dc, dp = models
    with pytest.raises(TypeError), pytest.warns(DeprecationWarning):
        Engine(tp, tc, dp, dc, beam_width=4)


# --------------------------------------------------------- SamplingParams
def test_sampling_params_equivalent_to_kwargs(models):
    """A mixed greedy+sampled batch submitted via SamplingParams produces
    exactly the tokens of the loose-kwargs path."""
    tc, tp, dc, dp = models
    rng = np.random.default_rng(12)
    prompts = _prompts(rng, 4)
    temps = [0.0, 0.8, 0.0, 0.9]
    cfg = EngineConfig(mode="pard", k=4, max_batch=2, max_len=256,
                       kv_block_size=16, seed=5)

    eng_kw = Engine(tp, tc, dp, dc, config=cfg)
    rids_kw = {eng_kw.submit(p, 12, temperature=t): i
               for i, (p, t) in enumerate(zip(prompts, temps))}
    out_kw = {rids_kw[c.rid]: c.tokens for c in eng_kw.run()}

    eng_sp = Engine(tp, tc, dp, dc, config=cfg)
    rids_sp = {eng_sp.submit(p, params=SamplingParams(max_new=12,
                                                      temperature=t)): i
               for i, (p, t) in enumerate(zip(prompts, temps))}
    out_sp = {rids_sp[c.rid]: c.tokens for c in eng_sp.run()}

    assert len(out_kw) == len(out_sp) == len(prompts)
    for i in range(len(prompts)):
        assert np.array_equal(out_kw[i], out_sp[i])


def test_params_with_loose_kwargs_rejected(models):
    tc, tp, dc, dp = models
    eng = Engine(tp, tc, dp, dc,
                 config=EngineConfig(max_batch=1, max_len=256))
    with pytest.raises(ValueError, match="SamplingParams"):
        eng.submit(np.arange(4, dtype=np.int32), temperature=0.5,
                   params=SamplingParams(max_new=8))


def test_per_request_seed_decouples_from_engine_seed(models):
    """SamplingParams.seed pins a sampled request's stream to the request:
    the same seed reproduces the same tokens under DIFFERENT engine seeds,
    while the engine-derived default stream does not."""
    tc, tp, dc, dp = models
    rng = np.random.default_rng(13)
    p = rng.integers(0, 512, size=8).astype(np.int32)

    def run(engine_seed, req_seed):
        cfg = EngineConfig(mode="pard", k=4, max_batch=1, max_len=256,
                           seed=engine_seed)
        eng = Engine(tp, tc, dp, dc, config=cfg)
        eng.submit(p, params=SamplingParams(max_new=16, temperature=0.9,
                                            seed=req_seed))
        return eng.run()[0].tokens

    pinned = [run(s, req_seed=123) for s in (0, 1, 2)]
    assert all(np.array_equal(pinned[0], t) for t in pinned[1:])
    floating = [run(s, req_seed=None) for s in (0, 1)]
    assert not np.array_equal(floating[0], floating[1])
