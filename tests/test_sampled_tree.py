"""Sampled (temperature > 0) tree acceptance: multi-round sibling rejection
sampling distribution preservation, per-request temperature mixing, seeded
determinism across calls / KV layouts, per-round accept accounting, and the
slow statistical CI gate comparing committed-token frequencies against AR
sampling (the ``sampled-gate`` job runs ``-m slow``)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import acceptance
from repro.core.spec_decode import SpecDecoder, TreeTemplate
from repro.models import forward, init_params
from repro.serving.engine import Engine

TEMP = 0.8


@pytest.fixture(scope="module")
def tiny():
    tc = get_config("tiny-target")
    dc = get_config("tiny-draft")
    tp = init_params(jax.random.PRNGKey(0), tc)
    dp = init_params(jax.random.PRNGKey(1), dc)
    return tc, tp, dc, dp


def _prompt(vocab, b=2, p=8, seed=2):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, p), 0, vocab)


def _ragged_prompts(n, seed=12):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 512, size=int(t)).astype(np.int32)
            for t in rng.integers(4, 14, size=n)]


@pytest.mark.parametrize("branching", [(3,), (2, 2)])
def test_multi_round_accept_preserves_distribution(branching):
    """The RRS identity at the acceptance-function level: for ANY draft q,
    the first committed token of ``sampled_tree_accept`` is distributed
    exactly as the target p — the accept rounds, the renormalised residual
    and the correction sample must all agree for this to hold."""
    V = 8
    key = jax.random.PRNGKey(0)
    p = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (V,)) * 1.5)
    q = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 2), (V,)) * 1.5)
    tree = TreeTemplate.from_branching(branching)
    s, d = tree.num_slots, tree.max_depth

    @jax.jit
    def one(rng):
        r1, r2 = jax.random.split(rng)
        props = jax.random.categorical(
            r1, jnp.log(q), shape=(1, tree.num_nodes)).astype(jnp.int32)
        a, toks, _, commit, _ = acceptance.sampled_tree_accept(
            tree, jnp.broadcast_to(p, (1, s, V)),
            jnp.broadcast_to(q, (1, d, V)), props, r2[None])
        return jnp.where(a[0] >= 1, toks[0, 0], commit[0])

    trials = 4000
    firsts = np.asarray(jax.vmap(one)(
        jax.random.split(jax.random.PRNGKey(7), trials)))
    emp = np.bincount(firsts, minlength=V) / trials
    tv = 0.5 * np.abs(emp - np.asarray(p)).sum()
    assert tv < 0.05, f"TV distance {tv} (emp={emp}, p={np.asarray(p)})"


def test_sampled_tree_seeded_determinism(tiny):
    """Same seed + same prompt => bit-identical sampled-tree output across
    two generate_spec calls; a different seed must change something."""
    tc, tp, dc, dp = tiny
    dec = SpecDecoder(tp, tc, dp, dc, max_len=256, temperature=TEMP,
                      tree=TreeTemplate.from_branching((2, 2, 2, 1)))
    prompt = _prompt(tc.vocab_size)
    out1, st1 = dec.generate_spec(prompt, 24, mode="pard", seed=3)
    out2, _ = dec.generate_spec(prompt, 24, mode="pard", seed=3)
    out3, _ = dec.generate_spec(prompt, 24, mode="pard", seed=4)
    assert bool(jnp.all(out1 == out2))
    assert not bool(jnp.all(out1 == out3))
    assert st1.tokens_generated == 24 * prompt.shape[0]
    # sampled tokens never escape the real vocab into the padded tail
    assert int(jnp.max(out1)) < tc.vocab_size


def test_sampled_tree_layouts_agree(tiny):
    """Sampled tree decoding commits identical tokens under the contiguous
    and block-paged KV layouts: per-request (seed, rid) keys make the
    sampling trajectory independent of the cache layout."""
    tc, tp, dc, dp = tiny
    prompts = _ragged_prompts(4)
    results = {}
    for layout in ("contiguous", "paged"):
        eng = Engine(tp, tc, tp, tc, mode="pard", max_batch=2, max_len=256,
                     temperature=TEMP, seed=7, kv_layout=layout,
                     kv_block_size=32,
                     tree=TreeTemplate.from_branching((2, 2, 2, 1)))
        rids = {eng.submit(p, 12): i for i, p in enumerate(prompts)}
        results[layout] = {rids[c.rid]: c.tokens for c in eng.run()}
    for i in range(len(prompts)):
        assert np.array_equal(results["contiguous"][i], results["paged"][i])


def test_mixed_batch_greedy_rows_exact(tiny):
    """One batch mixes greedy and sampled requests: greedy rows must stay
    token-identical to their AR reference even while batched with sampled
    rows (per-row acceptance selection), and sampled rows must actually
    sample (differ from the greedy AR sequence)."""
    tc, tp, dc, dp = tiny
    prompts = _ragged_prompts(4)
    refs = {}
    for i, p in enumerate(prompts):
        dec = SpecDecoder(tp, tc, tp, tc, k=4, max_len=256)
        refs[i] = np.asarray(dec.generate_ar(jnp.asarray(p)[None], 12)[0][0])
    eng = Engine(tp, tc, tp, tc, mode="pard", max_batch=2, max_len=256,
                 temperature=TEMP, seed=7, kv_layout="paged",
                 kv_block_size=32,
                 tree=TreeTemplate.from_branching((2, 2, 2, 1)))
    rids = {}
    for i, p in enumerate(prompts):
        t = 0.0 if i % 2 == 0 else None        # None = engine default (0.8)
        rids[eng.submit(p, 12, temperature=t)] = i
    comps = {rids[c.rid]: c.tokens for c in eng.run()}
    for i in range(len(prompts)):
        if i % 2 == 0:
            assert np.array_equal(refs[i], comps[i])
    assert any(not np.array_equal(refs[i], comps[i])
               for i in range(len(prompts)) if i % 2 == 1)


def test_flat_spec_per_request_temperature(tiny):
    """The flat (non-tree) PARD path honours per-request temperature too:
    greedy rows exact vs AR, sampled rows deterministic per seed."""
    tc, tp, dc, dp = tiny
    prompts = _ragged_prompts(3, seed=5)
    refs = [np.asarray(SpecDecoder(tp, tc, tp, tc, k=4, max_len=256)
                       .generate_ar(jnp.asarray(p)[None], 10)[0][0])
            for p in prompts]

    def run():
        eng = Engine(tp, tc, tp, tc, mode="pard", k=4, max_batch=2,
                     max_len=256, temperature=TEMP, seed=9,
                     kv_layout="paged", kv_block_size=32)
        rids = {}
        for i, p in enumerate(prompts):
            t = 0.0 if i == 0 else None
            rids[eng.submit(p, 10, temperature=t)] = i
        return {rids[c.rid]: c.tokens for c in eng.run()}

    first, second = run(), run()
    assert np.array_equal(refs[0], first[0])           # greedy row exact
    for i in range(len(prompts)):                      # seeded determinism
        assert np.array_equal(first[i], second[i])
    assert not np.array_equal(refs[1], first[1])       # sampled row samples


def test_round_hist_accounting(tiny):
    """Per-round accept counts: every accepted depth is attributed to
    exactly one sibling rank, so round_hist sums to the total accepted
    tokens (greedy and sampled alike)."""
    tc, tp, _, _ = tiny
    for temp in (0.0, TEMP):
        dec = SpecDecoder(tp, tc, tp, tc, max_len=512, temperature=temp,
                          tree=TreeTemplate.from_branching((2, 2, 2, 1)))
        prompt = _prompt(tc.vocab_size, b=4, p=10)
        _, stats = dec.generate_spec(prompt, 40, mode="pard")
        assert stats.round_hist.shape == (2,)          # max branching
        assert int(stats.round_hist.sum()) == int(
            np.asarray(stats.accept_hist).sum())
        assert int(stats.round_hist.sum()) > 0         # self-draft accepts


@pytest.mark.slow
@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_sampled_tree_matches_ar_distribution(tiny, kv_dtype):
    """The statistical CI gate: N seeded sampled-tree runs on the tiny
    config vs AR sampling with the same seeds — run under the bf16 cache
    AND the quantized int8 cache (the quantized-KV quality gate's sampled
    half: rejection-sampling correctness is measured WITHIN a kv_dtype,
    tree and AR sharing the same cache encoding). Two checks (thresholds
    calibrated so a correct implementation passes with wide margin while a
    greedy-only or unnormalised-residual implementation fails):

      * pooled committed-token TV distance tree-vs-AR < 0.5 (fair runs
        measure ~0.32 — the two-empirical-histogram noise floor at this
        sample count — while a greedy tree measures ~0.91);
      * first-committed-token chi-squared against the EXACT target
        distribution, 10 probability-quantile buckets per row, summed over
        rows: < 68.0 = chi2_0.999(dof=36). Correct runs measure ~31 (the
        AR control is asserted under the same threshold, so a miscalibrated
        threshold flags itself); a greedy tree measures ~1750.
    """
    tc, tp, dc, dp = tiny
    B, P, NEW, SEEDS = 4, 8, 8, 40
    prompt = _prompt(tc.vocab_size, b=B, p=P)
    tree_dec = SpecDecoder(tp, tc, dp, dc, max_len=256, temperature=TEMP,
                           tree=TreeTemplate.from_branching((2, 2, 2, 1)),
                           kv_dtype=kv_dtype)
    ar_dec = SpecDecoder(tp, tc, dp, dc, k=4, max_len=256, temperature=TEMP,
                         kv_dtype=kv_dtype)

    logits, _, _ = forward(tp, tc, prompt)
    p_exact = np.asarray(jax.nn.softmax(
        logits[:, -1].astype(jnp.float32) / TEMP, axis=-1))
    V = p_exact.shape[-1]                       # padded vocab

    tree_tok, ar_tok = [], []
    first_tree = np.zeros((B, V))
    first_ar = np.zeros((B, V))
    for s in range(SEEDS):
        out = np.asarray(
            tree_dec.generate_spec(prompt, NEW, mode="pard", seed=s)[0])
        tree_tok.append(out[:, P:])
        np.add.at(first_tree, (np.arange(B), out[:, P]), 1)
        out = np.asarray(ar_dec.generate_ar(prompt, NEW, seed=s)[0])
        ar_tok.append(out[:, P:])
        np.add.at(first_ar, (np.arange(B), out[:, P]), 1)

    def hist(arr):
        h = np.bincount(np.asarray(arr).ravel(), minlength=V).astype(float)
        return h / h.sum()

    tv = 0.5 * np.abs(hist(np.concatenate(tree_tok))
                      - hist(np.concatenate(ar_tok))).sum()
    assert tv < 0.5, f"pooled committed-token TV {tv:.3f} >= 0.5"

    def chi2(firsts, nb=10):
        tot = 0.0
        for b in range(B):
            order = np.argsort(-p_exact[b])
            bucket = np.minimum(
                (np.cumsum(p_exact[b][order]) * nb).astype(int), nb - 1)
            bid = np.zeros(V, int)
            bid[order] = bucket
            obs = np.zeros(nb)
            exp = np.zeros(nb)
            np.add.at(obs, bid, firsts[b])
            np.add.at(exp, bid, p_exact[b] * SEEDS)
            tot += float((((obs - exp) ** 2) / np.maximum(exp, 1e-9)).sum())
        return tot

    c_tree, c_ar = chi2(first_tree), chi2(first_ar)
    assert c_ar < 68.0, f"AR control chi2 {c_ar:.1f} — threshold miscalibrated"
    assert c_tree < 68.0, f"sampled-tree first-token chi2 {c_tree:.1f} >= 68"
