"""Baseline implementations: EAGLE-style head, MoE dispatch equivalence,
temperature-mode engine, launcher-level pieces."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_params


def test_eagle_lossless_untrained():
    """Target-dependent EAGLE baseline must also be lossless under greedy
    verification, even with a random head."""
    from repro.core.eagle import EagleDecoder, init_eagle
    from repro.core.spec_decode import SpecDecoder
    tc = get_config("tiny-target")
    tp = init_params(jax.random.PRNGKey(0), tc)
    ep = init_eagle(jax.random.PRNGKey(9), tc)
    prompt = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                                tc.vocab_size)
    sd = SpecDecoder(tp, tc, tp, tc, k=4, max_len=128)
    ar, _ = sd.generate_ar(prompt, 16)
    out, st = EagleDecoder(tp, tc, ep, k=4, max_len=128).generate(prompt, 16)
    assert bool(jnp.all(ar == out))
    assert st.draft_forwards == 4 * st.iterations   # EAGLE drafts K times


def test_eagle_loss_decreases():
    from repro.core.eagle import eagle_loss, init_eagle
    from repro.training.optimizer import AdamW
    tc = get_config("tiny-target")
    tp = init_params(jax.random.PRNGKey(0), tc)
    ep = init_eagle(jax.random.PRNGKey(9), tc)
    opt = AdamW(lr=3e-3)
    state = opt.init(ep)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0,
                                tc.vocab_size)

    @jax.jit
    def step(ep, state):
        (loss, _), g = jax.value_and_grad(
            lambda e: eagle_loss(e, tp, tc, tokens), has_aux=True)(ep)
        ep, state, _ = opt.update(g, state, ep)
        return ep, state, loss

    first = None
    for i in range(25):
        ep, state, loss = step(ep, state)
        if first is None:
            first = float(loss)
    assert float(loss) < first


def test_moe_grouped_dispatch_matches_dense_reference():
    """The grouped one-hot dispatch must equal the direct per-token
    computation sum_k gate_k * expert_{idx_k}(x) when nothing is dropped."""
    from repro.models.layers import init_moe, moe_apply
    cfg = get_config("granite-moe-3b-a800m").reduced()
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32)
    y = moe_apply(params, x, cfg, dropless=True)

    # dense reference: run every expert on every token
    logits = jnp.einsum("btd,de->bte", x, params["router"])
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gv, gi = jax.lax.top_k(probs, cfg.moe_top_k)
    gv = gv / jnp.sum(gv, -1, keepdims=True)
    hi = jnp.einsum("btd,edf->btef", x, params["we_i"])
    hg = jnp.einsum("btd,edf->btef", x, params["we_g"])
    ye = jnp.einsum("btef,efd->bted", jax.nn.silu(hg) * hi, params["we_o"])
    want = jnp.zeros_like(x)
    for k in range(cfg.moe_top_k):
        sel = jnp.take_along_axis(ye, gi[..., k, None, None], axis=2)[:, :, 0]
        want = want + gv[..., k, None].astype(x.dtype) * sel
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=2e-4, rtol=2e-3)


def test_engine_temperature_mode_runs():
    from repro.serving.engine import Engine
    tc = get_config("tiny-target")
    dc = get_config("tiny-draft")
    tp = init_params(jax.random.PRNGKey(0), tc)
    dp = init_params(jax.random.PRNGKey(1), dc)
    eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=2, max_len=128,
                 temperature=0.8, seed=3)
    rng = np.random.default_rng(0)
    for _ in range(3):
        eng.submit(rng.integers(0, 512, size=6).astype(np.int32), 8)
    comps = eng.run()
    assert len(comps) == 3
    for c in comps:
        assert c.generated == 8
        assert np.all(c.tokens < tc.vocab_size)   # mask/pad ids never emitted


def test_input_specs_cover_all_assigned():
    """Every (arch x shape) either yields specs or is a documented skip."""
    from repro.configs import ASSIGNED
    from repro.launch.steps import SHAPES, input_specs
    from repro.launch.dryrun import _skip_reason
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for shape in SHAPES:
            if _skip_reason(arch, shape):
                continue
            ins = input_specs(cfg, shape)
            assert "batch" in ins
            for leaf in jax.tree.leaves(ins):
                assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_roofline_collective_census_parses():
    from repro.launch.roofline import collective_census
    hlo = """
      %ag = bf16[64,128]{1,0} all-gather(%x), replica_groups={}
      %ar.1 = f32[1024]{0} all-reduce(%y), to_apply=%sum
      %rs = (f32[16], f32[16]) reduce-scatter(%a, %b), dimensions={0}
      %other = bf16[8,8]{1,0} dot(%p, %q)
    """
    c = collective_census(hlo)
    assert c["all-gather"]["count"] == 1
    assert c["all-gather"]["bytes"] == 64 * 128 * 2
    assert c["all-reduce"]["bytes"] == 4096
    assert c["reduce-scatter"]["bytes"] == 128
    assert c["total_bytes"] == 64 * 128 * 2 + 4096 + 128


def test_model_flops_sane():
    """2·N_active per token should be within 2x of actual param count x2
    for a dense model."""
    from repro.launch.roofline import model_flops_per_token
    from repro.launch.steps import param_shapes
    cfg = get_config("llama3.1-8b")
    est = model_flops_per_token(cfg) / 2.0
    sds = param_shapes(cfg)
    n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(sds))
    assert 0.5 < est / n < 1.5
