"""End-to-end backend equivalence: the model forward with the Pallas
attention backend (interpret mode on CPU) must match the XLA reference path
— the integration-level counterpart of the per-kernel oracle tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import forward, init_caches, init_params
from repro.models.attention import set_attention_backend


@pytest.fixture(autouse=True)
def _restore_backend():
    yield
    set_attention_backend("xla")


@pytest.mark.parametrize("arch", ["tiny-target", "gemma2-27b"])
def test_forward_backend_equivalence(arch):
    cfg = get_config(arch + "-smoke") if arch != "tiny-target" \
        else get_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                cfg.vocab_size)
    set_attention_backend("xla")
    ref, _, _ = forward(params, cfg, tokens, dtype=jnp.float32)
    set_attention_backend("pallas")
    out, _, _ = forward(params, cfg, tokens, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)


def test_decode_backend_equivalence():
    cfg = get_config("tiny-target")
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)

    def run():
        caches = init_caches(cfg, 2, 64, dtype=jnp.float32)
        _, caches, _ = forward(params, cfg, tokens, caches=caches,
                               cache_pos=jnp.zeros(2, jnp.int32),
                               dtype=jnp.float32)
        lg, _, _ = forward(params, cfg, tokens[:, :1], caches=caches,
                           cache_pos=jnp.full(2, 16, jnp.int32),
                           dtype=jnp.float32)
        return lg

    set_attention_backend("xla")
    ref = run()
    set_attention_backend("pallas")
    out = run()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)


def test_paged_decode_backend_equivalence():
    """Paged-cache decode through the model forward: the Pallas paged
    kernel (block-table indirection) must match the XLA gather path."""
    from repro.serving import kv_pool
    cfg = get_config("tiny-target")
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    tables = jnp.asarray([[1, 3, 5, 7], [2, 4, 6, 8]], jnp.int32)

    def run():
        caches = kv_pool.init_paged_caches(cfg, 2, num_blocks=9, block_size=8,
                                           dtype=jnp.float32)
        _, caches, _ = forward(params, cfg, tokens, caches=caches,
                               cache_pos=jnp.zeros(2, jnp.int32),
                               block_tables=tables, kv_block_size=8,
                               dtype=jnp.float32)
        lg, _, _ = forward(params, cfg, tokens[:, :1], caches=caches,
                           cache_pos=jnp.full(2, 16, jnp.int32),
                           block_tables=tables, kv_block_size=8,
                           dtype=jnp.float32)
        return lg

    set_attention_backend("xla")
    ref = run()
    set_attention_backend("pallas")
    out = run()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)
