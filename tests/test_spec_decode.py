"""Speculative decoding system tests: losslessness (the paper's core
guarantee), acceptance accounting, speculative sampling distribution
preservation, and SSM/hybrid rollback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.spec_decode import SpecDecoder
from repro.models import init_params


@pytest.fixture(scope="module")
def tiny():
    tc = get_config("tiny-target")
    dc = get_config("tiny-draft")
    tp = init_params(jax.random.PRNGKey(0), tc)
    dp = init_params(jax.random.PRNGKey(1), dc)
    return tc, tp, dc, dp


PROMPT = None


def _prompt(vocab, b=2, p=8, seed=2):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, p), 0, vocab)


@pytest.mark.parametrize("mode", ["pard", "vsd"])
def test_greedy_lossless_random_draft(tiny, mode):
    """Even a totally uncorrelated draft must give bit-identical output."""
    tc, tp, dc, dp = tiny
    dec = SpecDecoder(tp, tc, dp, dc, k=4, max_len=256)
    prompt = _prompt(tc.vocab_size)
    ar, _ = dec.generate_ar(prompt, 32)
    sp, stats = dec.generate_spec(prompt, 32, mode=mode)
    assert bool(jnp.all(ar == sp))
    assert stats.tokens_generated == 32 * prompt.shape[0]


def test_self_draft_accepts_everything(tiny):
    """Draft == target -> VSD acceptance is exactly 1.0 and each iteration
    commits K+1 tokens."""
    tc, tp, _, _ = tiny
    dec = SpecDecoder(tp, tc, tp, tc, k=4, max_len=256)
    prompt = _prompt(tc.vocab_size)
    ar, _ = dec.generate_ar(prompt, 40)
    sp, stats = dec.generate_spec(prompt, 40, mode="vsd")
    assert bool(jnp.all(ar == sp))
    assert stats.acceptance_rate == pytest.approx(1.0)
    assert stats.mean_accepted == pytest.approx(5.0)


def test_pard_one_draft_forward_per_iteration(tiny):
    """Eq. 4: PARD drafts once per iteration; VSD drafts K times (Eq. 3)."""
    tc, tp, dc, dp = tiny
    dec = SpecDecoder(tp, tc, dp, dc, k=4, max_len=256)
    prompt = _prompt(tc.vocab_size)
    _, s_pard = dec.generate_spec(prompt, 24, mode="pard")
    _, s_vsd = dec.generate_spec(prompt, 24, mode="vsd")
    assert s_pard.draft_forwards == s_pard.iterations
    assert s_vsd.draft_forwards == 4 * s_vsd.iterations


@pytest.mark.parametrize("arch", ["tiny-ssm", "jamba-1.5-large-398b-smoke"])
@pytest.mark.parametrize("mode", ["pard", "vsd"])
def test_ssm_hybrid_lossless(arch, mode):
    """SSM state rollback (per-token state gathering) keeps spec decoding
    lossless for recurrent and hybrid targets/drafts."""
    cfg = get_config(arch)
    params = init_params(jax.random.PRNGKey(3), cfg)
    dec = SpecDecoder(params, cfg, params, cfg, k=4, max_len=256)
    prompt = _prompt(cfg.vocab_size, seed=5)
    ar, _ = dec.generate_ar(prompt, 24)
    sp, _ = dec.generate_spec(prompt, 24, mode=mode)
    assert bool(jnp.all(ar == sp))


def test_speculative_sampling_preserves_distribution():
    """Leviathan acceptance identity: for ANY draft distribution q, the
    first committed token's induced distribution equals the target p.
    Tested directly on the extracted acceptance function with a small vocab
    and enough trials for a tight Monte-Carlo bound."""
    from repro.core.spec_decode import speculative_accept
    V, K = 8, 3
    key = jax.random.PRNGKey(0)
    p = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (V,)) * 1.5)
    q = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 2), (V,)) * 1.5)
    p_full = jnp.broadcast_to(p, (1, K + 1, V))
    qprob = jnp.broadcast_to(q, (1, K, V))

    trials = 4000

    @jax.jit
    def one(rng):
        r1, r2 = jax.random.split(rng)
        props = jax.random.categorical(r1, jnp.log(qprob))     # [1, K]
        a, accepted, commit = speculative_accept(p_full, qprob, props, r2)
        first = jnp.where(a[0] >= 1, props[0, 0], commit[0])
        return first

    keys = jax.random.split(jax.random.PRNGKey(7), trials)
    firsts = np.asarray(jax.vmap(one)(keys))
    emp = np.bincount(firsts, minlength=V) / trials
    tv = 0.5 * np.abs(emp - np.asarray(p)).sum()
    assert tv < 0.05, f"TV distance {tv} (emp={emp}, p={np.asarray(p)})"


def test_acceptance_histogram_monotone(tiny):
    """Acceptance of position j requires acceptance of j-1: the histogram
    must be non-increasing."""
    tc, tp, _, _ = tiny
    dec = SpecDecoder(tp, tc, tp, tc, k=4, max_len=256)
    prompt = _prompt(tc.vocab_size)
    _, stats = dec.generate_spec(prompt, 40, mode="pard")
    h = list(stats.accept_hist)
    assert all(h[i] >= h[i + 1] for i in range(len(h) - 1))
