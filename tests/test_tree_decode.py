"""Tree-structured PARD drafting: losslessness vs AR (the core guarantee),
degenerate-template == flat-K token identity, accepted-length accounting,
and engine-level paged-KV isolation when batched requests accept different
tree paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.spec_decode import SpecDecoder, TreeTemplate
from repro.models import init_params
from repro.serving.engine import Engine


@pytest.fixture(scope="module")
def tiny():
    tc = get_config("tiny-target")
    dc = get_config("tiny-draft")
    tp = init_params(jax.random.PRNGKey(0), tc)
    dp = init_params(jax.random.PRNGKey(1), dc)
    return tc, tp, dc, dp


def _prompt(vocab, b=2, p=8, seed=2):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, p), 0, vocab)


def test_template_construction():
    t = TreeTemplate.from_branching((3, 2, 1))
    assert t.max_depth == 3
    assert t.num_nodes == 3 + 6 + 6
    assert t.num_slots == t.num_nodes + 1
    # breadth-first: parents precede children, depths are non-decreasing
    assert all(t.parent[s] < s for s in range(1, t.num_slots))
    assert all(t.depth[t.parent[s]] == t.depth[s] - 1
               for s in range(1, t.num_slots))
    # ancestor bitmask: own bit plus exactly the parent's mask
    for s in range(1, t.num_slots):
        assert t.anc[s] == (t.anc[t.parent[s]] | np.uint32(1 << s))
    assert TreeTemplate.flat(4).is_chain and not t.is_chain


def test_template_too_large_rejected():
    with pytest.raises(AssertionError, match="window slots"):
        TreeTemplate.from_branching((4, 3, 1, 1))      # 41 slots > 32


def test_tree_rejects_ssm(tiny):
    """Sampled (temperature > 0) trees are supported now — only SSM targets
    still reject (no positional rollback for a packed window)."""
    tc, tp, dc, dp = tiny
    dec = SpecDecoder(tp, tc, dp, dc, temperature=0.7,
                      tree=TreeTemplate.flat(4))
    assert dec.tree is not None and dec.temperature == 0.7
    sc = get_config("tiny-ssm")
    sp = init_params(jax.random.PRNGKey(3), sc)
    with pytest.raises(NotImplementedError, match="SSM"):
        SpecDecoder(sp, sc, dp, dc, tree=TreeTemplate.flat(4))


@pytest.mark.parametrize("branching", [(2, 2, 2, 1), (3, 2, 1, 1), (4, 1)])
def test_tree_greedy_lossless_random_draft(tiny, branching):
    """Even a totally uncorrelated draft must give bit-identical output:
    every committed token is the target argmax given its committed prefix,
    whatever path the tree accepted."""
    tc, tp, dc, dp = tiny
    tree = TreeTemplate.from_branching(branching)
    dec = SpecDecoder(tp, tc, dp, dc, max_len=256, tree=tree)
    prompt = _prompt(tc.vocab_size)
    ar, _ = dec.generate_ar(prompt, 32)
    sp, stats = dec.generate_spec(prompt, 32, mode="pard")
    assert bool(jnp.all(ar == sp))
    assert stats.tokens_generated == 32 * prompt.shape[0]


def test_degenerate_tree_token_identical_to_flat(tiny):
    """branching (1,)*K must reproduce the flat-K PARD path token for
    token — the tree machinery collapses exactly onto today's chain."""
    tc, tp, dc, dp = tiny
    prompt = _prompt(tc.vocab_size)
    flat = SpecDecoder(tp, tc, dp, dc, k=4, max_len=256)
    ref, st_flat = flat.generate_spec(prompt, 32, mode="pard")
    chain = SpecDecoder(tp, tc, dp, dc, max_len=256,
                        tree=TreeTemplate.flat(4))
    out, st_chain = chain.generate_spec(prompt, 32, mode="pard")
    assert bool(jnp.all(ref == out))
    assert st_chain.mean_accepted == pytest.approx(st_flat.mean_accepted)


def test_tree_self_draft_accepts_at_least_chain(tiny):
    """Self-drafting (draft == target): depth-1 always matches, and a
    node's acceptance set is a superset of the chain's at every depth, so
    the tree's accepted length per step is >= 1 and the histogram is
    monotone."""
    tc, tp, _, _ = tiny
    dec = SpecDecoder(tp, tc, tp, tc, max_len=512,
                      tree=TreeTemplate.from_branching((2, 2, 2, 1)))
    prompt = _prompt(tc.vocab_size, b=4, p=10)
    _, stats = dec.generate_spec(prompt, 40, mode="pard")
    h = list(stats.accept_hist)
    assert all(h[i] >= h[i + 1] for i in range(len(h) - 1))
    assert stats.mean_accepted >= 2.0       # depth 1 matches every step


def test_tree_engine_matches_ar_reference(tiny):
    """Two batched ragged requests through the paged engine with tree
    drafting: each accepts its own tree paths, and each completion must
    match its single-request AR reference — no paged-KV cross-contamination
    through the shared pool or the compaction scatter."""
    tc, tp, dc, dp = tiny
    rng = np.random.default_rng(12)
    prompts = [rng.integers(0, 512, size=int(n_tok)).astype(np.int32)
               for n_tok in rng.integers(4, 14, size=5)]
    refs = {}
    for i, p in enumerate(prompts):
        dec = SpecDecoder(tp, tc, tp, tc, k=4, max_len=256)
        refs[i] = np.asarray(dec.generate_ar(jnp.asarray(p)[None], 12)[0][0])
    # self-draft so acceptance is non-trivial (different requests really do
    # take different paths through the template)
    eng = Engine(tp, tc, tp, tc, mode="pard", k=4, max_batch=2, max_len=256,
                 kv_layout="paged", kv_block_size=32,
                 tree=TreeTemplate.from_branching((2, 2, 2, 1)))
    rids = {eng.submit(p, 12): i for i, p in enumerate(prompts)}
    comps = eng.run()
    assert len(comps) == len(prompts)
    for c in comps:
        assert np.array_equal(refs[rids[c.rid]], c.tokens)
    assert eng.stats["accepted"] > 0
    assert eng.mean_accepted() > 1.0


def test_tree_engine_layouts_agree(tiny):
    """Tree drafting must commit identical tokens under the contiguous and
    the block-paged KV layout (compaction correctness in both)."""
    tc, tp, dc, dp = tiny
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, 512, size=int(n_tok)).astype(np.int32)
               for n_tok in rng.integers(4, 14, size=4)]
    results = {}
    for layout in ("contiguous", "paged"):
        eng = Engine(tp, tc, dp, dc, mode="pard", max_batch=2, max_len=256,
                     kv_layout=layout, kv_block_size=32,
                     tree=TreeTemplate.from_branching((3, 2, 1, 1)))
        rids = {eng.submit(p, 12): i for i, p in enumerate(prompts)}
        results[layout] = {rids[c.rid]: c.tokens for c in eng.run()}
    for i in range(len(prompts)):
        assert np.array_equal(results["contiguous"][i], results["paged"][i])
