"""Data-parallel engine replicas behind one scheduler (DESIGN.md §12).

Host-only portion (tier-1): the shared PrefixIndex registry, and the
scheduler's prefix-affinity-then-least-loaded ``_route_order`` exercised
directly against real BlockAllocators (no devices, no models).

Multi-device portion (CI dp-gate: REPRO_HOST_DEVICES=4): dp=2 engines are
token-set-identical to dp=1 for the same request set in both KV layouts,
same-prefix requests route to the replica owning the cached blocks, and a
full owner replica overflows to the other replica instead of stalling.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serving import kv_pool, scheduler as sched_mod
from repro.serving.config import EngineConfig, SamplingParams
from repro.serving.engine import Engine

needs2 = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >= 2 host devices (REPRO_HOST_DEVICES=4)")

BS = 16     # block size used by every host-only allocator below


# ------------------------------------------------------------ PrefixIndex
def _alloc(index=None, replica=0, num_blocks=32):
    return kv_pool.BlockAllocator(num_blocks, BS, max_batch=4, max_len=256,
                                  replica=replica, prefix_index=index)


def _seed_prefix(alloc, slot, prompt):
    """Admit ``prompt`` into ``alloc`` the way the scheduler does and mark
    its prompt blocks computed (matchable)."""
    keys = kv_pool.prefix_block_keys(prompt, BS)
    alloc.allocate(slot, len(prompt), keys=keys)
    alloc.mark_computed(slot, len(prompt))
    return keys


def test_prefix_index_registers_each_replica_once():
    idx = kv_pool.PrefixIndex()
    a0 = _alloc(idx, replica=0)
    assert idx.allocators == {0: a0}
    with pytest.raises(ValueError, match="already registered"):
        _alloc(idx, replica=0)
    a1 = _alloc(idx, replica=1)
    assert idx.allocators == {0: a0, 1: a1}


def test_prefix_index_best_replica_longest_hit_ties_low():
    idx = kv_pool.PrefixIndex()
    a0, a1 = _alloc(idx, 0), _alloc(idx, 1)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 512, size=3 * BS + 4).astype(np.int32)
    keys = kv_pool.prefix_block_keys(prompt, BS)
    assert len(keys) == 3
    # replica 1 holds the full 3-block prefix, replica 0 only 1 block
    _seed_prefix(a1, 0, prompt)
    _seed_prefix(a0, 0, prompt[:BS + 2])
    best_r, blocks = idx.best_replica(keys)
    assert best_r == 1 and len(blocks) == 3
    assert {r: len(m) for r, m in idx.match(keys).items()} == {0: 1, 1: 3}
    # equal hit lengths tie to the lowest replica id (deterministic)
    _seed_prefix(a0, 1, prompt)
    best_r, blocks = idx.best_replica(keys)
    assert best_r == 0 and len(blocks) == 3
    # no replica holds anything for a foreign prompt
    other = rng.integers(0, 512, size=2 * BS).astype(np.int32)
    assert idx.best_replica(kv_pool.prefix_block_keys(other, BS)) \
        == (None, [])


def test_prefix_index_requires_computed_blocks():
    """An allocated-but-not-yet-prefilled block must not attract routing
    (I5: match_prefix only returns computed blocks)."""
    idx = kv_pool.PrefixIndex()
    a0 = _alloc(idx, 0)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, 512, size=2 * BS).astype(np.int32)
    keys = kv_pool.prefix_block_keys(prompt, BS)
    a0.allocate(0, len(prompt), keys=keys)       # no mark_computed
    assert idx.best_replica(keys) == (None, [])
    a0.mark_computed(0, len(prompt))
    assert idx.best_replica(keys)[0] == 0


# ------------------------------------------------- _route_order (host-only)
class _FakeDec:
    """The slice of SpecDecoder the Scheduler reads at construction/submit
    time; routing itself never touches the decoder."""
    chunk_width = 8
    window_slack = 4
    min_row_slack = 4


class _FakeEx:
    kv_dtype = "bf16"


def _routing_sched(dp=2, prefix_cache=True, paged=True):
    idx = kv_pool.PrefixIndex() if paged else None
    allocs = [_alloc(idx, r) for r in range(dp)] if paged else [None] * dp
    return sched_mod.Scheduler(
        [_FakeDec()] * dp, [_FakeEx()] * dp, allocs, mode="pard",
        max_batch=4, max_len=256, temperature=0.0, eos_id=None,
        bank=None, ctrl=None, prefix_cache=prefix_cache, admit_window=4,
        prefill_budget=None, tree_reselect_every=4, prefix_index=idx)


def _req(prompt):
    return sched_mod.Request(0, np.asarray(prompt, np.int32),
                             SamplingParams(max_new=8))


def test_route_order_miss_goes_least_loaded():
    sched = _routing_sched()
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, 512, size=2 * BS).astype(np.int32)
    # ties break to the lowest replica id
    assert [(r.rep, h) for r, h in sched._route_order(_req(prompt))] \
        == [(0, 0), (1, 0)]
    # load replica 0 -> the emptier replica 1 now goes first
    sched.replicas[0].slots[0] = _req(prompt)
    sched.replicas[0]._occ_cache = None
    assert [(r.rep, h) for r, h in sched._route_order(_req(prompt))] \
        == [(1, 0), (0, 0)]


def test_route_order_hit_routes_to_owner_even_when_loaded():
    sched = _routing_sched()
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, 512, size=3 * BS + 2).astype(np.int32)
    # replica 1 owns the prefix AND is the more loaded replica: affinity
    # must still place it first (the hit is served copy-free there)
    _seed_prefix(sched.replicas[1].alloc, 0, prompt)
    sched.replicas[1].slots[0] = _req(prompt)
    sched.replicas[1]._occ_cache = None
    order = sched._route_order(_req(prompt))
    assert [(r.rep, h) for r, h in order] == [(1, 3), (0, 0)]
    # a different prompt ignores the cached blocks: pure least-loaded
    other = rng.integers(0, 512, size=2 * BS).astype(np.int32)
    assert [(r.rep, h) for r, h in sched._route_order(_req(other))] \
        == [(0, 0), (1, 0)]


def test_route_order_trivial_without_prefix_cache():
    for sched in (_routing_sched(prefix_cache=False),
                  _routing_sched(paged=False, prefix_cache=False),
                  _routing_sched(dp=1)):
        rng = np.random.default_rng(4)
        prompt = rng.integers(0, 512, size=2 * BS).astype(np.int32)
        order = sched._route_order(_req(prompt))
        assert [h for _, h in order] == [0] * sched.dp
        assert [r.rep for r, _ in order] == sorted(
            range(sched.dp),
            key=lambda i: (sched.replicas[i].occupancy(), i))


# ------------------------------------------------ end-to-end (multi-device)
@pytest.fixture(scope="module")
def models():
    tc = get_config("tiny-target")
    dc = get_config("tiny-draft")
    tp = init_params(jax.random.PRNGKey(0), tc)
    dp = init_params(jax.random.PRNGKey(1), dc)
    return tc, tp, dc, dp


def _mixed_submit(eng, reqs, max_new=24):
    rids = {}
    for i, r in enumerate(reqs):
        rids[eng.submit(r, params=SamplingParams(
            max_new=max_new,
            temperature=0.0 if i % 2 == 0 else 0.8,
            seed=None if i % 2 == 0 else 50 + i))] = i
    return rids


def _run_tokens(models, reqs, **cfg_kw):
    tc, tp, dc, dp = models
    eng = Engine(tp, tc, dp, dc, config=EngineConfig(
        mode="pard", k=4, max_batch=2, max_len=256, seed=7, **cfg_kw))
    rids = _mixed_submit(eng, reqs)
    out = {rids[c.rid]: c.tokens for c in eng.run()}
    return out, eng


@needs2
@pytest.mark.parametrize("layout", ["paged", "contiguous"])
def test_dp2_token_set_identical_to_dp1(models, layout):
    """The acceptance gate: dp=2 commits exactly the token set of dp=1
    for the same mixed greedy + pinned-seed sampled request set, in both
    KV layouts (routing must never leak into the tokens)."""
    rng = np.random.default_rng(5)
    sys_p = rng.integers(0, 512, size=64).astype(np.int32)
    reqs = [np.concatenate([sys_p,
                            rng.integers(0, 512, size=6).astype(np.int32)])
            for _ in range(6)]
    kw = dict(kv_layout=layout, kv_block_size=64, pipelined=True)
    if layout == "paged":
        kw["prefix_cache"] = True
    base, _ = _run_tokens(models, reqs, dp=1, **kw)
    out, eng = _run_tokens(models, reqs, dp=2, **kw)
    assert set(base) == set(out)
    for i in base:
        assert np.array_equal(base[i], out[i]), f"request {i} diverged"
    assert len(eng.stats["replica_steps"]) == 2
    assert all(s > 0 for s in eng.stats["replica_steps"])


@needs2
def test_dp2_same_prefix_requests_route_to_owner(models):
    """Warm same-prefix requests land on the replica already holding the
    cached blocks: the scheduler counts them as affinity-routed and the
    warm pass serves the prompt blocks from the cache."""
    tc, tp, dc, dp = models
    rng = np.random.default_rng(6)
    sys_p = rng.integers(0, 512, size=64).astype(np.int32)
    reqs = [np.concatenate([sys_p,
                            rng.integers(0, 512, size=6).astype(np.int32)])
            for _ in range(4)]
    eng = Engine(tp, tc, dp, dc, config=EngineConfig(
        mode="pard", k=4, max_batch=2, max_len=256, seed=7, dp=2,
        kv_layout="paged", kv_block_size=64, prefix_cache=True))
    _mixed_submit(eng, reqs)
    eng.run()                                    # cold: seeds one replica
    eng.stats.update(affinity_routed=0, prefix_lookup_blocks=0,
                     prefix_hit_blocks=0)
    rids = _mixed_submit(eng, reqs)
    out = {rids[c.rid]: c for c in eng.run()[-len(reqs):]}
    assert len(out) == len(reqs)
    # every warm request found its owner (and its cached prompt block)
    assert eng.stats["affinity_routed"] == len(reqs)
    assert eng.prefix_hit_rate() == 1.0


@needs2
def test_dp2_full_owner_overflows_not_stalls(models):
    """More same-prefix requests than the owning replica has slots: the
    overflow admits on the OTHER replica immediately (fall-through) rather
    than queueing behind the full owner, and everything completes."""
    tc, tp, dc, dp = models
    rng = np.random.default_rng(8)
    sys_p = rng.integers(0, 512, size=64).astype(np.int32)
    reqs = [np.concatenate([sys_p,
                            rng.integers(0, 512, size=6).astype(np.int32)])
            for _ in range(6)]
    eng = Engine(tp, tc, dp, dc, config=EngineConfig(
        mode="pard", k=4, max_batch=2, max_len=256, seed=7, dp=2,
        kv_layout="paged", kv_block_size=64, prefix_cache=True))
    _mixed_submit(eng, reqs)
    eng.run()                                    # warm one replica's cache
    _mixed_submit(eng, reqs)                     # 6 warm same-prefix reqs
    comps = eng.run()
    assert len(comps) == 2 * len(reqs)
    # the owner (2 slots) cannot hold all 6: some admissions must have
    # overflowed to the other replica, and both replicas must have stepped
    assert all(s > 0 for s in eng.stats["replica_steps"])
