"""Prefix-cache invariants (kv_pool I1/I2/I5) and engine-level identity:
refcounted sharing, computed-gated matching, LRU eviction, copy-on-write,
and cache-hit completions token-identical to cold ones."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serving import kv_pool
from repro.serving.kv_pool import BlockAllocator, prefix_block_keys


# --------------------------------------------------------------- invariants
def check_invariants(a: BlockAllocator):
    """Every structural invariant the allocator must hold between ops."""
    # I1: the garbage block is never handed out, cached, or refcounted
    assert 0 not in a.free and 0 not in a.lru and a.ref[0] == 0
    for blocks in a.owned.values():
        assert 0 not in blocks
    # refcount == number of slots mapping the block; never negative
    counts = {}
    for blocks in a.owned.values():
        for b in blocks:
            counts[b] = counts.get(b, 0) + 1
    for b in range(a.num_blocks):
        assert a.ref[b] == counts.get(b, 0), f"ref drift on block {b}"
    # never free/evictable while mapped ("never free a block with ref > 0")
    for b in a.free:
        assert a.ref[b] == 0
    for b in a.lru:
        assert a.ref[b] == 0
    assert not set(a.free) & set(a.lru)
    # I5: only computed registered blocks park on the LRU
    for b in a.lru:
        assert b in a.block_key and b in a.computed
    # index <-> block_key is a bijection where defined
    for key, b in a.index.items():
        assert a.block_key.get(b) == key
    for b, key in a.block_key.items():
        assert a.index.get(key) == b
    assert a.computed <= set(a.block_key)
    # I2: a block mapped by >= 2 slots is shared READ-ONLY — at most one
    # mapper (the original prefiller) holds it outside its read-only set,
    # and it must be computed (matching is gated on computed)
    for b, c in counts.items():
        if c >= 2:
            assert b in a.computed, f"shared uncomputed block {b}"
            writable = sum(
                1 for s, blocks in a.owned.items()
                if b in blocks
                and blocks.index(b) not in a.read_only.get(s, set()))
            assert writable <= 1, f"block {b} writable in {writable} tables"


def _admit(a: BlockAllocator, slot: int, prompt: np.ndarray, max_new=8,
           slack=10):
    """The scheduler's admission protocol against a bare allocator."""
    keys = prefix_block_keys(prompt, a.block_size)
    hit = a.match_prefix(keys)
    need = len(prompt) + max_new + slack
    if not a.can_allocate(a.blocks_needed(need) - len(hit), hit):
        return None
    a.allocate(slot, need, prefix=hit, keys=keys)
    return len(hit) * a.block_size


# ------------------------------------------------------------ deterministic
def test_register_match_and_computed_gating():
    a = BlockAllocator(num_blocks=16, block_size=4, max_batch=4, max_len=64)
    prompt = np.arange(10, dtype=np.int32)          # p-1 = 9 -> 2 full blocks
    keys = prefix_block_keys(prompt, 4)
    assert len(keys) == 2
    assert _admit(a, 0, prompt) == 0
    # registered but not computed: a concurrent identical prompt misses
    assert a.match_prefix(keys) == []
    a.mark_computed(0, 4)                           # prefill passed block 0
    assert a.match_prefix(keys) == [a.owned[0][0]]
    a.mark_computed(0, 9)                           # full prompt prefilled
    assert a.match_prefix(keys) == a.owned[0][:2]
    check_invariants(a)
    # second slot maps the prefix copy-free: refcount 2, shared read-only
    assert _admit(a, 1, prompt) == 8
    assert a.owned[1][:2] == a.owned[0][:2]
    assert all(a.ref[b] == 2 for b in a.owned[0][:2])
    check_invariants(a)
    # releases drop refs; the cached blocks park on the LRU, not free
    a.release(0)
    assert all(a.ref[b] == 1 for b in a.owned[1][:2])
    a.release(1)
    assert len(a.lru) == 2 and all(a.ref[b] == 0 for b in a.lru)
    check_invariants(a)
    # ...and still serve a later identical prompt
    assert _admit(a, 2, prompt) == 8
    check_invariants(a)


def test_lru_eviction_recycles_cold_blocks_only():
    a = BlockAllocator(num_blocks=8, block_size=4, max_batch=4, max_len=64)
    p1 = np.arange(5, dtype=np.int32)               # 1 full block
    p2 = 100 + np.arange(5, dtype=np.int32)
    _admit(a, 0, p1, max_new=2, slack=1)            # 2 blocks
    a.mark_computed(0, 4)
    _admit(a, 1, p2, max_new=2, slack=1)
    a.mark_computed(1, 4)
    a.release(0)
    a.release(1)                                    # LRU: [p1's, p2's]
    assert len(a.lru) == 2
    check_invariants(a)
    # a big allocation drains the free list then evicts the OLDEST entry
    free_before = len(a.free)
    a.allocate(2, (free_before + 1) * 4)
    assert len(a.lru) == 1
    check_invariants(a)
    # p1's registration was evicted; p2's prefix still hits
    assert a.match_prefix(prefix_block_keys(p1, 4)) == []
    assert len(a.match_prefix(prefix_block_keys(p2, 4))) == 1


def test_copy_on_write_detaches_shared_block():
    a = BlockAllocator(num_blocks=16, block_size=4, max_batch=4, max_len=64)
    prompt = np.arange(10, dtype=np.int32)
    _admit(a, 0, prompt)
    a.mark_computed(0, 9)
    _admit(a, 1, prompt)
    shared = a.owned[1][0]
    assert a.ref[shared] == 2
    pair = a.copy_on_write(1, 0)                    # slot 1 wants to write
    assert pair is not None and pair[0] == shared
    old, new = pair
    assert a.ref[old] == 1 and a.ref[new] == 1
    assert a.owned[1][0] == new and a.tables[1, 0] == new
    assert a.owned[0][0] == old                     # owner keeps the cached one
    check_invariants(a)
    # sole-owner cached block: detached from the index instead of copied
    assert a.copy_on_write(0, 0) is None
    assert old not in a.block_key
    check_invariants(a)
    # exclusive uncached block (slot 1's fresh tail): no-op
    assert a.copy_on_write(1, 2) is None
    check_invariants(a)


def test_can_allocate_excludes_lru_parked_prefix_blocks():
    """Regression: a matched prefix whose blocks sit ON the eviction LRU
    must not be double-counted as reclaimable capacity — the admission
    check has to report backpressure, not pass and then crash allocate()
    with an empty free list."""
    a = BlockAllocator(num_blocks=4, block_size=4, max_batch=3, max_len=32)
    prompt = np.arange(9, dtype=np.int32)           # p-1 = 8 -> 2 full blocks
    _admit(a, 0, prompt, max_new=2, slack=1)        # 3 blocks
    a.mark_computed(0, 8)
    a.release(0)                                    # 2 cached on LRU, 1 free
    a.allocate(1, 4)                                # drains the free list
    keys = prefix_block_keys(prompt, 4)
    hit = a.match_prefix(keys)
    assert len(hit) == 2 and all(b in a.lru for b in hit)
    # 3 blocks needed, 2 from the hit: ONE fresh block required, zero
    # reclaimable once the hit leaves the LRU -> must refuse
    assert not a.can_allocate(1, hit)
    assert a.can_allocate(0, hit)                   # the hit itself is fine
    check_invariants(a)
    # after the exclusive owner frees its block, admission goes through
    a.release(1)
    assert a.can_allocate(1, hit)
    assert _admit(a, 2, prompt, max_new=2, slack=1) == 8
    check_invariants(a)


def test_copy_on_write_exhausted_pool_raises_cleanly():
    a = BlockAllocator(num_blocks=4, block_size=4, max_batch=3, max_len=32)
    prompt = np.arange(9, dtype=np.int32)
    _admit(a, 0, prompt, max_new=2, slack=1)        # all 3 usable blocks
    a.mark_computed(0, 8)
    # share the prefix without fresh blocks: slot 1 maps only the hit
    hit = a.match_prefix(prefix_block_keys(prompt, 4))
    a.allocate(1, 8, prefix=hit)
    assert not a.can_allocate(1)
    with pytest.raises(RuntimeError, match="copy-on-write"):
        a.copy_on_write(1, 0)
    check_invariants(a)


def test_randomized_interleavings_hold_invariants():
    """Seeded-random version of the hypothesis property below — always
    runs, so the invariants keep local coverage without the optional dep."""
    rng = np.random.default_rng(0)
    for trial in range(20):
        _run_interleaving(
            a=BlockAllocator(num_blocks=12, block_size=4, max_batch=3,
                             max_len=64),
            ops=rng.integers(0, 4, size=40).tolist(),
            picks=rng.integers(0, 100, size=40).tolist(),
            n_prompts=int(rng.integers(1, 4)))


def _run_interleaving(a: BlockAllocator, ops, picks, n_prompts):
    """Replay submit/prefill/complete/evict ops, checking invariants after
    every mutation. Prompts are drawn from a small pool so prefix sharing,
    computed gating and eviction all actually trigger."""
    prompts = [np.full(11, i, dtype=np.int32) for i in range(n_prompts)]
    live = {}                                 # slot -> (prompt, pf_cursor)
    for op, pick in zip(ops, picks):
        if op == 0:                           # submit into a free slot
            free = [s for s in range(3) if s not in live]
            if not free:
                continue
            prompt = prompts[pick % len(prompts)]
            pf = _admit(a, free[0], prompt, max_new=2, slack=1)
            if pf is not None:
                live[free[0]] = (prompt, pf)
        elif op == 1 and live:                # one prefill chunk
            slot = sorted(live)[pick % len(live)]
            prompt, pf = live[slot]
            pf = min(pf + 5, len(prompt) - 1)
            a.mark_computed(slot, pf)
            live[slot] = (prompt, pf)
        elif op == 2 and live:                # complete
            slot = sorted(live)[pick % len(live)]
            a.release(slot)
            del live[slot]
        elif op == 3:                         # allocation pressure / evict
            if a.can_allocate(2) and 2 not in live:
                unique = 200 + np.arange(9, dtype=np.int32)
                pf = _admit(a, 2, unique, max_new=2, slack=1)
                if pf is not None:
                    live[2] = (unique, pf)
        check_invariants(a)
    for slot in list(live):
        a.release(slot)
        check_invariants(a)
    assert a.blocks_in_use == 0


def test_hypothesis_interleavings_hold_invariants():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(ops=st.lists(st.integers(0, 3), min_size=1, max_size=60),
           picks=st.lists(st.integers(0, 99), min_size=60, max_size=60),
           n_prompts=st.integers(1, 4),
           block_size=st.sampled_from([2, 4, 8]))
    def run(ops, picks, n_prompts, block_size):
        _run_interleaving(
            a=BlockAllocator(num_blocks=12, block_size=block_size,
                             max_batch=3, max_len=64),
            ops=ops, picks=picks, n_prompts=n_prompts)

    run()


# -------------------------------------------------------------- engine level
@pytest.fixture(scope="module")
def models():
    tc = get_config("tiny-target")
    dc = get_config("tiny-draft")
    tp = init_params(jax.random.PRNGKey(0), tc)
    dp = init_params(jax.random.PRNGKey(1), dc)
    return tc, tp, dc, dp


def _shared_prompts(rng, n, sys_len=33, tail=5):
    sys_p = rng.integers(0, 512, size=sys_len).astype(np.int32)
    return [np.concatenate([sys_p,
                            rng.integers(0, 512, size=tail).astype(np.int32)])
            for _ in range(n)]


@pytest.mark.parametrize("temps", [[0.0] * 5, [0.0, 0.8, 0.0, 0.7, 0.8]],
                         ids=["greedy", "mixed-sampled"])
def test_cache_hit_completions_identical_to_cold(models, temps):
    """Cache-hit completions must be token-identical to cold ones in BOTH
    layouts: contiguous (no cache, the reference), paged cold, paged warm.
    Greedy rows are deterministic; sampled rows are seeded per (seed, rid),
    so their trajectories must also be invariant to the KV source."""
    from repro.serving.engine import Engine
    tc, tp, dc, dp = models
    rng = np.random.default_rng(7)
    prompts = _shared_prompts(rng, 5)
    results = {}
    for name, layout, cache in [("cont", "contiguous", False),
                                ("cold", "paged", False),
                                ("warm", "paged", True)]:
        eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=2,
                     max_len=256, kv_layout=layout, kv_block_size=16,
                     prefix_cache=cache, seed=0)
        rids = {eng.submit(p, 12, temperature=t): i
                for i, (p, t) in enumerate(zip(prompts, temps))}
        results[name] = {rids[c.rid]: c.tokens for c in eng.run()}
        if name == "warm":
            assert eng.prefix_hit_rate() > 0.5
            assert eng.alloc.blocks_in_use == 0
    for i in range(len(prompts)):
        assert np.array_equal(results["cont"][i], results["cold"][i])
        assert np.array_equal(results["cont"][i], results["warm"][i])


def test_live_sharing_refcounts_and_block_savings(models):
    """Two later same-prefix requests map the finished request's cached
    blocks copy-free WHILE LIVE (refcount 2 each) and allocate strictly
    fewer fresh blocks than a cold admission would."""
    from repro.serving.engine import Engine
    tc, tp, dc, dp = models
    rng = np.random.default_rng(8)
    prompts = _shared_prompts(rng, 3)
    eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=2, max_len=256,
                 kv_layout="paged", kv_block_size=16, prefix_cache=True)
    eng.submit(prompts[0], 8)
    eng.run()
    free_before = len(eng.alloc.free) + len(eng.alloc.lru)
    eng.submit(prompts[1], 8)
    eng.submit(prompts[2], 8)
    eng.sched.admit()
    shared = [b for b in eng.alloc.owned[0] if eng.alloc.ref[b] == 2]
    assert len(shared) == 2                       # both full prompt blocks
    assert shared == eng.alloc.owned[1][:2]
    check_invariants(eng.alloc)
    # both admissions drew only their tails from the free pool
    taken = free_before - len(eng.alloc.free) - len(eng.alloc.lru)
    cold_need = 2 * eng.alloc.blocks_needed(
        len(prompts[1]) + 8 + eng.dec.window_slack)
    assert taken < cold_need
    comps = eng.run()
    assert len(comps) == 3 and eng.alloc.blocks_in_use == 0


def test_prefix_cache_rejects_contiguous_layout(models):
    from repro.serving.engine import Engine
    tc, tp, dc, dp = models
    with pytest.raises(AssertionError, match="paged"):
        Engine(tp, tc, dp, dc, mode="pard", kv_layout="contiguous",
               prefix_cache=True)


def test_prefix_keys_are_content_exact():
    p1 = np.asarray([1, 2, 3, 4, 5, 6, 7, 8, 9], np.int32)
    p2 = np.asarray([1, 2, 3, 4, 5, 6, 7, 8, 9], np.int32)
    p3 = np.asarray([1, 2, 3, 9, 5, 6, 7, 8, 9], np.int32)
    assert prefix_block_keys(p1, 4) == prefix_block_keys(p2, 4)
    k1, k3 = prefix_block_keys(p1, 4), prefix_block_keys(p3, 4)
    assert k1[0] != k3[0] and k1[1] != k3[1]      # chained: divergence sticks
    # only FULL blocks inside prompt[:-1] are keyed
    assert len(prefix_block_keys(np.arange(9, dtype=np.int32), 4)) == 2
    assert len(prefix_block_keys(np.arange(8, dtype=np.int32), 4)) == 1
    assert len(prefix_block_keys(np.arange(4, dtype=np.int32), 4)) == 0


def test_default_num_blocks_unchanged():
    assert kv_pool.default_num_blocks(2, 128, 32) == 2 * 4 + 1


# ------------------------------------------------------------ quantized pools
def test_prefix_keys_salted_by_kv_dtype_never_alias():
    """A cached block's payload is the dtype-specific encoding (quantized
    values + scales vs full precision), so the same token prefix under
    different kv_dtypes must produce disjoint key sets — and the default
    salt is byte-identical to the historical unsalted keys' dtype."""
    p = np.arange(1, 200, dtype=np.int32)
    per_dtype = {name: prefix_block_keys(p, 64, kv_dtype=name)
                 for name in ("bf16", "fp32", "int8", "fp8")}
    names = list(per_dtype)
    for i, a in enumerate(names):
        assert len(per_dtype[a]) == 3             # (199 - 1) // 64 full blocks
        for b in names[i + 1:]:
            assert not set(per_dtype[a]) & set(per_dtype[b])
    assert prefix_block_keys(p, 64) == per_dtype["bf16"]


def test_warm_cache_identical_to_cold_under_int8(models):
    """Warm-vs-cold token identity holds with a quantized pool: a cache
    hit serves the EXACT int8 blocks (values + scales) the registering
    request appended, so greedy and seeded-sampled trajectories are
    invariant to the KV source under int8 too."""
    from repro.serving.engine import Engine
    tc, tp, dc, dp = models
    rng = np.random.default_rng(11)
    prompts = _shared_prompts(rng, 5)
    temps = [0.0, 0.8, 0.0, 0.7, 0.8]
    results = {}
    for name, cache in [("cold", False), ("warm", True)]:
        eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=2,
                     max_len=256, kv_layout="paged", kv_block_size=16,
                     prefix_cache=cache, kv_dtype="int8", seed=0)
        rids = {eng.submit(p, 12, temperature=t): i
                for i, (p, t) in enumerate(zip(prompts, temps))}
        results[name] = {rids[c.rid]: c.tokens for c in eng.run()}
        check_invariants(eng.alloc)
        if name == "warm":
            assert eng.prefix_hit_rate() > 0.5
            assert eng.alloc.blocks_in_use == 0
    for i in range(len(prompts)):
        assert np.array_equal(results["cold"][i], results["warm"][i])


def test_live_sharing_and_cow_invariants_under_int8(models):
    """Refcounted live sharing + COW semantics are dtype-agnostic: the
    allocator tracks BLOCK INDICES, and the executor's copy_block copies
    every pool leaf (scales included). Run the live-sharing scenario on an
    int8 engine and hold the I1-I5 invariants throughout."""
    from repro.serving.engine import Engine
    tc, tp, dc, dp = models
    rng = np.random.default_rng(12)
    prompts = _shared_prompts(rng, 3)
    eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=2, max_len=256,
                 kv_layout="paged", kv_block_size=16, prefix_cache=True,
                 kv_dtype="int8")
    eng.submit(prompts[0], 8)
    eng.run()
    eng.submit(prompts[1], 8)
    eng.submit(prompts[2], 8)
    eng.sched.admit()
    shared = [b for b in eng.alloc.owned[0] if eng.alloc.ref[b] == 2]
    assert len(shared) == 2                       # both full prompt blocks
    assert shared == eng.alloc.owned[1][:2]
    check_invariants(eng.alloc)
    # exercise COW on a live shared block: the detached copy gets its own
    # refcount-1 block whose VALUES AND SCALES are byte-identical to the
    # donor's (copy_block is a generic tree.map over every pool leaf)
    pair = eng.alloc.copy_on_write(0, 0)
    assert pair is not None                       # shared -> must remap
    src, dst = pair
    assert src == shared[0] and dst != src
    assert eng.alloc.ref[dst] == 1 and eng.alloc.ref[src] == 1
    eng.ex.copy_block(src, dst)
    for c, scanned in ([(c, False) for c in eng.ex.state.tcache["prefix"]]
                       + [(c, True) for c in eng.ex.state.tcache["scan"]]):
        if "k" not in c and "ckv" not in c:
            continue                              # SSM/cross: not paged KV
        for leaf in c.values():
            blk = (lambda i, x=leaf: x[:, i]) if scanned \
                else (lambda i, x=leaf: x[i])
            np.testing.assert_array_equal(np.asarray(blk(src)),
                                          np.asarray(blk(dst)))
    check_invariants(eng.alloc)
    comps = eng.run()                             # cumulative completions
    assert len(comps) == 3 and eng.alloc.blocks_in_use == 0
