"""Training substrate: optimizer math, schedules, checkpoint roundtrip,
PARD adaptation loss semantics, and a short end-to-end fit."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.adaptation import ar_loss, pard_adaptation_loss
from repro.core.cod import CodConfig, pack_batch
from repro.data.pipeline import MarkovCorpus
from repro.models import init_params
from repro.training import checkpoint
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.train_loop import Trainer


def test_adamw_reduces_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_grad_clipping():
    opt = AdamW(lr=0.1, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, m = opt.update({"w": jnp.asarray([100.0, 0.0, 0.0])}, state, params)
    assert float(m["grad_norm"]) == pytest.approx(100.0)


def test_cosine_schedule():
    f = cosine_schedule(1.0, warmup=10, total=100, floor_frac=0.1)
    assert float(f(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(f(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(f(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("tiny-draft")
    params = init_params(jax.random.PRNGKey(0), cfg)
    path = os.path.join(tmp_path, "ckpt.npz")
    checkpoint.save(path, params, metadata={"step": 7})
    restored = checkpoint.restore(path, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert checkpoint.load_metadata(path)["step"] == 7


def test_subtask1_loss_equals_ar_loss():
    """Eq. 8 with k=1 is exactly the AR objective — the strongest
    train/inference-consistency check for the COD packing."""
    cfg = get_config("tiny-draft")
    params = init_params(jax.random.PRNGKey(1), cfg)
    corpus = MarkovCorpus(vocab_size=cfg.vocab_size, seed=0)
    tokens = corpus.sample(np.random.default_rng(0), 4, 48)
    l_ar, _ = ar_loss(params, cfg, jnp.asarray(tokens), dtype=jnp.float32)
    packed = pack_batch(tokens, CodConfig(k=4, r=0.7, r_min=0.2),
                        cfg.mask_token_id, seed=0)
    batch = {k: jnp.asarray(v) for k, v in packed.items() if k != "n_tokens"}
    _, metrics = pard_adaptation_loss(params, cfg, batch, k_max=4,
                                      dtype=jnp.float32)
    assert float(metrics["loss_subtask_1"]) == pytest.approx(float(l_ar),
                                                             rel=1e-5)


def test_trainer_learns_markov():
    cfg = get_config("tiny-draft")
    params = init_params(jax.random.PRNGKey(2), cfg)
    corpus = MarkovCorpus(vocab_size=cfg.vocab_size, seed=0, determinism=2.0)
    tr = Trainer(cfg, AdamW(lr=3e-3), loss_kind="ar")
    params, _, hist = tr.fit(params, corpus.batches(8, 64, seed=0), 40,
                             log_every=40, log_fn=None)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    assert last < 6.3  # below ln(512)=6.24 baseline means it's learning
    # run twice for determinism of the data stream
    s1 = corpus.sample(np.random.default_rng(9), 2, 16)
    s2 = corpus.sample(np.random.default_rng(9), 2, 16)
    np.testing.assert_array_equal(s1, s2)


def test_pard_trainer_step_runs():
    cfg = get_config("tiny-draft")
    params = init_params(jax.random.PRNGKey(3), cfg)
    corpus = MarkovCorpus(vocab_size=cfg.vocab_size, seed=0)
    tr = Trainer(cfg, AdamW(lr=1e-3), loss_kind="pard",
                 cod=CodConfig(k=3, r=0.6, r_min=0.2))
    params, _, hist = tr.fit(params, corpus.batches(4, 48, seed=1), 3,
                             log_every=3, log_fn=None)
    assert np.isfinite(hist[-1]["loss"])
