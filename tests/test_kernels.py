"""Per-kernel validation: shape/dtype sweeps asserting allclose against the
ref.py pure-jnp oracles (interpret=True executes the Pallas kernel bodies on
CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(42)


def rand(*shape, k=0, dtype=jnp.float32):
    return jax.random.normal(jax.random.fold_in(KEY, k), shape, jnp.float32
                             ).astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("b,t,hq,hkv,d", [
    (1, 64, 4, 4, 32), (2, 128, 4, 2, 64), (1, 96, 8, 1, 32), (2, 50, 2, 2, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(b, t, hq, hkv, d, dtype):
    q = rand(b, t, hq, d, k=1, dtype=dtype)
    k = rand(b, t, hkv, d, k=2, dtype=dtype)
    v = rand(b, t, hkv, d, k=3, dtype=dtype)
    out = ops.flash_attention(q, k, v, block_q=32, block_k=32)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("window,softcap", [(0, 0.0), (16, 0.0), (0, 30.0),
                                            (32, 50.0)])
def test_flash_attention_variants(window, softcap):
    q, k, v = (rand(2, 64, 4, 32, k=i) for i in (1, 2, 3))
    out = ops.flash_attention(q, k, v, window=window, softcap=softcap,
                              block_q=32, block_k=32)
    want = ref.flash_attention_ref(q, k, v, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("b,tq,hq,hkv,d,s", [
    (2, 9, 4, 2, 64, 256), (3, 1, 4, 4, 32, 128), (1, 5, 8, 2, 32, 100),
])
def test_decode_attention(b, tq, hq, hkv, d, s):
    q = rand(b, tq, hq, d, k=4)
    k = rand(b, s, hkv, d, k=5)
    v = rand(b, s, hkv, d, k=6)
    kv_len = jnp.asarray([s // 2 + 3 * i + tq for i in range(b)], jnp.int32)
    q_pos = (kv_len - tq)[:, None] + jnp.arange(tq)[None, :]
    out = ops.decode_attention(q, k, v, kv_len, q_pos, block_k=64)
    want = ref.decode_attention_ref(q, k, v, kv_len, q_pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def _paged_setup(b, hkv, d, bs, mbs, key=0):
    """A pool with rows owning interleaved (non-monotone) blocks, plus the
    equivalent contiguous cache for cross-layout parity checks."""
    nb = 1 + b * mbs
    k_pages = rand(nb, bs, hkv, d, k=key + 1)
    v_pages = rand(nb, bs, hkv, d, k=key + 2)
    perm = np.random.default_rng(key).permutation(np.arange(1, nb))
    tables = jnp.asarray(perm.reshape(b, mbs), jnp.int32)
    k_cont = ref.gather_pages(k_pages, tables)
    v_cont = ref.gather_pages(v_pages, tables)
    return k_pages, v_pages, tables, k_cont, v_cont


@pytest.mark.parametrize("b,tq,hq,hkv,d,bs,mbs", [
    (2, 9, 4, 2, 64, 32, 4),     # PARD verify window (K+1 = 9)
    (3, 1, 4, 4, 32, 16, 5),     # plain AR decode
    (1, 8, 8, 2, 32, 64, 3),     # 2K draft window
])
def test_decode_attention_paged(b, tq, hq, hkv, d, bs, mbs):
    q = rand(b, tq, hq, d, k=4)
    kv_len = jnp.asarray([bs * mbs // 2 + 3 * i + tq for i in range(b)],
                         jnp.int32)
    k_pages, v_pages, tables, k_cont, v_cont = _paged_setup(b, hkv, d, bs,
                                                            mbs)
    q_pos = (kv_len - tq)[:, None] + jnp.arange(tq)[None, :]
    out = ops.decode_attention_paged(q, k_pages, v_pages, tables, kv_len,
                                     q_pos)
    want = ref.decode_attention_paged_ref(q, k_pages, v_pages, tables,
                                          kv_len, q_pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
    # cross-layout: the contiguous kernel on the gathered view must agree
    cont = ops.decode_attention(q, k_cont, v_cont, kv_len, q_pos, block_k=bs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(cont), atol=2e-5)


def test_decode_attention_paged_window_softcap():
    b, tq, h, d, bs, mbs = 2, 3, 4, 32, 16, 6
    q = rand(b, tq, h, d, k=7)
    kv_len = jnp.asarray([77, 60], jnp.int32)
    k_pages, v_pages, tables, _, _ = _paged_setup(b, h, d, bs, mbs, key=30)
    q_pos = (kv_len - tq)[:, None] + jnp.arange(tq)[None, :]
    out = ops.decode_attention_paged(q, k_pages, v_pages, tables, kv_len,
                                     q_pos, window=24, softcap=30.0)
    want = ref.decode_attention_paged_ref(q, k_pages, v_pages, tables,
                                          kv_len, q_pos, window=24,
                                          softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_decode_attention_paged_ignores_garbage_block():
    """Unallocated table entries point at block 0; its contents must never
    leak into the output (kv_len masks them)."""
    b, tq, h, d, bs, mbs = 1, 2, 2, 16, 8, 4
    q = rand(b, tq, h, d, k=40)
    k_pages = rand(6, bs, h, d, k=41)
    v_pages = rand(6, bs, h, d, k=42)
    tables = jnp.asarray([[3, 5, 0, 0]], jnp.int32)     # 2 real blocks
    kv_len = jnp.asarray([14], jnp.int32)
    q_pos = (kv_len - tq)[:, None] + jnp.arange(tq)[None, :]
    out1 = ops.decode_attention_paged(q, k_pages, v_pages, tables, kv_len,
                                      q_pos)
    poisoned_k = k_pages.at[0].set(1e4)
    poisoned_v = v_pages.at[0].set(-1e4)
    out2 = ops.decode_attention_paged(q, poisoned_k, poisoned_v, tables,
                                      kv_len, q_pos)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=0)


def test_decode_attention_window():
    b, tq, h, d, s = 2, 3, 4, 32, 128
    q, k, v = rand(b, tq, h, d, k=7), rand(b, s, h, d, k=8), rand(b, s, h, d, k=9)
    kv_len = jnp.asarray([100, 80], jnp.int32)
    q_pos = (kv_len - tq)[:, None] + jnp.arange(tq)[None, :]
    out = ops.decode_attention(q, k, v, kv_len, q_pos, window=32, block_k=32)
    want = ref.decode_attention_ref(q, k, v, kv_len, q_pos, window=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("k_sub,r", [(3, 0.6), (4, 0.5)])
def test_pard_attention(k_sub, r):
    from repro.core.cod import CodConfig, pack_batch
    toks = np.random.default_rng(0).integers(0, 500, size=(2, 40))
    packed = pack_batch(toks, CodConfig(k=k_sub, r=r, r_min=0.2), 512, seed=0)
    seg = jnp.asarray(packed["segment"])
    base = jnp.asarray(packed["base"])
    t = seg.shape[1]
    q, k, v = rand(2, t, 2, 32, k=10), rand(2, t, 2, 32, k=11), rand(2, t, 2, 32, k=12)
    out = ops.pard_attention(q, k, v, seg, base, block_q=32)
    want = ref.pard_attention_ref(q, k, v, seg, base)
    live = np.asarray(seg > 0)[:, :, None, None]
    np.testing.assert_allclose(np.asarray(out) * live, np.asarray(want) * live,
                               atol=2e-5)


@pytest.mark.parametrize("b,t,h,p,n,chunk", [
    (1, 32, 2, 8, 4, 8), (2, 64, 3, 16, 8, 16), (1, 50, 2, 8, 8, 16),
])
def test_ssd_kernel(b, t, h, p, n, chunk):
    x = rand(b, t, h, p, k=13)
    dt = jax.nn.softplus(rand(b, t, h, k=14))
    A = -jnp.exp(rand(h, k=15) * 0.5)
    B = rand(b, t, n, k=16)
    C = rand(b, t, n, k=17)
    s0 = rand(b, h, p, n, k=18) * 0.1
    y, sf = ops.ssd_chunked(x, dt, A, B, C, s0, chunk=chunk)
    yr, sr = ref.ssd_ref(x, dt, A, B, C, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr), atol=2e-4)


def test_ssd_kernel_matches_model_chunked_path():
    """The kernel and the model's jnp chunked scan must agree (they are the
    two production paths)."""
    from repro.models.ssm import ssd_scan_chunked
    b, t, h, p, n = 2, 48, 2, 8, 8
    x = rand(b, t, h, p, k=19)
    dt = jax.nn.softplus(rand(b, t, h, k=20))
    A = -jnp.exp(rand(h, k=21) * 0.5)
    B, C = rand(b, t, n, k=22), rand(b, t, n, k=23)
    y1, s1 = ops.ssd_chunked(x, dt, A, B, C, chunk=16)
    y2, s2 = ssd_scan_chunked(x, dt, A, B, C, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)
