"""Quantized KV cache (int8 / fp8) — DESIGN.md §10.

Four layers of coverage, cheapest first:

  * quantize→dequantize roundtrip error bounds (pure property sweeps, plus
    hypothesis when available);
  * kernel-vs-oracle parity for every Pallas streaming variant (contiguous
    + paged, decode + tree) with quantized pools and fused dequant,
    including garbage-block poisoning;
  * pool-level invariants: scale leaves exist with the right shapes, byte
    accounting shows the ≥2x int8 reduction, quantized pools keep the
    pytree-structure contract with contiguous caches;
  * committed-token quality bounds end-to-end: int8 spec == int8 AR
    (greedy losslessness is dtype-internal — quantization is deterministic
    per append, so the verifier and the baseline see identical caches),
    paged == contiguous under int8, and int8-vs-fp32 greedy disagreement
    stays under a calibrated bound on a fixed workload.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import MarkovCorpus
from repro.kernels import ops, ref
from repro.models import init_params
from repro.models.attention import (KV_DTYPES, dequantize_kv, gather_pages,
                                    kv_dtype_is_quantized, quantize_kv,
                                    resolve_kv_dtype)
from repro.serving import kv_pool
from repro.serving.engine import Engine

KEY = jax.random.PRNGKey(42)
QUANT_DTYPES = ["int8", "fp8"]


def rand(*shape, k=0):
    return jax.random.normal(jax.random.fold_in(KEY, k), shape, jnp.float32)


# ---------------------------------------------------------------------------
# quantize → dequantize roundtrip bounds
# ---------------------------------------------------------------------------

def _roundtrip_bound(x, name):
    """Max reconstruction error allowed for one [..., D] row of values.

    int8: the scaled lattice step is amax/127, rounding error ≤ half a
    step. fp8 e4m3 has a 3-bit mantissa: relative error ≤ 2^-4 of the
    value, so ≤ amax/16 after scaling to the [-448, 448] range (plus
    denormal slack near zero).
    """
    amax = np.max(np.abs(np.asarray(x, np.float32)), axis=-1, keepdims=True)
    if name == "int8":
        return amax / 127.0 * 0.5 + 1e-7
    return amax / 16.0 + 1e-7


@pytest.mark.parametrize("name", QUANT_DTYPES)
def test_roundtrip_error_bound_sweep(name):
    qd = resolve_kv_dtype(name)
    rng = np.random.default_rng(0)
    for trial in range(20):
        shape = (rng.integers(1, 5), rng.integers(1, 9),
                 rng.integers(1, 5), int(rng.choice([4, 16, 32, 64])))
        scale_mag = float(10.0 ** rng.uniform(-3, 3))
        x = jnp.asarray(rng.standard_normal(shape) * scale_mag, jnp.float32)
        q, s = quantize_kv(x, qd)
        back = dequantize_kv(q, s)
        err = np.abs(np.asarray(back) - np.asarray(x))
        bound = _roundtrip_bound(x, name)
        assert (err <= bound).all(), (trial, err.max(), bound.max())


@pytest.mark.parametrize("name", QUANT_DTYPES)
def test_roundtrip_zero_and_extremes(name):
    qd = resolve_kv_dtype(name)
    # all-zero rows quantize to zeros with scale 1 (no NaN/Inf): this is
    # what keeps the garbage block harmless under quantization
    z = jnp.zeros((2, 4, 3, 16), jnp.float32)
    q, s = quantize_kv(z, qd)
    assert np.asarray(s).min() == 1.0
    np.testing.assert_array_equal(np.asarray(dequantize_kv(q, s)), 0.0)
    # a single huge element: sign and magnitude survive the roundtrip
    x = jnp.zeros((1, 1, 1, 8), jnp.float32).at[..., 3].set(-1e4)
    q, s = quantize_kv(x, qd)
    back = np.asarray(dequantize_kv(q, s))
    assert abs(back[..., 3] + 1e4) / 1e4 < 0.1
    assert np.isfinite(back).all()


@pytest.mark.parametrize("name", QUANT_DTYPES)
def test_roundtrip_hypothesis(name):
    hyp = pytest.importorskip("hypothesis")
    hnp = pytest.importorskip("hypothesis.extra.numpy")
    st = pytest.importorskip("hypothesis.strategies")
    qd = resolve_kv_dtype(name)

    @hyp.given(hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=4,
                                                       min_side=1,
                                                       max_side=16),
                          elements=st.floats(-1e4, 1e4, width=32)))
    @hyp.settings(max_examples=50, deadline=None)
    def check(x):
        q, s = quantize_kv(jnp.asarray(x), qd)
        back = np.asarray(dequantize_kv(q, s))
        assert np.isfinite(back).all()
        assert (np.abs(back - x) <= _roundtrip_bound(x, name)).all()

    check()


# ---------------------------------------------------------------------------
# kernel-vs-oracle parity with fused dequant
# ---------------------------------------------------------------------------

def _paged_setup(b, hkv, d, bs, mbs, key=0):
    nb = b * mbs + 1
    perm = np.random.default_rng(key).permutation(np.arange(1, nb))
    tables = jnp.asarray(perm.reshape(b, mbs), jnp.int32)
    k_pages = rand(nb, bs, hkv, d, k=10 + key)
    v_pages = rand(nb, bs, hkv, d, k=20 + key)
    return k_pages, v_pages, tables


@pytest.mark.parametrize("name", QUANT_DTYPES)
@pytest.mark.parametrize("b,tq,hq,hkv,d,s", [
    (2, 9, 4, 2, 64, 256), (1, 5, 8, 2, 32, 100),
])
def test_decode_attention_quant(name, b, tq, hq, hkv, d, s):
    qd = resolve_kv_dtype(name)
    q = rand(b, tq, hq, d, k=4)
    k, ks = quantize_kv(rand(b, s, hkv, d, k=5), qd)
    v, vs = quantize_kv(rand(b, s, hkv, d, k=6), qd)
    kv_len = jnp.asarray([s // 2 + 3 * i + tq for i in range(b)], jnp.int32)
    q_pos = (kv_len - tq)[:, None] + jnp.arange(tq)[None, :]
    out = ops.decode_attention(q, k, v, kv_len, q_pos, k_scale=ks,
                               v_scale=vs, block_k=64)
    want = ref.decode_attention_ref(q, k, v, kv_len, q_pos, k_scale=ks,
                                    v_scale=vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("name", QUANT_DTYPES)
def test_decode_attention_paged_quant(name):
    qd = resolve_kv_dtype(name)
    b, tq, hq, hkv, d, bs, mbs = 2, 5, 4, 2, 32, 32, 4
    kp, vp, tables = _paged_setup(b, hkv, d, bs, mbs)
    kq, ks = quantize_kv(kp, qd)
    vq, vs = quantize_kv(vp, qd)
    q = rand(b, tq, hq, d, k=3)
    kv_len = jnp.array([100, 70], jnp.int32)
    q_pos = (kv_len - tq)[:, None] + jnp.arange(tq)[None, :]
    out = ops.decode_attention_paged(q, kq, vq, tables, kv_len, q_pos,
                                     k_scale=ks, v_scale=vs)
    want = ref.decode_attention_paged_ref(q, kq, vq, tables, kv_len, q_pos,
                                          k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
    # the two layouts agree on the same logical cache
    kc, vc = gather_pages(kq, tables), gather_pages(vq, tables)
    ksc, vsc = gather_pages(ks, tables), gather_pages(vs, tables)
    cont = ops.decode_attention(q, kc, vc, kv_len, q_pos, k_scale=ksc,
                                v_scale=vsc, block_k=bs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(cont), atol=2e-5)


@pytest.mark.parametrize("name", QUANT_DTYPES)
def test_tree_attention_quant_both_layouts(name):
    qd = resolve_kv_dtype(name)
    b, tq, hq, hkv, d, bs, mbs = 2, 5, 4, 2, 32, 32, 4
    kp, vp, tables = _paged_setup(b, hkv, d, bs, mbs, key=1)
    kq, ks = quantize_kv(kp, qd)
    vq, vs = quantize_kv(vp, qd)
    q = rand(b, tq, hq, d, k=7)
    kv_len = jnp.array([100, 70], jnp.int32)
    q_pos = (kv_len - tq)[:, None] + jnp.arange(tq)[None, :]
    win_start = kv_len - tq
    anc = jnp.asarray(np.array([[1, 3, 5, 11, 19], [1, 3, 5, 9, 17]],
                               np.uint32))
    out = ops.tree_attention_paged(q, kq, vq, tables, kv_len, q_pos,
                                   win_start, anc, k_scale=ks, v_scale=vs)
    want = ref.tree_attention_paged_ref(q, kq, vq, tables, kv_len, q_pos,
                                        win_start, anc, k_scale=ks,
                                        v_scale=vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
    kc, vc = gather_pages(kq, tables), gather_pages(vq, tables)
    ksc, vsc = gather_pages(ks, tables), gather_pages(vs, tables)
    cont = ops.tree_attention(q, kc, vc, kv_len, q_pos, win_start, anc,
                              k_scale=ksc, v_scale=vsc, block_k=bs)
    contw = ref.tree_attention_ref(q, kc, vc, kv_len, q_pos, win_start, anc,
                                   k_scale=ksc, v_scale=vsc)
    np.testing.assert_allclose(np.asarray(cont), np.asarray(contw),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(cont), atol=2e-5)


@pytest.mark.parametrize("name", QUANT_DTYPES)
def test_quant_garbage_block_is_invisible(name):
    """Poisoning the garbage block's VALUES AND SCALES must not change any
    output: validity is kv_index < kv_len, never the table contents."""
    qd = resolve_kv_dtype(name)
    b, tq, hq, hkv, d, bs, mbs = 2, 5, 4, 2, 32, 32, 4
    kp, vp, tables = _paged_setup(b, hkv, d, bs, mbs, key=2)
    kq, ks = quantize_kv(kp, qd)
    vq, vs = quantize_kv(vp, qd)
    q = rand(b, tq, hq, d, k=9)
    kv_len = jnp.array([100, 70], jnp.int32)
    q_pos = (kv_len - tq)[:, None] + jnp.arange(tq)[None, :]
    clean = ops.decode_attention_paged(q, kq, vq, tables, kv_len, q_pos,
                                       k_scale=ks, v_scale=vs)
    maxq = 127 if name == "int8" else 448.0
    kq2 = kq.at[0].set(jnp.asarray(maxq, kq.dtype))
    vq2 = vq.at[0].set(jnp.asarray(maxq, vq.dtype))
    ks2, vs2 = ks.at[0].set(1e6), vs.at[0].set(1e6)
    poisoned = ops.decode_attention_paged(q, kq2, vq2, tables, kv_len, q_pos,
                                          k_scale=ks2, v_scale=vs2)
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(poisoned))


# ---------------------------------------------------------------------------
# pool layout + byte accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", QUANT_DTYPES)
def test_quant_pool_has_scale_leaves(name):
    cfg = get_config("tiny-target")
    pool = kv_pool.init_paged_caches(cfg, 2, 9, 16, dtype=name)
    layers = pool["prefix"] + pool["scan"]
    gqa = [c for c in layers if "k" in c]
    assert gqa, "tiny-target should have GQA attention layers"
    for layer in gqa:
        assert set(layer) == {"k", "v", "k_scale", "v_scale"}
        assert layer["k"].dtype == resolve_kv_dtype(name)
        assert layer["k_scale"].dtype == jnp.float32
        # per-(slot, head): the scale drops only the head_dim axis
        assert layer["k_scale"].shape == layer["k"].shape[:-1]
        # scale 1 everywhere: the zeroed pool dequantizes to exact zeros
        assert np.asarray(layer["k_scale"]).min() == 1.0


def test_int8_pool_byte_reduction():
    """The acceptance gate in miniature: int8 pool bytes (values + scales)
    ≤ half the fp32 pool's, measured by the same accounting the engine
    reports in BENCH_serve.json."""
    cfg = get_config("tiny-target")
    fp32 = kv_pool.init_paged_caches(cfg, 2, 17, 64, dtype="fp32")
    int8 = kv_pool.init_paged_caches(cfg, 2, 17, 64, dtype="int8")
    cap32 = kv_pool.kv_capacity_bytes(cfg, fp32)
    cap8 = kv_pool.kv_capacity_bytes(cfg, int8)
    assert cap8 * 2 <= cap32, (cap8, cap32)


def test_prefix_keys_salted_by_kv_dtype():
    """Quantized and full-precision blocks must never alias in the prefix
    cache: the cached payload encodings differ."""
    prompt = np.arange(1, 130, dtype=np.int32)
    base = kv_pool.prefix_block_keys(prompt, 64)
    for name in ("fp32", "int8", "fp8"):
        salted = kv_pool.prefix_block_keys(prompt, 64, kv_dtype=name)
        assert len(salted) == len(base) > 0
        assert not set(salted) & set(base)
    assert kv_pool.prefix_block_keys(prompt, 64, kv_dtype="bf16") == base


def test_kv_dtype_registry():
    assert set(KV_DTYPES) == {"bf16", "fp32", "int8", "fp8"}
    for name in QUANT_DTYPES:
        assert kv_dtype_is_quantized(resolve_kv_dtype(name))
    for name in ("bf16", "fp32"):
        assert not kv_dtype_is_quantized(resolve_kv_dtype(name))


# ---------------------------------------------------------------------------
# committed-token quality bounds (end-to-end engine runs)
# ---------------------------------------------------------------------------

def _serve_tokens(kv_dtype, mode="pard", layout="paged", tree=None,
                  n_req=4, max_new=24):
    tc = get_config("tiny-target")
    dc = get_config("tiny-draft")
    tp = init_params(jax.random.PRNGKey(0), tc)
    dp = init_params(jax.random.PRNGKey(1), dc)
    corpus = MarkovCorpus(vocab_size=tc.vocab_size, seed=0, determinism=2.0)
    rng = np.random.default_rng(0)
    eng = Engine(tp, tc, dp if mode != "ar" else None,
                 dc if mode != "ar" else None, mode=mode, k=4,
                 max_batch=2, max_len=256, kv_layout=layout,
                 kv_dtype=kv_dtype, tree=tree)
    for _ in range(n_req):
        eng.submit(corpus.prompts(rng, 1, 16)[0], max_new)
    return {c.rid: list(c.tokens) for c in eng.run()}


def test_greedy_spec_matches_ar_under_int8():
    """Greedy speculative losslessness is INTERNAL to a kv_dtype: the
    verifier replays the same quantized cache the AR baseline builds
    (quantization is deterministic per append; compaction moves encoded
    values unchanged), so spec-vs-AR must stay bit-exact under int8."""
    assert _serve_tokens("int8", mode="pard") == _serve_tokens("int8",
                                                               mode="ar")


def test_int8_paged_matches_contiguous():
    assert _serve_tokens("int8", layout="paged") == \
        _serve_tokens("int8", layout="contiguous")


def test_tree_mode_lossless_under_int8():
    toks = _serve_tokens("int8", mode="pard", tree=(2, 2, 1))
    assert toks == _serve_tokens("int8", mode="ar")


# calibrated on the fixed workload above: int8 observed ≈ 96% agreement
# (per-head scales keep the argmax ordering), fp8 ≈ 68% (e4m3's 3-bit
# mantissa flips near-tie argmaxes on the random-init tiny model, and ONE
# flip diverges the row's whole remaining trajectory). The gates sit far
# below observed and far above what a real encoding bug produces
# (agreement collapses towards 1/vocab ≈ 0.4% when bytes are misread).
QUALITY_FLOOR = {"int8": 0.80, "fp8": 0.50}


@pytest.mark.parametrize("name", QUANT_DTYPES)
def test_committed_token_quality_bound(name):
    """Greedy disagreement vs the fp32 path stays bounded on the fixed
    workload (the committed-token quality bound, DESIGN.md §10)."""
    quant = _serve_tokens(name)
    fp32 = _serve_tokens("fp32")
    assert quant.keys() == fp32.keys()
    agree = total = 0
    for rid in quant:
        for a, b in zip(quant[rid], fp32[rid]):
            agree += a == b
            total += 1
    assert total > 0
    floor = QUALITY_FLOOR[name]
    assert agree / total >= floor, f"{name}: {agree}/{total} tokens agree"
