"""Overlap-pipelined serve loop (DESIGN.md §9): the depth-2 dispatch/
harvest pipeline must be invisible in the tokens — byte-identical
completions vs the synchronous loop — across layouts, sampling modes,
EOS truncation, slot churn (re-admission while a step is in flight) and
adaptive reshaping. Plus the observability contract: per-step host
overhead lands in latency_summary / SpecStats."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.spec_decode import SpecDecoder, TemplateBank, TreeTemplate
from repro.models import init_params
from repro.serving.engine import Engine


@pytest.fixture(scope="module")
def models():
    tc = get_config("tiny-target")
    dc = get_config("tiny-draft")
    tp = init_params(jax.random.PRNGKey(0), tc)
    dp = init_params(jax.random.PRNGKey(1), dc)
    return tc, tp, dc, dp


def _prompts(rng, n, lo=4, hi=14, vocab=512):
    return [rng.integers(0, vocab, size=int(t)).astype(np.int32)
            for t in rng.integers(lo, hi, size=n)]


def _run(models, pipelined, *, n_req=6, max_batch=2, seed_rng=7,
         temps=None, eos_id=None, max_new=12, engine_kw=None,
         submit_kw=None, return_engine=False):
    tc, tp, dc, dp = models
    rng = np.random.default_rng(seed_rng)
    prompts = _prompts(rng, n_req, lo=6, hi=18)
    kw = dict(mode="pard", k=4, max_batch=max_batch, max_len=256,
              eos_id=eos_id, seed=0)
    kw.update(engine_kw or {})
    eng = Engine(tp, tc, dp, dc, **kw)
    for i, p in enumerate(prompts):
        t = None if temps is None else temps[i % len(temps)]
        eng.submit(p, max_new + 2 * (i % 3), temperature=t,
                   **(submit_kw or {}))
    comps = eng.run(pipelined=pipelined)
    toks = {c.rid: np.asarray(c.tokens) for c in comps}
    if return_engine:
        return toks, eng
    return toks


def _assert_identical(sync, pipe):
    assert set(sync) == set(pipe)
    for rid in sync:
        assert np.array_equal(sync[rid], pipe[rid]), (
            f"rid {rid}: pipelined tokens diverged\n"
            f"sync {sync[rid].tolist()}\npipe {pipe[rid].tolist()}")


# ------------------------------------------------------- token identity
@pytest.mark.parametrize("layout", ["paged", "contiguous"])
def test_pipelined_greedy_identical(models, layout):
    """Greedy batches: the pipeline is invisible in the tokens in both
    KV layouts, including mid-flight admission churn (6 requests through
    2 slots means every retirement re-admits while a step is in
    flight)."""
    kw = dict(kv_layout=layout, kv_block_size=32)
    sync = _run(models, False, engine_kw=kw)
    pipe = _run(models, True, engine_kw=kw)
    _assert_identical(sync, pipe)


@pytest.mark.parametrize("layout", ["paged", "contiguous"])
def test_pipelined_sampled_mixed_identical(models, layout):
    """Seeded-sampled rows mixed with greedy rows: per-request (seed,
    rid) PRNG keys advance only on a row's own live steps, so the
    pipeline shifts nothing."""
    kw = dict(kv_layout=layout, kv_block_size=32)
    temps = (0.0, 0.8, 0.0, 1.2)
    sync = _run(models, False, temps=temps, engine_kw=kw)
    pipe = _run(models, True, temps=temps, engine_kw=kw)
    _assert_identical(sync, pipe)


def test_pipelined_eos_truncation_identical(models):
    """EOS retirement lags one step in the pipeline (the row runs one
    extra in-flight step) but completions are built from the EOS step's
    own snapshot, so the extra step's speculation never leaks into the
    output. Pick an eos_id that actually fires on this tiny config by
    scanning a greedy sync run first."""
    sync0 = _run(models, False, max_new=20)
    gen = np.concatenate([t[6:] for t in sync0.values()])
    eos = int(np.bincount(gen).argmax())        # most common generated token
    sync = _run(models, False, max_new=20, eos_id=eos)
    pipe = _run(models, True, max_new=20, eos_id=eos)
    hit = [rid for rid in sync if eos in sync[rid].tolist()]
    assert hit, "chosen eos_id never fired — test would be vacuous"
    for rid in hit:                             # truncated AT the EOS
        row = sync[rid].tolist()
        assert row.index(eos) == len(row) - 1 or eos not in row[6:-1]
    _assert_identical(sync, pipe)


def test_pipelined_slot_churn_more_requests_than_slots(models):
    """Heavy churn: 10 requests through 2 slots with ragged budgets —
    every slot is re-admitted several times while steps are in flight,
    exercising the rid-stamped handle guard (a stale in-flight snapshot
    must never attribute to a slot's new occupant)."""
    sync = _run(models, False, n_req=10, max_batch=2, max_new=8)
    pipe = _run(models, True, n_req=10, max_batch=2, max_new=8)
    assert len(pipe) == 10
    _assert_identical(sync, pipe)


def test_pipelined_adaptive_reshape_identical(models):
    """Adaptive controller + greedy rows (+ one pinned sampled row):
    reshaping mid-request is staged at dispatch boundaries; greedy
    losslessness is shape-independent and a pinned row never reshapes,
    so both stay token-identical under the pipeline."""
    tc, tp, dc, dp = models
    kw = dict(tree=TemplateBank.default(4), adaptive_tree=True,
              tree_reselect_every=2)

    def run(pipelined):
        rng = np.random.default_rng(11)
        prompts = _prompts(rng, 6, lo=6, hi=18)
        eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=2,
                     max_len=256, seed=0, **kw)
        for i, p in enumerate(prompts):
            if i == 2:          # pinned + sampled: never reshapes
                eng.submit(p, 10, temperature=0.8, tree_idx=0)
            else:
                eng.submit(p, 10 + 2 * (i % 3))
        comps = eng.run(pipelined=pipelined)
        return {c.rid: np.asarray(c.tokens) for c in comps}

    _assert_identical(run(False), run(True))


def test_pipelined_static_tree_identical(models):
    """Static branching template through the fused tree step: identical
    under the pipeline (self-draft keeps acceptance meaningful)."""
    tc, tp, dc, dp = models
    kw = dict(tree=TreeTemplate.from_branching((2, 2, 1)))
    sync = _run(models, False, engine_kw=kw)
    pipe = _run(models, True, engine_kw=kw)
    _assert_identical(sync, pipe)


# ----------------------------------------------------------- accounting
def test_pipelined_stats_match_sync(models):
    """Commit accounting is loop-shape-independent: the pipeline may run
    a few EXTRA steps (retirement lags one dispatch, so a handle already
    in flight when the batch drains executes frozen — committing
    nothing), but accepted/live/committed totals must match exactly."""
    sync, es = _run(models, False, return_engine=True)
    pipe, ep = _run(models, True, return_engine=True)
    for key in ("accepted", "live_steps", "committed", "prefill_tokens"):
        assert es.stats[key] == ep.stats[key], key
    assert ep.stats["steps"] >= es.stats["steps"]
    # the lag is bounded: at most one frozen step per retirement event
    assert ep.stats["steps"] - es.stats["steps"] <= len(pipe)
    for eng in (es, ep):
        assert eng.stats["target_forwards"] == eng.stats["steps"]


def test_host_overhead_recorded(models):
    """latency_summary reports harvest->dispatch host overhead
    percentiles; the pipelined loop records one sample per dispatch
    after the first."""
    _, eng = _run(models, True, return_engine=True)
    lat = eng.latency_summary()
    assert "host_overhead_p50_ms" in lat and "host_overhead_p95_ms" in lat
    # ramp-up: the first TWO dispatches of the depth-2 pipeline precede
    # any harvest, so they carry no overhead sample
    assert len(eng.sched.host_overhead_ms) >= eng.stats["steps"] - 2
    assert lat["host_overhead_p95_ms"] >= lat["host_overhead_p50_ms"] >= 0.0


def test_specstats_host_overhead(models):
    """generate_spec surfaces the same observability in SpecStats."""
    tc, tp, dc, dp = models
    dec = SpecDecoder(tp, tc, dp, dc, k=4, max_len=128)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, 512, size=(2, 8)).astype(np.int32)
    _, st = dec.generate_spec(prompt, 12, mode="pard")
    assert st.host_overhead_p95_ms >= st.host_overhead_p50_ms >= 0.0
    assert st.host_overhead_p50_ms > 0.0   # loop ran > 1 iteration
