"""Paged KV pool unit tests: allocator invariants (DESIGN.md §5 I1-I4),
paged write/gather semantics, and pool bytes accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import forward, init_caches, init_params
from repro.models.attention import gather_pages, write_cache_paged
from repro.serving import kv_pool


# ---------------------------------------------------------------------- I1/I2
def test_allocator_reserves_garbage_block():
    a = kv_pool.BlockAllocator(num_blocks=9, block_size=16, max_batch=2,
                               max_len=64)
    a.allocate(0, 64)
    a.allocate(1, 64)
    assert len(a.free) == 0                      # 8 usable blocks handed out
    used = a.owned[0] + a.owned[1]
    assert 0 not in used                         # I1: block 0 never allocated
    assert len(set(used)) == len(used)           # I2: unique ownership


def test_allocator_blocks_needed_rounding():
    a = kv_pool.BlockAllocator(num_blocks=32, block_size=16, max_batch=2,
                               max_len=256)
    assert a.blocks_needed(1) == 1
    assert a.blocks_needed(16) == 1
    assert a.blocks_needed(17) == 2
    # I3: an allocation a sequence's table cannot cover must fail loudly,
    # never clamp (a short allocation would let decode attend garbage KV)
    with pytest.raises(ValueError, match="block"):
        a.allocate(0, 10_000)


def test_allocator_release_reuses_blocks_and_zeroes_table():
    a = kv_pool.BlockAllocator(num_blocks=5, block_size=16, max_batch=2,
                               max_len=64)
    a.allocate(0, 60)                            # all 4 usable blocks
    first = list(a.owned[0])
    assert not a.can_allocate(1)                 # backpressure point
    v0 = a.version
    freed = a.release(0)
    assert sorted(freed) == sorted(first)
    assert np.all(a.tables[0] == 0)              # I4: row zeroed on release
    assert a.version > v0                        # device copy refresh signal
    a.allocate(1, 60)
    assert sorted(a.owned[1]) == sorted(first)   # freed blocks reallocated


def test_write_then_gather_roundtrip():
    """write_cache_paged + gather_pages reproduce a contiguous cache for
    arbitrary (interleaved) block tables."""
    bs, nb, mbs, b, h, d = 8, 7, 3, 2, 2, 4
    pages = jnp.zeros((nb, bs, h, d), jnp.float32)
    # deliberately non-monotone block ownership
    tables = jnp.asarray([[3, 1, 5], [2, 6, 4]], jnp.int32)
    new = jax.random.normal(jax.random.PRNGKey(0), (b, 11, h, d))
    pages = write_cache_paged(pages, new[:, :5], jnp.zeros((b,), jnp.int32),
                              tables, bs)
    pages = write_cache_paged(pages, new[:, 5:], jnp.full((b,), 5, jnp.int32),
                              tables, bs)
    view = gather_pages(pages, tables)           # [B, 24, h, d]
    np.testing.assert_allclose(np.asarray(view[:, :11]), np.asarray(new))
    assert np.all(np.asarray(view[:, 11:]) == 0.0)


def test_write_past_allocation_lands_in_garbage_block():
    bs, nb = 8, 4
    pages = jnp.zeros((nb, bs, 1, 2), jnp.float32)
    tables = jnp.asarray([[2, 0, 0]], jnp.int32)  # 1 block allocated
    new = jnp.ones((1, 6, 1, 2))
    # write straddles the allocation boundary: positions 5..7 -> block 2,
    # 8..10 -> unallocated entry -> garbage block 0 (I1)
    pages = write_cache_paged(pages, new, jnp.full((1,), 5, jnp.int32),
                              tables, bs)
    assert np.all(np.asarray(pages[2, 5:8]) == 1.0)
    assert np.all(np.asarray(pages[0, 0:3]) == 1.0)   # garbage block absorbed
    assert np.all(np.asarray(pages[1]) == 0.0)        # other blocks untouched
    assert np.all(np.asarray(pages[3]) == 0.0)
    # positions past the END of the table (ent >= MBS) also route to the
    # garbage block — never into the row's last real block
    far = write_cache_paged(jnp.zeros((nb, bs, 1, 2)), 7 * jnp.ones((1, 2, 1, 2)),
                            jnp.full((1,), 3 * bs + 2, jnp.int32), tables, bs)
    assert np.all(np.asarray(far[0, 2:4]) == 7.0)
    assert np.all(np.asarray(far[1:]) == 0.0)


@pytest.mark.parametrize("arch", ["tiny-target", "jamba-1.5-large-398b-smoke",
                                  "deepseek-v2-lite-16b-smoke"])
def test_forward_layout_equivalence(arch):
    """Prefill + decode logits must be identical (up to numerics) between
    contiguous caches and a paged pool with scrambled block ownership —
    covers the GQA, MLA and SSM-hybrid cache paths."""
    cfg = get_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                cfg.vocab_size)
    bs, mbs = 8, 4
    tables = jnp.asarray(
        np.random.default_rng(0).permutation(np.arange(1, 9)).reshape(2, 4),
        jnp.int32)

    cont = init_caches(cfg, 2, bs * mbs, dtype=jnp.float32)
    _, cont, _ = forward(params, cfg, tokens, caches=cont,
                         cache_pos=jnp.zeros(2, jnp.int32), dtype=jnp.float32)
    want, _, _ = forward(params, cfg, tokens[:, -1:], caches=cont,
                         cache_pos=jnp.full(2, 12, jnp.int32),
                         dtype=jnp.float32)

    paged = kv_pool.init_paged_caches(cfg, 2, num_blocks=9, block_size=bs,
                                      dtype=jnp.float32)
    _, paged, _ = forward(params, cfg, tokens, caches=paged,
                          cache_pos=jnp.zeros(2, jnp.int32),
                          block_tables=tables, kv_block_size=bs,
                          dtype=jnp.float32)
    out, _, _ = forward(params, cfg, tokens[:, -1:], caches=paged,
                        cache_pos=jnp.full(2, 12, jnp.int32),
                        block_tables=tables, kv_block_size=bs,
                        dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("arch", ["tiny-target", "jamba-1.5-large-398b-smoke",
                                  "deepseek-v2-lite-16b-smoke"])
def test_paged_cache_structure_matches_contiguous(arch):
    """Same pytree structure as init_caches (the engine swaps layouts
    without touching any consumer); attention leaves paged, SSM unchanged."""
    cfg = get_config(arch)
    cont = init_caches(cfg, 2, 64, dtype=jnp.float32)
    paged = kv_pool.init_paged_caches(cfg, 2, num_blocks=9, block_size=8,
                                      dtype=jnp.float32)
    assert (jax.tree.structure(cont) == jax.tree.structure(paged))
    cap = kv_pool.kv_capacity_bytes(cfg, paged)
    per = kv_pool.kv_bytes_per_block(cfg, paged, 9)
    assert cap == per * 9 > 0


# ------------------------------------------------------------ quantized pools
@pytest.mark.parametrize("arch", ["tiny-target",
                                  "deepseek-v2-lite-16b-smoke"])
def test_forward_layout_equivalence_int8(arch):
    """The layout contract survives quantization: contiguous int8 caches
    and a scrambled int8 paged pool see the SAME appended encodings
    (quantization is deterministic per write), so decode logits agree to
    the usual layout tolerance — covers GQA (fused-kernel dequant) and
    MLA (dequant-at-gather) cache paths."""
    cfg = get_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                cfg.vocab_size)
    bs, mbs = 8, 4
    tables = jnp.asarray(
        np.random.default_rng(0).permutation(np.arange(1, 9)).reshape(2, 4),
        jnp.int32)

    cont = init_caches(cfg, 2, bs * mbs, dtype="int8")
    _, cont, _ = forward(params, cfg, tokens, caches=cont,
                         cache_pos=jnp.zeros(2, jnp.int32), dtype=jnp.float32)
    want, _, _ = forward(params, cfg, tokens[:, -1:], caches=cont,
                         cache_pos=jnp.full(2, 12, jnp.int32),
                         dtype=jnp.float32)

    paged = kv_pool.init_paged_caches(cfg, 2, num_blocks=9, block_size=bs,
                                      dtype="int8")
    _, paged, _ = forward(params, cfg, tokens, caches=paged,
                          cache_pos=jnp.zeros(2, jnp.int32),
                          block_tables=tables, kv_block_size=bs,
                          dtype=jnp.float32)
    out, _, _ = forward(params, cfg, tokens[:, -1:], caches=paged,
                        cache_pos=jnp.full(2, 12, jnp.int32),
                        block_tables=tables, kv_block_size=bs,
                        dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("arch", ["tiny-target",
                                  "deepseek-v2-lite-16b-smoke"])
def test_quant_paged_cache_structure_matches_contiguous(arch):
    """The layout swap stays transparent under quantization: contiguous
    int8 caches (with their *_scale leaves) and the int8 paged pool share
    one pytree structure, and byte accounting covers values AND scales."""
    cfg = get_config(arch)
    cont = init_caches(cfg, 2, 64, dtype="int8")
    paged = kv_pool.init_paged_caches(cfg, 2, num_blocks=9, block_size=8,
                                      dtype="int8")
    assert (jax.tree.structure(cont) == jax.tree.structure(paged))
    cap = kv_pool.kv_capacity_bytes(cfg, paged)
    per = kv_pool.kv_bytes_per_block(cfg, paged, 9)
    assert cap == per * 9 > 0
    fp32 = kv_pool.init_paged_caches(cfg, 2, num_blocks=9, block_size=8,
                                     dtype="fp32")
    assert cap * 2 <= kv_pool.kv_capacity_bytes(cfg, fp32)


def test_quant_write_past_allocation_lands_in_garbage_block():
    """I1 under quantization: both the value write AND the scale write for
    positions past the allocation route to garbage block 0."""
    bs, nb = 8, 4
    pages = jnp.zeros((nb, bs, 1, 2), jnp.int8)
    scales = jnp.ones((nb, bs, 1), jnp.float32)
    tables = jnp.asarray([[2, 0, 0]], jnp.int32)     # 1 block allocated
    newq = jnp.ones((1, 6, 1, 2), jnp.int8)
    news = jnp.full((1, 6, 1), 3.0, jnp.float32)
    pages = write_cache_paged(pages, newq, jnp.full((1,), 5, jnp.int32),
                              tables, bs)
    scales = write_cache_paged(scales, news, jnp.full((1,), 5, jnp.int32),
                               tables, bs)
    assert np.all(np.asarray(pages[2, 5:8]) == 1)
    assert np.all(np.asarray(scales[2, 5:8]) == 3.0)
    assert np.all(np.asarray(pages[0, 0:3]) == 1)    # garbage block absorbed
    assert np.all(np.asarray(scales[0, 0:3]) == 3.0)
    assert np.all(np.asarray(pages[1]) == 0)         # other blocks untouched
    assert np.all(np.asarray(scales[1]) == 1.0)
