"""Layered scheduler/executor stack (DESIGN.md §8): chunked prefill fused
into the decode step, FIFO-fair skip-ahead admission, prefill budgeting,
EOS truncation, and per-request latency accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.spec_decode import SpecDecoder
from repro.models import init_params
from repro.serving.engine import Engine


@pytest.fixture(scope="module")
def models():
    tc = get_config("tiny-target")
    dc = get_config("tiny-draft")
    tp = init_params(jax.random.PRNGKey(0), tc)
    dp = init_params(jax.random.PRNGKey(1), dc)
    return tc, tp, dc, dp


def _prompts(rng, n, lo=4, hi=14, vocab=512):
    return [rng.integers(0, vocab, size=int(t)).astype(np.int32)
            for t in rng.integers(lo, hi, size=n)]


# ------------------------------------------------------------ chunked prefill
def test_admission_never_runs_standalone_prefill(models):
    """Acceptance criterion: with >= 2 decoding rows live, admitting a new
    request never runs a standalone prefill forward — target_forwards
    counts STEPS only, prefill happens as chunks inside those steps, and
    the completions still match per-request AR references."""
    tc, tp, dc, dp = models
    rng = np.random.default_rng(20)
    prompts = _prompts(rng, 6, lo=8, hi=20)
    eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=3, max_len=256)
    rids = {eng.submit(p, 10): i for i, p in enumerate(prompts)}
    comps = eng.run()
    assert len(comps) == len(prompts)
    # the structural assert: one target forward per step, nothing else
    assert eng.stats["target_forwards"] == eng.stats["steps"]
    assert eng.stats["prefill_chunks"] > 0
    assert eng.stats["prefill_tokens"] == sum(len(p) - 1 for p in prompts)
    # 6 requests through 3 slots: admissions 4..6 happened while >= 2 rows
    # were decoding, i.e. mixed prefill+decode steps ran
    for c in comps:
        i = rids[c.rid]
        dec = SpecDecoder(tp, tc, dp, dc, k=4, max_len=256)
        ref = np.asarray(dec.generate_ar(
            jnp.asarray(prompts[i])[None], 10)[0][0])
        assert np.array_equal(ref, c.tokens)


def test_mixed_phase_steps_paged_matches_contiguous(models):
    """Acceptance criterion: mixed prefill+decode steps produce identical
    greedy completions in both KV layouts."""
    tc, tp, dc, dp = models
    rng = np.random.default_rng(21)
    prompts = _prompts(rng, 7, lo=6, hi=24)
    results = {}
    for layout in ("contiguous", "paged"):
        eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=2,
                     max_len=256, kv_layout=layout, kv_block_size=32)
        rids = {eng.submit(p, 11): i for i, p in enumerate(prompts)}
        results[layout] = {rids[c.rid]: c.tokens for c in eng.run()}
        assert eng.stats["target_forwards"] == eng.stats["steps"]
    for i in range(len(prompts)):
        assert np.array_equal(results["contiguous"][i], results["paged"][i])


def test_ar_mode_chunked_prefill_matches_reference(models):
    tc, tp, dc, dp = models
    rng = np.random.default_rng(22)
    prompts = _prompts(rng, 5, lo=6, hi=30)
    eng = Engine(tp, tc, dp, dc, mode="ar", max_batch=2, max_len=256,
                 prefill_chunk=8)
    rids = {eng.submit(p, 9): i for i, p in enumerate(prompts)}
    comps = eng.run()
    assert eng.stats["target_forwards"] == eng.stats["steps"]
    for c in comps:
        i = rids[c.rid]
        dec = SpecDecoder(tp, tc, None, None, k=1, max_len=256)
        ref = np.asarray(dec.generate_ar(
            jnp.asarray(prompts[i])[None], 9)[0][0])
        assert np.array_equal(ref, c.tokens)


def test_tree_engine_chunked_prefill(models):
    """Chunked prefill through the tree step: causal chunk masks ride the
    tree-attention kernels; completions still match the AR reference."""
    tc, tp, dc, dp = models
    rng = np.random.default_rng(23)
    prompts = _prompts(rng, 4, lo=8, hi=20)
    eng = Engine(tp, tc, tp, tc, mode="pard", k=4, max_batch=2, max_len=256,
                 kv_layout="paged", kv_block_size=32, tree=(2, 2, 2, 1))
    rids = {eng.submit(p, 10): i for i, p in enumerate(prompts)}
    comps = eng.run()
    assert eng.stats["target_forwards"] == eng.stats["steps"]
    assert eng.stats["prefill_chunks"] > 0
    for c in comps:
        i = rids[c.rid]
        dec = SpecDecoder(tp, tc, tp, tc, k=4, max_len=256)
        ref = np.asarray(dec.generate_ar(
            jnp.asarray(prompts[i])[None], 10)[0][0])
        assert np.array_equal(ref, c.tokens)


def test_tree_chunked_prefill_near_max_len(models):
    """A chain-pinned row admitted at the max_len feasibility bound: the
    prefill cursor runs close to the buffer end, where slicing the chunk at
    the bank-wide window width would clamp and silently shift the prompt —
    the chunk must slice at the (narrower) chunk width instead."""
    tc, tp, dc, dp = models
    from repro.core.spec_decode import TemplateBank
    rng = np.random.default_rng(31)
    bank = TemplateBank.default(4)                   # widest window 23 slots
    max_len, max_new = 128, 6
    dec = SpecDecoder(tp, tc, tp, tc, k=4, max_len=max_len, tree=bank)
    p_len = max_len - max_new - dec.row_slack(0)     # chain slack, exactly
    prompt = rng.integers(0, 512, size=p_len).astype(np.int32)
    eng = Engine(tp, tc, tp, tc, mode="pard", k=4, max_batch=1,
                 max_len=max_len, kv_layout="paged", kv_block_size=32,
                 tree=bank)
    eng.submit(prompt, max_new, tree_idx=0)
    out = eng.run()[0]
    ref_dec = SpecDecoder(tp, tc, tp, tc, k=4, max_len=512)
    ref = np.asarray(ref_dec.generate_ar(
        jnp.asarray(prompt)[None], max_new)[0][0])
    assert np.array_equal(ref, out.tokens)


# ---------------------------------------------------------------- admission
def test_head_of_line_skip_ahead(models):
    """A pool-oversized request at the queue head must not starve smaller
    requests behind it: they admit (within the bounded scan window) while
    the big one waits for blocks, and everything still completes."""
    tc, tp, dc, dp = models
    rng = np.random.default_rng(24)
    small = [rng.integers(0, 512, size=8).astype(np.int32) for _ in range(3)]
    big = rng.integers(0, 512, size=130).astype(np.int32)
    # slack = max(2K, K+1) + 2 = 10; small: 8+8+10=26 -> 1 block of 32;
    # big: 130+8+10=148 -> 5 blocks. Pool: 5 usable -> big needs ALL of it
    eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=2, max_len=256,
                 kv_layout="paged", kv_block_size=32, kv_num_blocks=6)
    r_small0 = eng.submit(small[0], 8)
    r_big = eng.submit(big, 8)
    r_next = eng.submit(small[1], 8)
    r_last = eng.submit(small[2], 8)
    comps = eng.run()
    assert len(comps) == 4
    order = [c.rid for c in comps]
    # small[1], queued BEHIND the infeasible big, overtook it instead of
    # starving (small[2] then legitimately waits: the admitted big holds
    # the whole pool, and it completes afterwards — nothing deadlocks)
    assert order.index(r_small0) < order.index(r_big)
    assert order.index(r_next) < order.index(r_big)
    assert r_last in order
    big_tokens = next(c for c in comps if c.rid == r_big)
    dec = SpecDecoder(tp, tc, dp, dc, k=4, max_len=256)
    ref = np.asarray(dec.generate_ar(jnp.asarray(big)[None], 8)[0][0])
    assert np.array_equal(ref, big_tokens.tokens)


def test_admit_window_bounds_overtaking(models):
    """Requests beyond ``admit_window`` may never jump the queue: with a
    window of 1 the blocked head pins everything behind it (the old strict
    FIFO), so the oversized head admits FIRST once blocks free up."""
    tc, tp, dc, dp = models
    rng = np.random.default_rng(25)
    big = rng.integers(0, 512, size=130).astype(np.int32)
    small = rng.integers(0, 512, size=8).astype(np.int32)
    eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=2, max_len=256,
                 kv_layout="paged", kv_block_size=32, kv_num_blocks=6,
                 admit_window=1)
    r_first = eng.submit(small, 8)
    r_big = eng.submit(big, 8)
    r_last = eng.submit(small, 8)
    comps = eng.run()
    order = [c.rid for c in comps]
    assert order.index(r_first) < order.index(r_big) < order.index(r_last)


def test_oversized_request_still_fails_loudly(models):
    tc, tp, dc, dp = models
    rng = np.random.default_rng(26)
    p = rng.integers(0, 512, size=16).astype(np.int32)
    eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=2, max_len=512,
                 kv_layout="paged", kv_block_size=32, kv_num_blocks=2)
    eng.submit(p, 24)                            # needs 2 blocks; pool has 1
    with pytest.raises(RuntimeError, match="KV blocks"):
        eng.run()


def test_prefill_budget_caps_concurrent_lanes(models):
    """``prefill_budget`` tokens/step caps CONCURRENT prefilling rows at
    budget // chunk lanes — observed across every scheduler tick."""
    tc, tp, dc, dp = models
    rng = np.random.default_rng(27)
    prompts = _prompts(rng, 6, lo=20, hi=40)
    eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=4, max_len=256,
                 prefill_budget=5)               # chunk=K+1=5 -> 1 lane
    assert eng.sched.prefill_lanes == 1
    seen = []
    orig = eng.ex.dispatch

    def spy(*args, **kw):
        seen.append(eng.sched.prefilling_count())
        return orig(*args, **kw)

    eng.ex.dispatch = spy
    for p in prompts:
        eng.submit(p, 8)
    comps = eng.run()
    assert len(comps) == len(prompts)
    assert max(seen) == 1                        # never two prefill lanes
    # control: without a budget the same workload overlaps prefills
    eng2 = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=4, max_len=256)
    seen2 = []
    orig2 = eng2.ex.dispatch

    def spy2(*args, **kw):
        seen2.append(eng2.sched.prefilling_count())
        return orig2(*args, **kw)

    eng2.ex.dispatch = spy2
    for p in prompts:
        eng2.submit(p, 8)
    eng2.run()
    assert max(seen2) > 1


# ------------------------------------------------------------- EOS + latency
def test_eos_truncates_mid_window_commits(models):
    """Regression (ISSUE 5 satellite): tokens speculatively committed AFTER
    an EOS inside the same verify window must not leak into the completion
    or its ``generated`` count."""
    tc, tp, dc, dp = models
    rng = np.random.default_rng(28)
    p = rng.integers(0, 512, size=6).astype(np.int32)
    dec = SpecDecoder(tp, tc, dp, dc, k=4, max_len=256)
    full = np.asarray(dec.generate_ar(jnp.asarray(p)[None], 16)[0][0])
    eos = int(full[len(p) + 5])                  # mid-window position
    eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=1, max_len=256,
                 eos_id=eos, kv_layout="paged", kv_block_size=32)
    eng.submit(p, 16)
    out = eng.run()[0]
    gen = out.tokens[len(p):].tolist()
    assert eos in gen
    # the completion ends AT the eos — nothing committed past it survives
    assert gen.index(eos) == len(gen) - 1
    assert out.generated == len(gen)
    assert np.array_equal(out.tokens, full[:len(out.tokens)])


def test_latency_accounting(models):
    """Every completion records queue wait, TTFT and per-token percentile
    latencies; requests admitted behind a full batch see a positive queue
    wait, and the summary aggregates sanely."""
    tc, tp, dc, dp = models
    rng = np.random.default_rng(29)
    prompts = _prompts(rng, 5, lo=8, hi=16)
    eng = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=2, max_len=256)
    rids = {eng.submit(p, 10): i for i, p in enumerate(prompts)}
    comps = eng.run()
    assert len(comps) == len(prompts)
    for c in comps:
        assert c.queue_wait >= 0.0
        assert c.ttft > c.queue_wait            # first token needs steps
        assert c.wall_done - c.wall_submitted >= c.ttft
        assert 0.0 < c.tok_p50 <= c.tok_p95
    # later requests waited for a slot behind the first two
    by_req = {rids[c.rid]: c for c in comps}
    assert by_req[4].queue_wait > by_req[0].queue_wait
    s = eng.latency_summary()
    assert s["requests"] == len(prompts)
    assert 0 < s["ttft_p50_ms"] <= s["ttft_p95_ms"]
    assert 0 < s["tok_p50_ms"]


def test_prefix_hit_shortens_ttft_steps(models):
    """A full-prefix cache hit skips every prefill chunk: the request's
    first token arrives after strictly fewer engine steps."""
    tc, tp, dc, dp = models
    rng = np.random.default_rng(30)
    prompt = rng.integers(0, 512, size=65).astype(np.int32)  # 64 = 4 blocks

    def steps_to_first(eng):
        eng.submit(prompt, 6)
        before = eng.stats["steps"]
        eng.run()
        c = eng.completions[-1]
        # prefill chunks ran as steps before the first commit
        return eng.stats["steps"] - before, c

    cold = Engine(tp, tc, dp, dc, mode="pard", k=4, max_batch=1, max_len=256,
                  kv_layout="paged", kv_block_size=16, prefix_cache=True)
    n_cold, c_cold = steps_to_first(cold)
    n_warm, c_warm = steps_to_first(cold)        # same engine: cache is hot
    assert cold.prefix_hit_rate() > 0
    assert n_warm < n_cold
    assert np.array_equal(c_cold.tokens, c_warm.tokens)
