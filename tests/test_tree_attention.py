"""Tree-verification attention kernel: Pallas-vs-oracle parity on random
ancestor masks (both cache layouts), degenerate-chain equivalence with the
causal decode kernel, and garbage-block isolation for the paged variant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.spec_decode import TreeTemplate
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(43)


def rand(*shape, k=0, dtype=jnp.float32):
    return jax.random.normal(jax.random.fold_in(KEY, k), shape, jnp.float32
                             ).astype(dtype)


def random_anc(rng, b, tq):
    """Random — not necessarily tree-shaped — ancestor bitmasks. The kernel
    contract is the bitmask semantics, so parity must hold for arbitrary
    masks; self-visibility (bit s of slot s) keeps softmax rows non-empty."""
    bits = rng.integers(0, 2, size=(b, tq, tq)).astype(np.uint64)
    anc = np.zeros((b, tq), np.uint32)
    for s in range(tq):
        bits[:, s, s] = 1
        anc[:, s] = sum(bits[:, s, j].astype(np.uint32) << np.uint32(j)
                        for j in range(tq))
    return jnp.asarray(anc)


def chain_anc(b, tq):
    tmpl = TreeTemplate.flat(tq - 1)
    return jnp.broadcast_to(jnp.asarray(tmpl.anc)[None, :], (b, tq))


@pytest.mark.parametrize("b,tq,hq,hkv,d,s", [
    (2, 9, 4, 2, 64, 256), (1, 15, 8, 2, 32, 128), (3, 5, 4, 4, 32, 96),
])
def test_tree_attention_random_masks(b, tq, hq, hkv, d, s):
    rng = np.random.default_rng(b * 100 + tq)
    q = rand(b, tq, hq, d, k=1)
    k = rand(b, s, hkv, d, k=2)
    v = rand(b, s, hkv, d, k=3)
    win_start = jnp.asarray([s // 2 - 3 * i for i in range(b)], jnp.int32)
    kv_len = win_start + tq
    q_pos = win_start[:, None] + jnp.arange(tq)[None, :]
    anc = random_anc(rng, b, tq)
    out = ops.tree_attention(q, k, v, kv_len, q_pos, win_start, anc,
                             block_k=64)
    want = ref.tree_attention_ref(q, k, v, kv_len, q_pos, win_start, anc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_tree_attention_window_softcap():
    b, tq, h, d, s = 2, 7, 4, 32, 128
    rng = np.random.default_rng(5)
    q, k, v = rand(b, tq, h, d, k=4), rand(b, s, h, d, k=5), rand(b, s, h, d, k=6)
    win_start = jnp.asarray([90, 70], jnp.int32)
    kv_len = win_start + tq
    q_pos = win_start[:, None] + jnp.arange(tq)[None, :]
    anc = random_anc(rng, b, tq)
    out = ops.tree_attention(q, k, v, kv_len, q_pos, win_start, anc,
                             window=32, softcap=30.0, block_k=32)
    want = ref.tree_attention_ref(q, k, v, kv_len, q_pos, win_start, anc,
                                  window=32, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_tree_chain_equals_causal_decode():
    """A degenerate single-branch template's ancestor masks reproduce plain
    causal attention: the tree kernel must agree with the decode kernel."""
    b, tq, h, d, s = 2, 6, 4, 32, 128
    q, k, v = rand(b, tq, h, d, k=7), rand(b, s, h, d, k=8), rand(b, s, h, d, k=9)
    win_start = jnp.asarray([80, 65], jnp.int32)
    kv_len = win_start + tq
    q_pos = win_start[:, None] + jnp.arange(tq)[None, :]
    anc = chain_anc(b, tq)
    out = ops.tree_attention(q, k, v, kv_len, q_pos, win_start, anc,
                             block_k=32)
    want = ops.decode_attention(q, k, v, kv_len, q_pos, block_k=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def _paged_setup(b, hkv, d, bs, mbs, key=0):
    nb = 1 + b * mbs
    k_pages = rand(nb, bs, hkv, d, k=key + 1)
    v_pages = rand(nb, bs, hkv, d, k=key + 2)
    perm = np.random.default_rng(key).permutation(np.arange(1, nb))
    tables = jnp.asarray(perm.reshape(b, mbs), jnp.int32)
    return k_pages, v_pages, tables


@pytest.mark.parametrize("b,tq,hq,hkv,d,bs,mbs", [
    (2, 9, 4, 2, 64, 32, 4),     # small tree verify window
    (1, 22, 8, 2, 32, 64, 3),    # [3,2,1,1]-template-sized window
    (3, 5, 4, 4, 32, 16, 5),
])
def test_tree_attention_paged_random_masks(b, tq, hq, hkv, d, bs, mbs):
    rng = np.random.default_rng(tq)
    q = rand(b, tq, hq, d, k=10)
    win_start = jnp.asarray([bs * mbs // 2 - 5 * i - tq for i in range(b)],
                            jnp.int32)
    kv_len = win_start + tq
    q_pos = win_start[:, None] + jnp.arange(tq)[None, :]
    anc = random_anc(rng, b, tq)
    k_pages, v_pages, tables = _paged_setup(b, hkv, d, bs, mbs)
    out = ops.tree_attention_paged(q, k_pages, v_pages, tables, kv_len,
                                   q_pos, win_start, anc)
    want = ref.tree_attention_paged_ref(q, k_pages, v_pages, tables, kv_len,
                                        q_pos, win_start, anc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
    # cross-layout: the contiguous kernel on the gathered view must agree
    k_cont = ref.gather_pages(k_pages, tables)
    v_cont = ref.gather_pages(v_pages, tables)
    cont = ops.tree_attention(q, k_cont, v_cont, kv_len, q_pos, win_start,
                              anc, block_k=bs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(cont), atol=2e-5)


def test_tree_attention_paged_ignores_garbage_block():
    """Unallocated table entries point at block 0; its contents must never
    leak into the output (kv_len masks them)."""
    b, tq, h, d, bs, mbs = 1, 4, 2, 16, 8, 4
    rng = np.random.default_rng(3)
    q = rand(b, tq, h, d, k=20)
    k_pages = rand(6, bs, h, d, k=21)
    v_pages = rand(6, bs, h, d, k=22)
    tables = jnp.asarray([[3, 5, 0, 0]], jnp.int32)     # 2 real blocks
    win_start = jnp.asarray([10], jnp.int32)
    kv_len = win_start + tq
    q_pos = win_start[:, None] + jnp.arange(tq)[None, :]
    anc = random_anc(rng, b, tq)
    out1 = ops.tree_attention_paged(q, k_pages, v_pages, tables, kv_len,
                                    q_pos, win_start, anc)
    poisoned_k = k_pages.at[0].set(1e4)
    poisoned_v = v_pages.at[0].set(-1e4)
    out2 = ops.tree_attention_paged(q, poisoned_k, poisoned_v, tables,
                                    kv_len, q_pos, win_start, anc)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=0)
