"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family runs one forward and one train step on CPU; output shapes and
finiteness asserted. Also checks prefill+decode consistency against a single
cached forward (the property speculative decoding relies on)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.core.adaptation import ar_loss
from repro.models import (encode, fake_frontend_embed, forward, init_caches,
                          init_params)
from repro.training.optimizer import AdamW


def _setup(name):
    cfg = get_config(name + "-smoke")
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    enc_out = None
    fe = fake_frontend_embed(cfg, 2)
    if cfg.is_encoder_decoder:
        enc_out = encode(params, cfg, fe)
    elif cfg.cross_attn_period:
        enc_out = fe
    return cfg, params, tokens, enc_out, fe


@pytest.mark.parametrize("name", ASSIGNED)
def test_forward_shapes_and_finite(name):
    cfg, params, tokens, enc_out, _ = _setup(name)
    logits, _, aux = forward(params, cfg, tokens, enc_out=enc_out)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    # padded vocab ids can never win an argmax
    assert int(jnp.max(jnp.argmax(logits, -1))) < cfg.vocab_size


@pytest.mark.parametrize("name", ASSIGNED)
def test_train_step(name):
    cfg, params, tokens, enc_out, fe = _setup(name)
    opt = AdamW(lr=1e-3)
    state = opt.init(params)

    def loss_fn(p):
        loss, _ = ar_loss(p, cfg, tokens, dtype=jnp.float32,
                          frontend_embed=fe)
        return loss

    l0 = float(loss_fn(params))
    grads = jax.grad(loss_fn)(params)
    params2, state, om = opt.update(grads, state, params)
    l1 = float(loss_fn(params2))
    assert np.isfinite(l0) and np.isfinite(l1)
    assert float(om["grad_norm"]) > 0.0
    assert l1 < l0 + 1e-3  # one step should not blow the loss up


@pytest.mark.parametrize("name", ASSIGNED)
def test_prefill_decode_consistency(name):
    cfg, params, tokens, enc_out, _ = _setup(name)
    T = tokens.shape[1]
    caches = init_caches(cfg, 2, 64, dtype=jnp.float32)
    full, _, _ = forward(params, cfg, tokens, caches=caches,
                         cache_pos=jnp.zeros(2, jnp.int32), enc_out=enc_out,
                         dtype=jnp.float32)
    caches = init_caches(cfg, 2, 64, dtype=jnp.float32)
    lg, caches, _ = forward(params, cfg, tokens[:, :10], caches=caches,
                            cache_pos=jnp.zeros(2, jnp.int32),
                            enc_out=enc_out, dtype=jnp.float32)
    outs = [lg]
    for t in range(10, T):
        lg, caches, _ = forward(params, cfg, tokens[:, t:t + 1],
                                caches=caches,
                                cache_pos=jnp.full(2, t, jnp.int32),
                                enc_out=enc_out, dtype=jnp.float32)
        outs.append(lg)
    stepped = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(stepped),
                               atol=2e-3, rtol=2e-3)
