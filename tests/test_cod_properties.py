"""Hypothesis property tests for the COD data processor (Algorithm 1) and
the spec-decode invariants."""
import numpy as np
import pytest

# optional dev dependency (requirements-dev.txt): skip cleanly, never break
# collection of the tier-1 suite
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.cod import (CodConfig, check_invariants, pack_sample,
                            packed_len_bound, subtask_sizes)

MASK = 512


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(8, 200),
    k=st.integers(1, 8),
    r=st.floats(0.1, 1.0),
    r_min=st.floats(0.0, 0.5),
    seed=st.integers(0, 10_000),
)
def test_cod_invariants(n, k, r, r_min, seed):
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, 500, size=n)
    cod = CodConfig(k=k, r=r, r_min=r_min)
    packed = pack_sample(tokens, cod, MASK, np.random.default_rng(seed + 1))
    check_invariants(packed, tokens, cod, MASK)


@settings(max_examples=40, deadline=None)
@given(n=st.integers(16, 512), k=st.integers(2, 12), r=st.floats(0.2, 0.9))
def test_cod_token_budget_eq10(n, k, r):
    """Eq. 10: total tokens < N / (1 - r) + subtask-1 overhead, and is
    always <= the no-drop cost K*N."""
    cod = CodConfig(k=k, r=r, r_min=0.0)
    total = int(subtask_sizes(n, cod).sum())
    nodrop = int(subtask_sizes(n, CodConfig(k=k, r=r, drop=False)).sum())
    assert total <= nodrop
    # Eq. 10 bound (+k for rounding slack on each subtask)
    assert total <= n / (1.0 - r) + n * 0.0 + k + n * (r ** 0)  # N + N/(1-r)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(16, 256), k=st.integers(2, 8), seed=st.integers(0, 99))
def test_cod_nesting(n, k, seed):
    """Retained bases must be nested across subtasks (KV completeness)."""
    tokens = np.arange(n) % 500
    cod = CodConfig(k=k, r=0.5, r_min=0.0)
    packed = pack_sample(tokens, cod, MASK, np.random.default_rng(seed))
    seg, base = packed["segment"], packed["base"]
    sets = {s: set(base[seg == s].tolist()) for s in range(2, k + 1)}
    for s in range(3, k + 1):
        assert sets[s] <= sets[s - 1], f"subtask {s} not nested in {s-1}"


def test_packed_len_bound_holds():
    tokens = np.arange(100)
    cod = CodConfig(k=6, r=0.7, r_min=0.2)
    packed = pack_sample(tokens, cod, MASK, np.random.default_rng(0))
    bound = packed_len_bound(100, cod)
    assert int(packed["n_tokens"]) <= bound
    assert int(packed["n_tokens"]) >= bound - cod.k * cod.k  # near-exact


def test_drop_false_covers_all_subtasks():
    n, k = 64, 4
    cod = CodConfig(k=k, drop=False)
    sizes = subtask_sizes(n, cod)
    assert sizes[0] == n
    for s in range(2, k + 1):
        assert sizes[s - 1] == n - s
