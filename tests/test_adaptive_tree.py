"""Per-request tree templates and acceptance-driven reshaping (DESIGN.md
§7): TemplateBank construction, mixed-template batches staying lossless
(greedy rows token-identical to AR, contiguous == paged), per-request paged
allocation sizing (no over/under-allocation when a wide and a chain request
share one batch), the submit() feasibility error path, allocator growth,
the EWMA controller's selection policy, and per-row win_len parity of the
tree-attention kernels against their oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.spec_decode import SpecDecoder, TemplateBank
from repro.kernels import ops, ref
from repro.models import init_params
from repro.serving.engine import Engine, TreeController
from repro.serving.kv_pool import BlockAllocator


@pytest.fixture(scope="module")
def tiny():
    tc = get_config("tiny-target")
    dc = get_config("tiny-draft")
    tp = init_params(jax.random.PRNGKey(0), tc)
    dp = init_params(jax.random.PRNGKey(1), dc)
    return tc, tp, dc, dp


def _prompt(vocab, b=2, p=8, seed=2):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, p), 0, vocab)


def _ragged_prompts(n, seed=21):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 512, size=int(t)).astype(np.int32)
            for t in rng.integers(4, 14, size=n)]


BANK = ((1, 1, 1, 1), (2, 2, 2, 1), (4, 2, 1, 1))


# ---------------------------------------------------------------- bank
def test_template_bank_construction():
    bank = TemplateBank.from_templates(BANK)
    assert len(bank) == 3 and bank.max_depth == 4
    assert bank.max_slots == 29 and bank.max_branching == 4
    assert list(bank.nslots) == [5, 23, 29]
    # padded slots carry zeroed metadata beyond each template's slot count
    for i, t in enumerate(bank.templates):
        ns = t.num_slots
        assert np.array_equal(bank.anc[i, :ns], t.anc)
        assert not bank.anc[i, ns:].any()
        assert not bank.depth[i, ns:].any()
    # the default bank's wide hedge stays within the balanced tree's
    # padded window (22 <= 23 slots) — see TemplateBank.default
    assert TemplateBank.default(4).key == "1x1x1x1|2x2x2x1|3x2x1x1"
    assert TemplateBank.default(4).max_slots == 23


def test_template_bank_rejects_mixed_depth():
    with pytest.raises(AssertionError, match="share one depth"):
        TemplateBank.from_templates(((1, 1, 1, 1), (2, 2)))


def test_row_slack_per_template(tiny):
    tc, tp, dc, dp = tiny
    dec = SpecDecoder(tp, tc, dp, dc, max_len=256,
                      tree=TemplateBank.from_templates(BANK))
    # chain: draft window 2K=8 dominates its 5 slots; wide: 29 slots win
    assert dec.row_slack(0) == 10
    assert dec.row_slack(1) == 25
    assert dec.row_slack(2) == 31
    assert dec.window_slack == 31 and dec.min_row_slack == 10


# ---------------------------------------------- mixed-template batches
def test_mixed_template_batch_lossless(tiny):
    """One generate_spec batch where every row uses a DIFFERENT bank
    template must stay token-identical to AR for every row."""
    tc, tp, dc, dp = tiny
    bank = TemplateBank.from_templates(BANK)
    dec = SpecDecoder(tp, tc, dp, dc, max_len=256, tree=bank)
    prompt = _prompt(tc.vocab_size, b=3)
    ar, _ = dec.generate_ar(prompt, 32)
    sp, stats = dec.generate_spec(prompt, 32, mode="pard",
                                  tree_idx=[0, 1, 2])
    assert bool(jnp.all(ar == sp))
    assert stats.tokens_generated == 32 * 3


def test_mixed_batch_chain_row_identical_to_flat(tiny):
    """A chain-template row inside a mixed batch must reproduce the flat-K
    PARD path token for token — per-row masks and win_len fully isolate it
    from the wide-template rows sharing the batch window."""
    tc, tp, dc, dp = tiny
    prompt = _prompt(tc.vocab_size, b=2)
    flat = SpecDecoder(tp, tc, dp, dc, k=4, max_len=256)
    ref_toks, _ = flat.generate_spec(prompt, 32, mode="pard")
    bank = TemplateBank.from_templates(BANK)
    mixed = SpecDecoder(tp, tc, dp, dc, max_len=256, tree=bank)
    out, _ = mixed.generate_spec(prompt, 32, mode="pard", tree_idx=[0, 2])
    assert bool(jnp.all(ref_toks[0] == out[0]))


def test_engine_mixed_templates_match_ar(tiny):
    """Wide-template and chain requests SHARING one paged batch: every
    completion must match its single-request AR reference (self-draft so
    different shapes really accept different paths)."""
    tc, tp, dc, dp = tiny
    prompts = _ragged_prompts(5)
    refs = {}
    for i, p in enumerate(prompts):
        dec = SpecDecoder(tp, tc, tp, tc, k=4, max_len=256)
        refs[i] = np.asarray(dec.generate_ar(jnp.asarray(p)[None], 12)[0][0])
    eng = Engine(tp, tc, tp, tc, mode="pard", max_batch=2, max_len=256,
                 kv_layout="paged", kv_block_size=32,
                 tree=TemplateBank.from_templates(BANK))
    rids = {eng.submit(p, 12, tree_idx=i % 3): i
            for i, p in enumerate(prompts)}
    comps = eng.run()
    assert len(comps) == len(prompts)
    for c in comps:
        assert np.array_equal(refs[rids[c.rid]], c.tokens)
    assert eng.mean_accepted() > 1.0


def test_engine_mixed_templates_layouts_agree(tiny):
    """Mixed per-request templates must commit identical tokens under the
    contiguous and the block-paged KV layout."""
    tc, tp, dc, dp = tiny
    prompts = _ragged_prompts(4, seed=22)
    results = {}
    for layout in ("contiguous", "paged"):
        eng = Engine(tp, tc, dp, dc, mode="pard", max_batch=2, max_len=256,
                     kv_layout=layout, kv_block_size=32,
                     tree=TemplateBank.from_templates(BANK))
        rids = {eng.submit(p, 12, tree_idx=(i * 2) % 3): i
                for i, p in enumerate(prompts)}
        results[layout] = {rids[c.rid]: c.tokens for c in eng.run()}
    for i in range(len(prompts)):
        assert np.array_equal(results["contiguous"][i], results["paged"][i])


def test_engine_mixed_templates_sampled_layouts_agree(tiny):
    """Per-request templates + per-request temperature: sampled rows keep
    seeded determinism across KV layouts with mixed tree shapes."""
    tc, tp, dc, dp = tiny
    prompts = _ragged_prompts(4, seed=23)
    results = {}
    for layout in ("contiguous", "paged"):
        eng = Engine(tp, tc, tp, tc, mode="pard", max_batch=2, max_len=256,
                     temperature=0.8, seed=5, kv_layout=layout,
                     kv_block_size=32,
                     tree=TemplateBank.from_templates(BANK))
        rids = {}
        for i, p in enumerate(prompts):
            t = 0.0 if i % 2 == 0 else None
            rids[eng.submit(p, 12, temperature=t, tree_idx=i % 3)] = i
        results[layout] = {rids[c.rid]: c.tokens for c in eng.run()}
    for i in range(len(prompts)):
        assert np.array_equal(results["contiguous"][i], results["paged"][i])


# ------------------------------------------------- per-request sizing
def test_per_request_block_allocation(tiny):
    """A chain request and a wide-template request admitted into one paged
    engine must allocate blocks for their OWN window slack — the chain row
    strictly fewer — and both must still match their AR references (no
    under-allocation: every slot a row actually reads is backed)."""
    tc, tp, dc, dp = tiny
    bank = TemplateBank.from_templates(BANK)
    p_len, max_new, bs = 8, 12, 32
    rng = np.random.default_rng(24)
    prompts = [rng.integers(0, 512, size=p_len).astype(np.int32)
               for _ in range(2)]
    eng = Engine(tp, tc, tp, tc, mode="pard", max_batch=2, max_len=256,
                 kv_layout="paged", kv_block_size=bs, tree=bank)
    allocs = {}

    def spy(slot, n, _orig=eng.alloc.allocate):
        _orig(slot, n)
        allocs[slot] = (n, len(eng.alloc.owned[slot]))

    eng.alloc.allocate = spy
    rids = {eng.submit(prompts[0], max_new, tree_idx=0): 0,   # chain
            eng.submit(prompts[1], max_new, tree_idx=2): 1}   # wide
    comps = eng.run()
    dec = SpecDecoder(tp, tc, tp, tc, k=4, max_len=256)
    for c in comps:
        i = rids[c.rid]
        ref_toks = np.asarray(
            dec.generate_ar(jnp.asarray(prompts[i])[None], max_new)[0][0])
        assert np.array_equal(ref_toks, c.tokens)
    # exact per-template sizing: prompt + max_new + row_slack, no more
    need_chain = p_len + max_new + 10          # max(2K, 5) + 2
    need_wide = p_len + max_new + 31           # max(2K, 29) + 2
    assert allocs[0] == (need_chain, -(-need_chain // bs))
    assert allocs[1] == (need_wide, -(-need_wide // bs))
    assert allocs[1][1] > allocs[0][1]


def test_submit_feasibility_uses_per_request_slack(tiny):
    """The submit() need-vs-max_len error path with per-request slack: a
    prompt that fits the chain template but not the wide one is accepted
    unpinned (admission restricts itself to feasible templates), accepted
    pinned to the chain, and rejected pinned to the wide template."""
    tc, tp, dc, dp = tiny
    bank = TemplateBank.from_templates(BANK)
    eng = Engine(tp, tc, tp, tc, mode="pard", max_batch=1, max_len=64,
                 kv_layout="paged", kv_block_size=32, tree=bank)
    prompt = np.arange(10, dtype=np.int32) % 512
    # 10 + 32 + 31 (wide) = 73 > 64, but + 10 (chain) = 52 fits
    with pytest.raises(ValueError, match="cache positions"):
        eng.submit(prompt, 32, tree_idx=2)
    eng.submit(prompt, 32, tree_idx=0)
    eng.submit(prompt, 32)                     # unpinned: feasible subset
    comps = eng.run()
    assert len(comps) == 2
    assert all(c.generated == 32 for c in comps)
    # invalid template index fails loudly too
    with pytest.raises(ValueError, match="tree_idx"):
        eng.submit(prompt, 8, tree_idx=7)
    # contiguous rows are written batch-wide (the bank's widest window):
    # pinning the chain must NOT shrink the requirement there, or the
    # clamped cache write would silently corrupt committed KV near max_len
    cont = Engine(tp, tc, tp, tc, mode="pard", max_batch=1, max_len=64,
                  kv_layout="contiguous", tree=bank)
    with pytest.raises(ValueError, match="cache positions"):
        cont.submit(prompt, 32, tree_idx=0)


def test_block_allocator_grow():
    alloc = BlockAllocator(num_blocks=8, block_size=16, max_batch=2,
                           max_len=128)
    alloc.allocate(0, 30)                      # 2 blocks
    owned = list(alloc.tables[0, :2])
    v0 = alloc.version
    assert alloc.grow(0, 20) and alloc.version == v0       # no-op: covered
    assert alloc.grow(0, 60)                   # 4 blocks now
    assert len(alloc.owned[0]) == 4
    assert list(alloc.tables[0, :2]) == owned  # prefix untouched
    assert alloc.tables[0, 2] != 0 and alloc.tables[0, 3] != 0
    assert alloc.version == v0 + 1
    alloc.allocate(1, 48)                      # 3 blocks -> pool exhausted
    assert not alloc.grow(0, 100)              # would need 3 more; 0 free
    assert len(alloc.owned[0]) == 4            # refusal left it untouched
    alloc.release(0)
    assert len(alloc.free) == 4


# ----------------------------------------------------- the controller
def test_controller_prefers_deep_chain_for_rank0_acceptance():
    """Synthetic stats: rank 0 accepts almost always at every depth, extra
    ranks never — the deep chain maximises expected accepted length."""
    bank = TemplateBank.from_templates(BANK)
    ctrl = TreeController(bank, max_batch=1, ewma=0.5)
    live = np.array([True])
    tree_idx = np.array([2], np.int32)         # wide in use: ranks offered
    rank = np.zeros((1, 4), np.int32)          # rank 0 wins every depth
    for _ in range(60):
        ctrl.update(live, tree_idx, np.array([4]), rank)
    assert ctrl.select(slot=0) == 0            # the chain


def test_controller_prefers_wide_for_rank_spread_acceptance():
    """Synthetic stats: depth 1 accepts only via ranks >= 1 (the target
    argmax lands in top-4 but rarely top-1) and nothing deeper — hedging
    wide at depth 1 beats the chain."""
    bank = TemplateBank.from_templates(BANK)
    ctrl = TreeController(bank, max_batch=1, ewma=0.5)
    live = np.array([True])
    tree_idx = np.array([2], np.int32)
    for i in range(60):
        rank = np.full((1, 4), -1, np.int32)
        rank[0, 0] = 1 + (i % 3)               # ranks 1..3 win depth 1
        ctrl.update(live, tree_idx, np.array([1]), rank)
    assert ctrl.select(slot=0) == 2            # the wide template


def test_adaptive_admission_falls_back_to_pool_sized_template(tiny):
    """A pool sized for the chain template only: the controller's
    optimistic prior would pick a wider tree than the free list can back —
    admission must fall back to the narrowest feasible template and serve
    the request rather than head-of-line block or crash run()."""
    tc, tp, dc, dp = tiny
    rng = np.random.default_rng(26)
    prompt = rng.integers(0, 512, size=8).astype(np.int32)
    # chain need = 8+16+10 = 34 -> 5 blocks of 8; wide needs 7 of 6 usable
    eng = Engine(tp, tc, tp, tc, mode="pard", max_batch=1, max_len=128,
                 kv_layout="paged", kv_block_size=8, kv_num_blocks=7,
                 adaptive_tree=True, tree=TemplateBank.from_templates(BANK))
    eng.submit(prompt, 16)
    comps = eng.run()
    assert len(comps) == 1 and comps[0].generated == 16
    dec = SpecDecoder(tp, tc, tp, tc, k=4, max_len=128)
    ref_toks = np.asarray(
        dec.generate_ar(jnp.asarray(prompt)[None], 16)[0][0])
    assert np.array_equal(ref_toks, comps[0].tokens)


def test_adaptive_engine_lossless_and_accounted(tiny):
    """Greedy losslessness is template-independent, so the adaptive engine
    must match per-request AR references NO MATTER what the controller
    selects or when it reshapes; tree_hist accounts every live step to the
    then-active template."""
    tc, tp, dc, dp = tiny
    prompts = _ragged_prompts(5, seed=25)
    refs = {}
    for i, p in enumerate(prompts):
        dec = SpecDecoder(tp, tc, tp, tc, k=4, max_len=256)
        refs[i] = np.asarray(dec.generate_ar(jnp.asarray(p)[None], 12)[0][0])
    eng = Engine(tp, tc, tp, tc, mode="pard", k=4, max_batch=2, max_len=256,
                 kv_layout="paged", kv_block_size=32, adaptive_tree=True,
                 tree_reselect_every=2)
    rids = {eng.submit(p, 12): i for i, p in enumerate(prompts)}
    comps = eng.run()
    assert len(comps) == len(prompts)
    for c in comps:
        assert np.array_equal(refs[rids[c.rid]], c.tokens)
    assert int(eng.stats["tree_hist"].sum()) == eng.stats["live_steps"]
    assert eng.mean_accepted() > 1.5           # self-draft accepts deeply


# ------------------------------------------------ kernels: win_len
def _qkv(rng, b, tq, s, hq=4, hkv=2, d=16):
    def r(*shape):
        return jnp.asarray(rng.standard_normal(shape), jnp.float32)
    return r(b, tq, hq, d), r(b, s, hkv, d), r(b, s, hkv, d)


def _random_anc(rng, b, tq):
    bits = rng.integers(0, 2, size=(b, tq, tq)).astype(np.uint32)
    anc = np.zeros((b, tq), np.uint32)
    for sl in range(tq):
        bits[:, sl, sl] = 1
        anc[:, sl] = sum(bits[:, sl, j].astype(np.uint32) << np.uint32(j)
                         for j in range(tq))
    return jnp.asarray(anc)


def test_tree_attention_per_row_win_len_matches_ref():
    rng = np.random.default_rng(0)
    b, tq, s = 3, 8, 128
    q, k, v = _qkv(rng, b, tq, s)
    win_start = jnp.asarray([40, 25, 60], jnp.int32)
    kv_len = win_start + tq
    q_pos = win_start[:, None] + jnp.arange(tq)[None, :]
    anc = _random_anc(rng, b, tq)
    win_len = jnp.asarray([3, 8, 5], jnp.int32)    # per-row window sizing
    out = ops.tree_attention(q, k, v, kv_len, q_pos, win_start, anc,
                             win_len=win_len, interpret=True)
    want = ref.tree_attention_ref(q, k, v, kv_len, q_pos, win_start, anc,
                                  win_len=win_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # a row with win_len == tq must equal the no-win_len call exactly
    full = ops.tree_attention(q, k, v, kv_len, q_pos, win_start, anc,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(full[1]),
                               rtol=2e-5, atol=2e-5)


def test_tree_attention_paged_per_row_win_len_matches_ref():
    rng = np.random.default_rng(1)
    b, tq, bs, mbs = 2, 8, 32, 6
    nb = 1 + b * mbs
    q = jnp.asarray(rng.standard_normal((b, tq, 4, 16)), jnp.float32)
    k_pages = jnp.asarray(rng.standard_normal((nb, bs, 2, 16)), jnp.float32)
    v_pages = jnp.asarray(rng.standard_normal((nb, bs, 2, 16)), jnp.float32)
    tables = jnp.asarray(
        1 + np.arange(b * mbs, dtype=np.int32).reshape(b, mbs))
    win_start = jnp.asarray([100, 70], jnp.int32)
    kv_len = win_start + tq
    q_pos = win_start[:, None] + jnp.arange(tq)[None, :]
    anc = _random_anc(rng, b, tq)
    win_len = jnp.asarray([2, 6], jnp.int32)
    out = ops.tree_attention_paged(q, k_pages, v_pages, tables, kv_len,
                                   q_pos, win_start, anc, win_len=win_len,
                                   interpret=True)
    want = ref.tree_attention_paged_ref(q, k_pages, v_pages, tables, kv_len,
                                        q_pos, win_start, anc,
                                        win_len=win_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
