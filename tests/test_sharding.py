"""Sharding rules + a real sharded train step on a host CPU mesh.

The full 512-device production mesh is exercised by the dry-run process
(launch/dryrun.py — separate process because of XLA_FLAGS); here we verify
the spec resolver's divisibility fallbacks and that a pjit'd step runs on
whatever devices the test process has.
"""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.steps import cache_shapes, param_shapes
from repro.sharding.specs import cache_specs, data_spec, param_specs


class FakeMesh:
    """Stands in for a (16,16) production mesh in spec-resolution tests."""
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape)


MESH = FakeMesh((16, 16), ("data", "model"))


def _leaves_with_paths(tree, prefix=""):
    if isinstance(tree, P):
        # PartitionSpec subclasses tuple — it is a LEAF, not a container
        yield prefix, tree
    elif isinstance(tree, dict):
        for k, v in tree.items():
            yield from _leaves_with_paths(v, f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _leaves_with_paths(v, f"{prefix}/#{i}")
    elif tree is not None:
        yield prefix, tree


def test_param_specs_divisibility():
    """Every sharded dim must divide the mesh axis size — across ALL 10
    assigned archs (this is what makes the production dry-run lower)."""
    from repro.configs import ASSIGNED
    for name in ASSIGNED:
        cfg = get_config(name)
        params = param_shapes(cfg)
        specs = param_specs(params, MESH, fsdp=True)
        flat_p = dict(_leaves_with_paths(params))
        flat_s = dict(_leaves_with_paths(specs))
        for path, sds in flat_p.items():
            spec = flat_s[path]
            assert len(spec) <= len(sds.shape), (name, path)
            for dim, ax in zip(sds.shape, tuple(spec) + (None,) * 10):
                if ax is None:
                    continue
                size = {"data": 16, "model": 16}[ax if isinstance(ax, str)
                                                 else ax[0]]
                assert dim % size == 0, (name, path, sds.shape, spec)


def test_kv_head_fallback():
    """kv=8 heads cannot shard over model=16 -> the rule must fall back to
    sharding the d_model row dim instead of producing an invalid spec."""
    cfg = get_config("command-r-35b")       # kv=8
    params = param_shapes(cfg)
    specs = param_specs(params, MESH, fsdp=False)
    wk_spec = specs["scan"][0]["mixer"]["wk"]
    assert "model" in tuple(wk_spec), wk_spec
    # and it must NOT be on the kv-head dim (index -2 of [d, hkv, hd])
    assert tuple(wk_spec)[-2] != "model"


def test_minicpm3_head_fallback():
    """40 q heads don't divide 16 -> row-parallel fallback."""
    cfg = get_config("minicpm3-4b")
    params = param_shapes(cfg)
    specs = param_specs(params, MESH, fsdp=False)
    for path, spec in _leaves_with_paths(specs):
        for dim_ax in [tuple(spec)]:
            pass  # structure validated by test_param_specs_divisibility


def test_data_spec_fallbacks():
    assert tuple(data_spec(MESH, 256, 2)) == ("data", None)
    assert tuple(data_spec(MESH, 1, 2)) == (None, None)
    m3 = FakeMesh((2, 16, 16), ("pod", "data", "model"))
    assert tuple(data_spec(m3, 256, 2))[0] == ("pod", "data")
    assert tuple(data_spec(m3, 1, 2)) == (None, None)


def test_cache_specs_long_context_seq_sharding():
    """batch=1: KV cache must shard its sequence dim over data."""
    cfg = get_config("gemma2-27b")
    caches = cache_shapes(cfg, 1, 8192)
    specs = cache_specs(caches, cfg, MESH, 1)
    k_spec = tuple(specs["scan"][0]["k"])
    # [R, B, S, Hkv, hd] -> S (index 2) on data
    assert k_spec[2] == "data"


def test_sharded_train_step_runs_on_host_mesh():
    """End-to-end pjit train step on the test process's devices."""
    n = jax.device_count()
    # jax.sharding.AxisType landed after 0.4.x; plain make_mesh axes are
    # already Auto-typed under the installed API
    mesh = jax.make_mesh((n, 1), ("data", "model"))
    cfg = get_config("tiny-draft")
    from repro.training.optimizer import AdamW
    from repro.training.train_loop import Trainer
    from repro.models import init_params
    from jax.sharding import NamedSharding

    params = init_params(jax.random.PRNGKey(0), cfg)
    pspec = param_specs(params, mesh, fsdp=False)
    psharding = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                             is_leaf=lambda x: isinstance(x, P))
    tr = Trainer(cfg, AdamW(lr=1e-3), loss_kind="ar", mesh=mesh,
                 param_sharding=psharding,
                 data_sharding={"tokens": NamedSharding(mesh, P("data", None))})
    params = jax.device_put(params, psharding)
    tokens = jnp.zeros((n * 2, 32), jnp.int32)
    state = tr.init_state(params)
    p2, s2, m = tr._step(params, state, {"tokens": tokens})
    assert np.isfinite(float(m["loss"]))
