import os

# Tests must see exactly ONE device (the dry-run sets 512 in its own
# process); keep any user XLA_FLAGS out of the test environment.
# Exception: REPRO_HOST_DEVICES=N opts a test run into N forced host
# devices (the sharded-serving suite in CI's shard-gate job) — set by us
# AFTER the pop so stray user flags still never leak in.
os.environ.pop("XLA_FLAGS", None)
_n = os.environ.get("REPRO_HOST_DEVICES")
if _n:
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={_n}"

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
