import os

# Tests must see exactly ONE device (the dry-run sets 512 in its own
# process); keep any user XLA_FLAGS out of the test environment.
os.environ.pop("XLA_FLAGS", None)

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
