"""Sharded multi-device serving (DESIGN.md §11).

Single-device portion (tier-1): spec resolution for the reduction-free
serving ruleset, paged-pool / quant-scale / draft shardings, mesh helpers,
and mesh-of-1 == no-mesh token identity.

Multi-device portion (CI shard-gate: REPRO_HOST_DEVICES=4): token identity
across mesh shapes 1/2/4 for mixed greedy + seeded-sampled batches in both
KV layouts, and through the pipelined loop.
"""
import os

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import mesh as mesh_mod
from repro.models import init_params
from repro.serving import kv_pool
from repro.serving.config import EngineConfig, SamplingParams
from repro.serving.engine import Engine
from repro.sharding import specs

needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4 host devices (REPRO_HOST_DEVICES=4)")


@pytest.fixture(scope="module")
def models():
    tc = get_config("tiny-target")
    dc = get_config("tiny-draft")
    tp = init_params(jax.random.PRNGKey(0), tc)
    dp = init_params(jax.random.PRNGKey(1), dc)
    return tc, tp, dc, dp


def _mesh1():
    return mesh_mod.make_host_mesh(model=1, data=1)


def _walk(tree, prefix=""):
    """Path/leaf pairs with PartitionSpec treated as a LEAF (it subclasses
    tuple, so the generic walkers would iterate into it)."""
    if isinstance(tree, P):
        yield prefix, tree
    elif isinstance(tree, dict):
        for k, v in tree.items():
            yield from _walk(v, f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _walk(v, f"{prefix}/{i}")
    elif tree is not None:
        yield prefix, tree


def _by_name(spec_tree, name):
    return [(p, s) for p, s in _walk(spec_tree)
            if p.rsplit("/", 1)[-1] == name]


# ----------------------------------------------------------- spec resolution
def test_serving_param_rules_shard_output_dims_only(models):
    tc, tp, dc, dp = models
    mesh = _mesh1()
    sp = specs.param_specs(tp, mesh, serving=True)
    found = {}
    for path, s in _walk(sp):
        found.setdefault(path.rsplit("/", 1)[-1], []).append((path, s))
    # projections shard their OUTPUT dim (heads / d_ff / d_model-out) —
    # never a contraction dim — so no partial-sum reduce can appear.
    # Scanned layers pad a leading None (the repeats axis): compare tails.
    def tail(s, n):
        assert all(a is None for a in s[:-n]), s
        return tuple(s[-n:])

    for p, s in found.get("wq", []):
        assert tail(s, 3) == (None, "model", None), (p, s)
    for p, s in found.get("wo", []):
        assert tail(s, 2) == (None, "model"), (p, s)
    for p, s in found.get("wi", []):
        assert tail(s, 2) == (None, "model"), (p, s)
    for p, s in found.get("embedding", []):
        assert s == P("model", None), (p, s)
    # norms replicate
    for p, s in found.get("scale", []):
        assert all(a is None for a in s), (p, s)
    assert found.get("wq") and found.get("wi"), "tiny-target layout changed?"


def test_paged_pool_specs_shard_kv_heads_and_scales(models):
    tc, _, dc, _ = models
    mesh = _mesh1()
    pool = kv_pool.init_paged_caches(tc, 2, 8, 16, dtype="int8")
    sp = specs.paged_cache_specs(pool, mesh)
    ks, kss = _by_name(sp, "k"), _by_name(sp, "k_scale")
    assert ks and kss, "quantized paged pool must carry k + k_scale leaves"
    for p, s in ks:                      # [.., NB, bs, Hkv, hd]
        assert s[-2] == "model" and s[-1] is None, (p, s)
        assert all(a is None for a in s[:-2]), (p, s)
    for p, s in kss:                     # [.., NB, bs, Hkv]
        assert s[-1] == "model", (p, s)
        assert all(a is None for a in s[:-1]), (p, s)


def test_draft_replicates(models):
    _, _, dc, dp = models
    sp = specs.replicated_specs(dp)
    leaves = list(_walk(sp))
    assert leaves and all(s == P() for _, s in leaves)


def test_host_mesh_validation():
    m = _mesh1()
    assert m.axis_names == ("data", "model")
    with pytest.raises(ValueError, match="must be >= 1"):
        mesh_mod.make_host_mesh(model=1, data=0)
    n = jax.device_count()
    with pytest.raises(ValueError, match="divide"):
        mesh_mod.make_host_mesh(model=n + 1)
    with pytest.raises(ValueError, match="needs"):
        mesh_mod.make_host_mesh(model=1, data=n + 1)


def test_ensure_host_devices_too_late(monkeypatch):
    # keep the env-flag mutation from leaking into other tests
    monkeypatch.setenv("XLA_FLAGS", os.environ.get("XLA_FLAGS", ""))
    # jax is long initialized by the time tests run: asking for more
    # devices than the live backend exposes must fail loudly
    with pytest.raises(RuntimeError, match="host devices"):
        mesh_mod.ensure_host_devices(jax.device_count() + 1)
    mesh_mod.ensure_host_devices(jax.device_count())    # no-op, satisfied


def test_config_builds_mesh_from_tp():
    cfg = EngineConfig(tp=1)
    assert cfg.mesh is None                # tp=1 = single-device serving
    with pytest.raises(ValueError, match="model"):
        EngineConfig(mesh=jax.sharding.Mesh(
            np.asarray(jax.devices()[:1]).reshape(1), ("data",)))


# ------------------------------------------------------------ token identity
def _serve(models, mesh, layout, pipelined=False, n_req=4, max_new=12):
    tc, tp, dc, dp = models
    cfg = EngineConfig(mode="pard", k=4, max_batch=2, max_len=256,
                      kv_layout=layout, kv_block_size=16, seed=3,
                      pipelined=pipelined, mesh=mesh)
    eng = Engine(tp, tc, dp, dc, config=cfg)
    rng = np.random.default_rng(7)
    out_rids = {}
    for i in range(n_req):
        p = rng.integers(0, 512, size=int(rng.integers(4, 14))).astype(
            np.int32)
        # mixed batch: even rows greedy, odd rows sampled with pinned seeds
        sp = SamplingParams(max_new=max_new,
                            temperature=0.0 if i % 2 == 0 else 0.8,
                            seed=None if i % 2 == 0 else 100 + i)
        out_rids[eng.submit(p, params=sp)] = i
    return {out_rids[c.rid]: c.tokens for c in eng.run()}


@pytest.mark.parametrize("layout", ["paged", "contiguous"])
def test_mesh_of_one_matches_no_mesh(models, layout):
    """A (1, 1) mesh engine — full sharded code path: serving rules,
    state shardings, pinned jit shardings — is token-identical to the
    meshless engine."""
    base = _serve(models, None, layout)
    one = _serve(models, _mesh1(), layout)
    assert base.keys() == one.keys()
    for i in base:
        assert np.array_equal(base[i], one[i]), f"request {i} diverged"


@needs4
@pytest.mark.parametrize("layout", ["paged", "contiguous"])
def test_token_identity_across_mesh_shapes(models, layout):
    """THE tentpole gate: meshes of 1, 2 and 4 devices produce bitwise-
    identical tokens for a mixed greedy + seeded-sampled batch."""
    base = _serve(models, mesh_mod.make_host_mesh(model=1, data=1), layout)
    for n in (2, 4):
        got = _serve(models, mesh_mod.make_host_mesh(model=n, data=1),
                     layout)
        assert base.keys() == got.keys()
        for i in base:
            assert np.array_equal(base[i], got[i]), \
                f"request {i} diverged on the {n}-device mesh"


@needs4
def test_sharded_pipelined_loop_identity(models):
    """The depth-2 dispatch/harvest pipeline (DESIGN.md §9) composes with
    tensor-parallel serving: same tokens as the synchronous tp=1 loop."""
    base = _serve(models, None, "paged", pipelined=False)
    got = _serve(models, mesh_mod.make_host_mesh(model=2, data=1), "paged",
                 pipelined=True)
    assert base.keys() == got.keys()
    for i in base:
        assert np.array_equal(base[i], got[i])
