"""Throughput tensor-parallel serving ruleset (DESIGN.md §13).

Single-device portion (tier-1): the two serving rulesets agree on every
leaf outside the declared divergent set, indivisible dims replicate (never
contraction-split) in the exact ruleset, the canonical-chunk feasibility
fallback replicates a contraction dim ROWPARALLEL_CHUNKS does not divide
even when the (smaller) mesh would, and ``rowparallel_einsum``'s inline
chunk emulation reproduces the documented f32-once combine.

Multi-device portion (CI shard-gate throughput leg,
REPRO_HOST_DEVICES=4): the empirical psum law — XLA CPU's bf16
all-reduce equals f32-upcast-sum-round-once — against a real 4-way psum,
and bitwise tp2/tp4-vs-tp1 greedy/sampled token identity of the
throughput ruleset in both KV layouts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.kernels import ops
from repro.launch import mesh as mesh_mod
from repro.models import init_params
from repro.serving.config import EngineConfig, SamplingParams
from repro.serving.engine import Engine
from repro.sharding import specs

needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4 host devices (REPRO_HOST_DEVICES=4)")


@pytest.fixture(scope="module")
def models():
    tc = get_config("tiny-target")
    dc = get_config("tiny-draft")
    tp = init_params(jax.random.PRNGKey(0), tc)
    dp = init_params(jax.random.PRNGKey(1), dc)
    return tc, tp, dc, dp


class _FakeMesh:
    """Just enough Mesh surface for spec resolution (axis sizes without
    instantiating devices this host does not have)."""
    def __init__(self, model):
        self.axis_names = ("data", "model")
        self.devices = np.empty((1, model))


def _walk(tree, prefix=""):
    if isinstance(tree, P):
        yield prefix, tree
    elif isinstance(tree, dict):
        for k, v in tree.items():
            yield from _walk(v, f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _walk(v, f"{prefix}/{i}")
    elif tree is not None:
        yield prefix, tree


def _by_name(spec_tree, name):
    return [(p, s) for p, s in _walk(spec_tree)
            if p.rsplit("/", 1)[-1] == name]


# ------------------------------------------------------------ rule agreement
def test_rulesets_agree_outside_divergent_leaves():
    """Only the contraction-side weights and the replicated embedding pair
    may differ between the serving rulesets — everything else (the
    column-parallel up-projections, norms, biases) must stay identical so
    the throughput ruleset inherits the exact ruleset's layout choices."""
    assert set(specs.THROUGHPUT_PARAM_RULES) == set(specs.SERVING_PARAM_RULES)
    for name, rule in specs.SERVING_PARAM_RULES.items():
        thr = specs.THROUGHPUT_PARAM_RULES[name]
        if name in specs.RULESET_DIVERGENT_LEAVES:
            assert thr != rule, f"{name}: declared divergent but identical"
        else:
            assert thr == rule, f"{name}: rulesets diverge off-list"
    assert specs.THROUGHPUT_MLP_WO_RULES != specs.SERVING_MLP_WO_RULES
    # the divergent leaves shard the CONTRACTION dim (axis 0 of the
    # trailing spec for 2-D wo/out_proj, the middle f dim for 3-D we_o)
    assert specs.THROUGHPUT_PARAM_RULES["wo"] == [("tp", None, None)]
    assert specs.THROUGHPUT_PARAM_RULES["we_o"] == [(None, "tp", None)]
    assert specs.THROUGHPUT_PARAM_RULES["out_proj"] == [("tp", None)]
    assert specs.THROUGHPUT_MLP_WO_RULES == [("tp", None)]
    # the tied embedding/unembed replicate (no vocab-parallel collectives)
    assert specs.THROUGHPUT_PARAM_RULES["embedding"] == [(None, None)]
    assert specs.THROUGHPUT_PARAM_RULES["unembed"] == [(None, None)]


def test_exact_ruleset_indivisible_dims_replicate(models):
    """tiny-draft has 2 heads / 2 kv-heads: on a (fake) 4-way model mesh
    the exact ruleset must REPLICATE those projections — its single
    output-dim candidate is infeasible and there is no contraction-dim
    fallback that could smuggle in a partial-sum reduce."""
    _, _, dc, dp = models
    sp = specs.param_specs(dp, _FakeMesh(4), serving=True)
    hits = 0
    for name in ("wq", "wk", "wv"):
        for path, s in _by_name(sp, name):
            assert all(a is None for a in s), (path, s)
            hits += 1
    assert hits, "tiny-draft attention layout changed?"


def test_exact_ruleset_never_shards_contraction(models):
    """On the feasible tiny-target tp4 layout the exact ruleset shards
    attention wo on its OUTPUT d_model dim, never the heads contraction."""
    tc, tp, _, _ = models
    sp = specs.param_specs(tp, _FakeMesh(4), serving=True)
    rows = _by_name(sp, "wo")
    assert rows
    for path, s in rows:
        n = 3 if "mixer" in path else 2
        assert tuple(s[-n:])[-1] == "model" and s[-n] is None, (path, s)


def test_canonical_chunk_feasibility(models):
    """A contraction dim that ROWPARALLEL_CHUNKS (=4) does not divide must
    replicate under the throughput ruleset EVEN on a 2-way mesh that would
    divide it — the chunk count, not the mesh, pins the numerics.
    tiny-draft attention has 2 heads: 2 %% 4 != 0, so its wo replicates at
    tp2; tiny-target's 4 heads shard."""
    tc, tp, dc, dp = models
    dsp = specs.param_specs(dp, _FakeMesh(2), serving=True,
                            ruleset="throughput")
    for path, s in _by_name(dsp, "wo"):
        if "mixer" in path:          # attention wo [H, hd, d], H=2
            assert all(a is None for a in s), (path, s)
    tsp = specs.param_specs(tp, _FakeMesh(2), serving=True,
                            ruleset="throughput")
    hits = 0
    for path, s in _by_name(tsp, "wo"):
        if "mixer" in path:          # attention wo [H, hd, d], H=4
            assert tuple(s[-3:]) == ("model", None, None), (path, s)
            hits += 1
        else:                        # mlp wo [f, d], f=256
            assert tuple(s[-2:]) == ("model", None), (path, s)
    assert hits


def test_param_specs_rejects_unknown_ruleset(models):
    tc, tp, _, _ = models
    with pytest.raises(ValueError, match="ruleset"):
        specs.param_specs(tp, _FakeMesh(2), serving=True, ruleset="fast")


def test_engine_config_validates_tp_ruleset():
    with pytest.raises(ValueError, match="tp_ruleset"):
        EngineConfig(tp_ruleset="megatron")
    assert EngineConfig(tp_ruleset="throughput").tp_ruleset == "throughput"


# ------------------------------------------------- rowparallel_einsum numerics
def _canonical(x, w, nc=4):
    """Reference canonical-chunk combine: bf16 partial per chunk, ONE
    f32-upcast sum, rounded to the compute dtype once."""
    parts = [jnp.einsum("bf,fd->bd", xc, wc)
             for xc, wc in zip(jnp.split(x, nc, axis=1),
                               jnp.split(w, nc, axis=0))]
    return sum(p.astype(jnp.float32) for p in parts).astype(x.dtype)


def test_rowparallel_einsum_no_mesh_is_plain_einsum():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (6, 64), jnp.bfloat16)
    w = jax.random.normal(k2, (64, 32), jnp.bfloat16)
    got = ops.rowparallel_einsum("bf,fd->bd", x, w, x_axis=-1, w_axis=0)
    assert jnp.array_equal(got, jnp.einsum("bf,fd->bd", x, w))


def test_rowparallel_einsum_chunk_emulation_matches_canonical():
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(k1, (6, 64), jnp.bfloat16)
    w = jax.random.normal(k2, (64, 32), jnp.bfloat16)
    mesh = mesh_mod.make_host_mesh(model=1, data=1)
    with ops.activation_mesh(mesh, "throughput"):
        got = ops.rowparallel_einsum("bf,fd->bd", x, w, x_axis=-1, w_axis=0)
    ref = _canonical(x, w)
    assert jnp.array_equal(got, ref)
    # ... and stays within bf16 rounding noise of the whole contraction
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(jnp.einsum("bf,fd->bd", x, w),
                                          np.float32),
                               rtol=0.05, atol=0.5)


def test_rowparallel_einsum_indivisible_falls_back_bitwise():
    """A contraction dim 4 does not divide takes the gather path — plain
    whole contraction, bitwise equal to the no-ruleset einsum."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    x = jax.random.normal(k1, (6, 30), jnp.bfloat16)
    w = jax.random.normal(k2, (30, 32), jnp.bfloat16)
    mesh = mesh_mod.make_host_mesh(model=1, data=1)
    with ops.activation_mesh(mesh, "throughput"):
        got = ops.rowparallel_einsum("bf,fd->bd", x, w, x_axis=-1, w_axis=0)
    assert jnp.array_equal(got, jnp.einsum("bf,fd->bd", x, w))


@needs4
def test_psum_bf16_is_f32_upcast_sum_rounded_once():
    """The empirical law the throughput numerics were designed around:
    XLA CPU's bf16 all-reduce upcasts to f32, sums (order-free for 4
    bf16-valued terms — exact in f32), and rounds to bf16 once. The HLO
    shows the reduction computation ``promoted``; here it is pinned
    behaviorally against a real 4-way psum."""
    from jax.experimental.shard_map import shard_map
    mesh = mesh_mod.make_host_mesh(model=4, data=1)
    rng = np.random.default_rng(0)
    parts = jnp.asarray(
        rng.normal(size=(4, 256)) * 10.0 ** rng.integers(-2, 3, (4, 256)),
        jnp.bfloat16)

    @jax.jit
    def psum4(p):
        f = shard_map(lambda s: jax.lax.psum(s, "model"), mesh=mesh,
                      in_specs=P(("model",), None), out_specs=P())
        return f(p)

    # each shard holds a (1, 256) slice, so the psum'd output keeps the
    # collapsed leading axis at size 1
    got = psum4(parts).reshape(-1)
    ref = jnp.sum(parts.astype(jnp.float32), axis=0).astype(jnp.bfloat16)
    assert jnp.array_equal(got, ref)


# -------------------------------------------------------- cross-mesh identity
def _serve(models, mesh, layout, ruleset, n_req=4, max_new=12):
    tc, tp, dc, dp = models
    cfg = EngineConfig(mode="pard", k=4, max_batch=2, max_len=256,
                       kv_layout=layout, kv_block_size=16, seed=3,
                       mesh=mesh, tp_ruleset=ruleset)
    eng = Engine(tp, tc, dp, dc, config=cfg)
    rng = np.random.default_rng(7)
    out_rids = {}
    for i in range(n_req):
        p = rng.integers(0, 512, size=int(rng.integers(4, 14))).astype(
            np.int32)
        sp = SamplingParams(max_new=max_new,
                            temperature=0.0 if i % 2 == 0 else 0.8,
                            seed=None if i % 2 == 0 else 100 + i)
        out_rids[eng.submit(p, params=sp)] = i
    return {out_rids[c.rid]: c.tokens for c in eng.run()}


@needs4
@pytest.mark.parametrize("layout", ["paged", "contiguous"])
def test_throughput_identity_across_mesh_shapes(models, layout):
    """The throughput ruleset's canonical-chunk numerics make every mesh
    size round the same f32 partial sum once — greedy AND seeded-sampled
    completions at tp2/tp4 match the throughput-tp1 reference at >= 0.99
    positional exact-match (bitwise in practice), in both KV layouts."""
    base = _serve(models, mesh_mod.make_host_mesh(model=1, data=1),
                  layout, "throughput")
    for n in (2, 4):
        got = _serve(models, mesh_mod.make_host_mesh(model=n, data=1),
                     layout, "throughput")
        assert base.keys() == got.keys()
        match = total = 0
        for i in base:
            a, b = np.asarray(base[i]), np.asarray(got[i])
            m = min(len(a), len(b))
            match += int(np.sum(a[:m] == b[:m]))
            total += max(len(a), len(b))
        rate = match / max(1, total)
        assert rate >= 0.99, \
            f"tp{n}/{layout}: exact-match rate {rate:.4f} < 0.99"
