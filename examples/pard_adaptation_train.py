"""PARD adaptation pipeline — trains the tiny model family used by the
benchmarks and demonstrates the paper's full training recipe end-to-end:

  1. AR-pretrain a target model and a smaller draft model on the same
     corpus (stand-ins for e.g. LLaMA3.1-8B and LLaMA3.2-1B);
  2. adapt the draft into a PARD parallel draft with mask-token training
     (Eq. 8) under Conditional Drop (Alg. 1) for several (K, r, r_min)
     settings — these power the Fig. 6a/6b ablation benchmarks;
  3. checkpoint everything under benchmarks/artifacts/.

Run:  PYTHONPATH=src python examples/pard_adaptation_train.py [--quick]
"""
import argparse
import json
import os
import sys
import time

import jax

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.core.cod import CodConfig
from repro.data.pipeline import MarkovCorpus
from repro.models import init_params
from repro.training import checkpoint
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.train_loop import Trainer

ART = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "artifacts")

# the corpus stands in for the paper's code/math corpora: highly predictable
# sequential structure (high acceptance regime, like HumanEval/GSM8K)
CORPUS = dict(vocab_size=512, seed=0, determinism=3.0, branching=4)

AR_RUNS = [("bench-target", 0), ("bench-draft", 1), ("bench-mid", 2)]

# (tag, k_train, r, r_min, drop)
PARD_RUNS = [
    ("pard_k8_r07", 8, 0.7, 0.2, True),     # the paper's setting
    ("pard_k8_r05", 8, 0.5, 0.1, True),     # aggressive drop (Fig. 6a)
    ("pard_k8_nodrop", 8, 1.0, 1.0, False),  # full mask training (Fig. 6a)
    ("pard_k2_r07", 2, 0.7, 0.2, True),     # K_train sweep (Fig. 6b)
    ("pard_k4_r07", 4, 0.7, 0.2, True),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny step counts (smoke only)")
    ap.add_argument("--ar-steps", type=int, default=300)
    ap.add_argument("--pard-steps", type=int, default=300)
    args = ap.parse_args()
    if args.quick:
        args.ar_steps, args.pard_steps = 30, 20

    os.makedirs(ART, exist_ok=True)
    corpus = MarkovCorpus(**CORPUS)
    manifest = {"corpus": CORPUS, "ar_steps": args.ar_steps,
                "pard_steps": args.pard_steps, "runs": {}}

    # ---- stage 1: AR pretraining ---------------------------------------
    for name, seed in AR_RUNS:
        path = os.path.join(ART, f"{name}.npz")
        cfg = get_config(name)
        if os.path.exists(path):
            print(f"[skip] {name} (exists)")
            manifest["runs"][name] = checkpoint.load_metadata(path)
            continue
        t0 = time.time()
        params = init_params(jax.random.PRNGKey(seed), cfg)
        tr = Trainer(cfg, AdamW(lr=cosine_schedule(3e-3, 30, args.ar_steps)),
                     loss_kind="ar")
        params, _, hist = tr.fit(params, corpus.batches(8, 48, seed=seed),
                                 args.ar_steps, log_every=100)
        meta = {"loss": hist[-1]["loss"], "steps": args.ar_steps,
                "wall_s": round(time.time() - t0, 1)}
        checkpoint.save(path, params, metadata=meta)
        manifest["runs"][name] = meta
        print(f"[done] {name}: {meta}")

    # ---- stage 2: PARD adaptation of the draft -------------------------
    dc = get_config("bench-draft")
    base_draft = checkpoint.restore(
        os.path.join(ART, "bench-draft.npz"),
        init_params(jax.random.PRNGKey(1), dc))

    for tag, k, r, r_min, drop in PARD_RUNS:
        path = os.path.join(ART, f"{tag}.npz")
        if os.path.exists(path):
            print(f"[skip] {tag} (exists)")
            manifest["runs"][tag] = checkpoint.load_metadata(path)
            continue
        t0 = time.time()
        cod = CodConfig(k=k, r=r, r_min=r_min, drop=drop)
        tr = Trainer(dc, AdamW(lr=cosine_schedule(2.5e-3, 30, args.pard_steps)),
                     loss_kind="pard", cod=cod)
        params, _, hist = tr.fit(base_draft, corpus.batches(8, 64, seed=91),
                                 args.pard_steps, log_every=100)
        meta = {"loss": hist[-1]["loss"],
                "token_nll": hist[-1]["token_mean_nll"],
                "train_tokens": hist[-1]["tokens"],
                "wall_s": round(time.time() - t0, 1),
                "cod": dict(k=k, r=r, r_min=r_min, drop=drop)}
        checkpoint.save(path, params, metadata=meta)
        manifest["runs"][tag] = meta
        print(f"[done] {tag}: {meta}")

    # ---- stage 3: EAGLE-style head for the comparison benchmarks --------
    eagle_path = os.path.join(ART, "eagle_head.npz")
    if not os.path.exists(eagle_path):
        from repro.core.eagle import eagle_loss, init_eagle
        tc = get_config("bench-target")
        tparams = checkpoint.restore(os.path.join(ART, "bench-target.npz"),
                                     init_params(jax.random.PRNGKey(0), tc))
        ep = init_eagle(jax.random.PRNGKey(9), tc)
        opt = AdamW(lr=cosine_schedule(2e-3, 20, args.pard_steps))
        state = opt.init(ep)
        stream = corpus.batches(8, 48, seed=77)
        import jax.numpy as jnp

        @jax.jit
        def estep(ep, state, tokens):
            (loss, _), g = jax.value_and_grad(
                lambda e: eagle_loss(e, tparams, tc, tokens),
                has_aux=True)(ep)
            ep, state, _ = opt.update(g, state, ep)
            return ep, state, loss

        t0 = time.time()
        last = None
        for i in range(args.pard_steps):
            ep, state, loss = estep(ep, state, jnp.asarray(next(stream)))
            if (i + 1) % 200 == 0 or i == args.pard_steps - 1:
                last = float(loss)
                print({"eagle_step": i + 1, "loss": round(last, 4)})
        meta = {"loss": last, "wall_s": round(time.time() - t0, 1)}
        checkpoint.save(eagle_path, ep, metadata=meta)
        manifest["runs"]["eagle_head"] = meta
        print(f"[done] eagle_head: {meta}")
    else:
        print("[skip] eagle_head (exists)")
        manifest["runs"]["eagle_head"] = checkpoint.load_metadata(eagle_path)

    with open(os.path.join(ART, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("all artifacts ready under", ART)


if __name__ == "__main__":
    main()
