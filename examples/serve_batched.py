"""Batched serving with continuous batching — the end-to-end driver
(deliverable b): a small model serving a stream of ragged requests through
the Engine with AR / VSD / PARD, reporting throughput and latency.

Uses the trained artifacts when present (run examples/pard_adaptation_train
first), random weights otherwise.

  PYTHONPATH=src python examples/serve_batched.py [--requests 12]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import MarkovCorpus
from repro.models import init_params
from repro.serving.config import EngineConfig, SamplingParams
from repro.serving.engine import Engine
from repro.training import checkpoint

ART = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "artifacts")


def load(name, arch):
    cfg = get_config(arch)
    params = init_params(jax.random.PRNGKey(hash(name) % 2**31), cfg)
    path = os.path.join(ART, f"{name}.npz")
    if os.path.exists(path):
        params = checkpoint.restore(path, params)
    return params, cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    tp, tc = load("bench-target", "bench-target")
    dp, dc = load("bench-draft", "bench-draft")
    pp, _ = load("pard_k8_r07", "bench-draft")

    corpus = MarkovCorpus(vocab_size=tc.vocab_size, seed=0, determinism=3.0)
    rng = np.random.default_rng(0)
    reqs = [corpus.prompts(rng, 1, int(n_tok))[0]
            for n_tok in rng.integers(8, 24, size=args.requests)]

    outputs = {}
    for mode, dparams in [("ar", dp), ("vsd", dp), ("pard", pp)]:
        cfg = EngineConfig(mode=mode, k=8, max_batch=args.max_batch,
                           max_len=512)
        eng = Engine(tp, tc, dparams, dc, config=cfg)
        for r in reqs:
            eng.submit(r, params=SamplingParams(max_new=args.max_new))
        t0 = time.perf_counter()
        comps = eng.run()
        wall = time.perf_counter() - t0
        total = sum(c.generated for c in comps)
        lats = sorted(c.wall_done - c.wall_submitted for c in comps)
        outputs[mode] = {c.rid: c.tokens for c in comps}
        print(f"{mode:5s} {total:4d} tok in {wall:6.2f}s = "
              f"{total / wall:7.1f} tok/s   p50 latency {lats[len(lats)//2]:.2f}s"
              f"   steps={eng.stats['steps']}"
              f" target_fwd={eng.stats['target_forwards']}"
              f" draft_fwd={eng.stats['draft_forwards']}"
              f" kv_peak={eng.peak_kv_bytes_in_use / 1e6:.2f}MB"
              f"/{eng.kv_capacity_bytes() / 1e6:.2f}MB")

    agree = all(np.array_equal(outputs["ar"][r], outputs["pard"][r])
                for r in outputs["ar"])
    print(f"\nall PARD outputs identical to AR greedy: {agree}")


if __name__ == "__main__":
    main()
