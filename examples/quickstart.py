"""Quickstart: the PARD pipeline end-to-end in ~3 minutes on CPU.

1. train a tiny target + draft LM on a synthetic corpus,
2. adapt the draft into a PARD parallel draft (mask tokens + COD),
3. decode with AR / vanilla SD / PARD and compare tokens/s,
4. verify PARD's output is bit-identical to AR greedy (losslessness).

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.cod import CodConfig
from repro.core.spec_decode import SpecDecoder
from repro.data.pipeline import MarkovCorpus
from repro.models import init_params
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.train_loop import Trainer

STEPS = int(os.environ.get("QUICKSTART_STEPS", 120))

tc = get_config("tiny-target")
dc = get_config("tiny-draft")
corpus = MarkovCorpus(vocab_size=tc.vocab_size, seed=0, determinism=3.0)

print(f"== 1. AR-pretrain target ({tc.num_layers}L/{tc.d_model}d) and "
      f"draft ({dc.num_layers}L/{dc.d_model}d), {STEPS} steps each ==")
tp = init_params(jax.random.PRNGKey(0), tc)
tr = Trainer(tc, AdamW(lr=cosine_schedule(3e-3, 20, STEPS)), loss_kind="ar")
tp, _, h = tr.fit(tp, corpus.batches(16, 96, seed=0), STEPS,
                  log_every=STEPS, log_fn=None)
print(f"   target loss: {h[-1]['loss']:.3f}")
dp = init_params(jax.random.PRNGKey(1), dc)
tr = Trainer(dc, AdamW(lr=cosine_schedule(3e-3, 20, STEPS)), loss_kind="ar")
dp, _, h = tr.fit(dp, corpus.batches(16, 96, seed=1), STEPS,
                  log_every=STEPS, log_fn=None)
print(f"   draft  loss: {h[-1]['loss']:.3f}")

print("== 2. PARD adaptation (mask tokens + conditional drop, Alg. 1) ==")
cod = CodConfig(k=4, r=0.7, r_min=0.2)
tr = Trainer(dc, AdamW(lr=cosine_schedule(2.5e-3, 20, STEPS * 2)),
             loss_kind="pard", cod=cod)
dp_pard, _, h = tr.fit(dp, corpus.batches(16, 96, seed=7), STEPS * 2,
                       log_every=STEPS * 2, log_fn=None)
print(f"   adaptation loss: {h[-1]['loss']:.3f} "
      f"(train tokens: {h[-1]['tokens']})")

print("== 3. decode: AR vs VSD vs PARD ==")
rng = np.random.default_rng(5)
prompt = jnp.asarray(corpus.prompts(rng, 4, 16))
MAX_NEW = 48

results = {}
dec_vsd = SpecDecoder(tp, tc, dp, dc, k=4, max_len=512)
dec_pard = SpecDecoder(tp, tc, dp_pard, dc, k=4, max_len=512)

for name, fn in [
    ("AR+", lambda: dec_vsd.generate_ar(prompt, MAX_NEW)),
    ("VSD", lambda: dec_vsd.generate_spec(prompt, MAX_NEW, mode="vsd")),
    ("PARD", lambda: dec_pard.generate_spec(prompt, MAX_NEW, mode="pard")),
]:
    fn()  # warm the jit
    t0 = time.perf_counter()
    toks, stats = fn()
    secs = time.perf_counter() - t0
    results[name] = (toks, secs, stats)
    extra = ""
    if name != "AR+":
        extra = (f"  acceptance={stats.acceptance_rate:.2f}"
                 f"  draft_fwd/iter={stats.draft_forwards / stats.iterations:.1f}")
    print(f"   {name:5s} {MAX_NEW * 4 / secs:8.1f} tok/s{extra}")

ar_t, vsd_t, pard_t = (results[k][1] for k in ("AR+", "VSD", "PARD"))
print(f"   speedups vs AR+: VSD {ar_t / vsd_t:.2f}x, PARD {ar_t / pard_t:.2f}x"
      f"   (paper: VSD 2.31x, PARD 3.57x on A100)")

print("== 4. losslessness ==")
same = bool(jnp.all(results["AR+"][0] == results["PARD"][0]))
print(f"   PARD output identical to AR greedy: {same}")
assert same
