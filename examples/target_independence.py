"""Target independence (paper Table 2 / Fig. 2): ONE PARD-adapted draft
accelerates an entire family of target models — no per-target retraining,
unlike EAGLE/Medusa heads.

  PYTHONPATH=src python examples/target_independence.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.spec_decode import SpecDecoder
from repro.data.pipeline import MarkovCorpus
from repro.models import init_params
from repro.training import checkpoint

ART = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "artifacts")


def load(name, arch):
    cfg = get_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    path = os.path.join(ART, f"{name}.npz")
    if os.path.exists(path):
        params = checkpoint.restore(path, params)
    else:
        print(f"(artifact {name} missing — random weights; run "
              f"examples/pard_adaptation_train.py for the real numbers)")
    return params, cfg


def main():
    pard_draft, dc = load("pard_k8_r07", "bench-draft")
    corpus = MarkovCorpus(vocab_size=dc.vocab_size, seed=0, determinism=3.0)
    prompt = jnp.asarray(corpus.prompts(np.random.default_rng(5), 4, 16))
    MAX_NEW = 48

    print("one PARD draft (tiny-draft, adapted once) against three targets:\n")
    print(f"{'target':14s} {'AR+ tok/s':>10s} {'PARD tok/s':>11s} "
          f"{'speedup':>8s} {'acc':>6s} {'lossless':>9s}")
    for tname in ("bench-target", "bench-mid", "bench-draft"):
        tp, tc = load(tname, tname)
        dec = SpecDecoder(tp, tc, pard_draft, dc, k=8, max_len=512)
        dec.generate_ar(prompt, MAX_NEW)  # warm
        t0 = time.perf_counter()
        ar, _ = dec.generate_ar(prompt, MAX_NEW)
        t_ar = time.perf_counter() - t0
        dec.generate_spec(prompt, MAX_NEW, mode="pard")  # warm
        t0 = time.perf_counter()
        sp, st = dec.generate_spec(prompt, MAX_NEW, mode="pard")
        t_sp = time.perf_counter() - t0
        print(f"{tname:14s} {MAX_NEW * 4 / t_ar:10.1f} "
              f"{MAX_NEW * 4 / t_sp:11.1f} {t_ar / t_sp:7.2f}x "
              f"{st.acceptance_rate:6.2f} {str(bool(jnp.all(ar == sp))):>9s}")

    print("\npaper (Table 2, one L3.2-1B PARD draft): L3-8B 3.25x, "
          "L3.2-3B 2.81x, L3.2-1B (self) 2.17x")


if __name__ == "__main__":
    main()
